"""Lazy-learning training driver (paper §4.1 recipe, CPU-scaled).

Reproduces the paper's pipeline on a reduced DiT-XL/2-family model:
frozen base + probe training with the lazy loss at a chosen penalty rho,
then reports the penalty -> lazy-ratio curve (the knob behind Tables 1/2)
and saves a calibrated lazy plan + checkpoint.

Run:  PYTHONPATH=src python examples/train_lazydit.py [--steps 120]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_checkpoint
from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.core import lazy as lazy_lib
from repro.data.synthetic import LatentImageDataset
from repro.models import dit as dit_lib
from repro.sampling import ddim
from repro.train import optim, trainer


def train_at_rho(base_params, cfg, sched, data, key, rho, steps):
    cfg_r = cfg.replace(lazy=cfg.lazy.__class__(
        enabled=True, rho_attn=rho, rho_ffn=rho))
    params = jax.tree.map(jnp.copy, base_params)
    opt = optim.adamw_init(params)
    it = data.batches(8, seed=int(rho * 1e6) % 2**31)
    aux = {}
    for i in range(steps):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, aux = trainer.lazy_train_step(
            params, opt, cfg_r, sched, jnp.asarray(x0), jnp.asarray(y), k,
            n_sample_steps=10, lr=1e-2)
    return params, aux


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--pretrain-steps", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config("dit_xl2_256").reduced(dit_input_size=16,
                                            dit_n_classes=8, n_layers=4)
    key = jax.random.PRNGKey(0)
    params = dit_lib.init_dit(key, cfg)
    sched = ddim.linear_schedule(200)
    data = LatentImageDataset(cfg, seed=0)

    print(f"model: reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model}")
    opt = optim.adamw_init(params)
    it = data.batches(16, seed=1)
    for i in range(args.pretrain_steps):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, aux = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    print(f"pretrain done, loss={float(aux['loss']):.4f}")

    # penalty regulation sweep (paper: rho from 1e-7 to 1e-2)
    print(f"{'rho':>10} {'s_attn':>8} {'s_ffn':>8} {'ratio@0.5':>10}")
    best = None
    for rho in (1e-4, 1e-3, 5e-3, 2e-2):
        p_r, aux = train_at_rho(params, cfg, sched, data, key, rho, args.steps)
        # measure realized ratio on a sampling run
        cfg_r = cfg.replace(lazy=LazyConfig(enabled=True, rho_attn=rho,
                                            rho_ffn=rho))
        _, am = ddim.ddim_sample(p_r, cfg_r, sched, key=jax.random.PRNGKey(3),
                                 labels=jnp.arange(4) % cfg.dit_n_classes,
                                 n_steps=10, lazy_mode="masked",
                                 collect_scores=True)
        sc = np.stack([np.stack([s["attn"], s["ffn"]], -1)
                       for s in am["scores"]])
        ratio = float((sc[1:] > 0.5).mean())
        print(f"{rho:10.0e} {float(aux['s_attn']):8.3f} "
              f"{float(aux['s_ffn']):8.3f} {ratio:10.1%}")
        if best is None or abs(ratio - 0.5) < abs(best[1] - 0.5):
            best = (p_r, ratio, sc)

    p_best, ratio, sc = best
    plan = lazy_lib.plan_from_scores(sc.mean(2))
    out = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    os.makedirs(out, exist_ok=True)
    save_checkpoint(os.path.join(out, "lazydit_ckpt.npz"), p_best)
    np.save(os.path.join(out, "lazy_plan.npy"), plan.skip)
    print(f"saved checkpoint + plan (lazy ratio {plan.lazy_ratio:.1%}) "
          f"-> artifacts/")


if __name__ == "__main__":
    main()
