"""Lazy decoding for an assigned LLM architecture (beyond-paper transfer).

Serves a reduced llama3.2 with the batched engine in off vs masked lazy
modes and reports probe scores, realized lazy ratio, and output agreement.

Run:  PYTHONPATH=src python examples/serve_lazy_llm.py [--arch llama3_2_1b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.models import transformer as tf
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--n-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = cfg.replace(lazy=LazyConfig(enabled=True, mode="masked"))
    print(f"arch={cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)

    eng_off = Engine(cfg, params, max_len=64, lazy_mode="off")
    res_off = eng_off.generate(prompt, n_new=args.n_new)
    eng_lazy = Engine(cfg, params, max_len=64, lazy_mode="masked")
    res_lazy = eng_lazy.generate(prompt, n_new=args.n_new)

    agree = float((res_off.tokens == res_lazy.tokens).mean())
    print(f"generated (off):  {res_off.tokens[0].tolist()}")
    print(f"generated (lazy): {res_lazy.tokens[0].tolist()}")
    print(f"token agreement: {agree:.1%}")
    print(f"realized lazy ratio: {res_lazy.realized_lazy_ratio:.1%}")
    if res_lazy.scores is not None:
        print(f"mean probe scores per step: "
              f"{np.round(res_lazy.scores.mean(1), 3).tolist()}")
    print("note: probes are untrained here (init bias -2 -> diligent); "
          "examples/train_lazydit.py shows the training side on DiT.")


if __name__ == "__main__":
    main()
