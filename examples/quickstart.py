"""Quickstart: the full LazyDiT pipeline at laptop scale in ~2 minutes.

  1. pretrain a tiny DiT on synthetic latents,
  2. lazy-learn the probes (paper §3.3: frozen base, lazy loss),
  3. sample with DDIM in all three lazy modes,
  4. report realized lazy ratio + cross-step similarity (paper Thm 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.core import similarity as sim_lib
from repro.data.synthetic import LatentImageDataset
from repro.models import dit as dit_lib
from repro.sampling import ddim, trajectory
from repro.train import optim, trainer


def main():
    cfg = ModelConfig(
        name="dit-quickstart", family="dit", n_layers=4, d_model=96,
        n_heads=4, n_kv_heads=4, d_ff=256, rope_type="none",
        dit_patch=2, dit_input_size=16, dit_in_channels=4, dit_n_classes=8,
        dtype="float32",
        lazy=LazyConfig(enabled=True, rho_attn=5e-3, rho_ffn=5e-3))
    key = jax.random.PRNGKey(0)
    params = dit_lib.init_dit(key, cfg)
    sched = ddim.linear_schedule(200)
    data = LatentImageDataset(cfg, seed=0)

    # 1. diffusion pretraining ------------------------------------------------
    print("== pretraining tiny DiT (80 steps) ==")
    opt = optim.adamw_init(params)
    it = data.batches(16, seed=1)
    for i in range(80):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, aux = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
        if i % 20 == 0:
            print(f"  step {i:3d} loss {float(aux['loss']):.4f}")

    # 2. lazy learning (paper recipe, shrunk) ---------------------------------
    print("== lazy learning (60 steps, frozen base) ==")
    opt2 = optim.adamw_init(params)
    for i in range(60):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt2, aux = trainer.lazy_train_step(
            params, opt2, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            n_sample_steps=10, lr=2e-2)
        if i % 20 == 0:
            print(f"  step {i:3d} diff {float(aux['diffusion_loss']):.4f} "
                  f"lazy {float(aux['lazy_loss']):.5f} "
                  f"s_attn {float(aux['s_attn']):.3f} "
                  f"s_ffn {float(aux['s_ffn']):.3f}")

    # 3. sampling in all modes ------------------------------------------------
    # the no-collect paths run through the FUSED single-compile trajectory
    # executor (sampling/trajectory.py): the whole DDIM loop is one
    # lax.scan, plan rows ride along as scanned device arrays
    labels = jnp.arange(4) % cfg.dit_n_classes
    kk = jax.random.PRNGKey(7)
    x_full, _ = ddim.ddim_sample(params, cfg, sched, key=kk, labels=labels,
                                 n_steps=10, lazy_mode="off")
    x_masked, aux_m = ddim.ddim_sample(params, cfg, sched, key=kk,
                                       labels=labels, n_steps=10,
                                       lazy_mode="masked",
                                       collect_scores=True,
                                       collect_traces=True)
    scores = np.stack([np.stack([s["attn"], s["ffn"]], -1)
                       for s in aux_m["scores"]])           # (T, L, B, 2)
    ratio = float((scores[1:] > 0.5).mean())
    print(f"== realized lazy ratio (masked mode): {ratio:.1%}")

    plan = lazy_lib.plan_with_target_ratio(scores.mean(2), target=0.3)
    x_plan, aux_p = trajectory.sample_trajectory(
        params, cfg, sched, key=kk, labels=labels, n_steps=10,
        lazy_mode="plan", plan=plan.skip)
    print(f"== fused plan-mode trajectory: one compiled scan, realized "
          f"skip ratio {aux_p['realized_skip_ratio']:.1%}")
    err_m = float(jnp.mean((x_full - x_masked) ** 2))
    err_p = float(jnp.mean((x_full - x_plan) ** 2))
    ref = float(jnp.mean(x_full ** 2))
    print(f"   sample MSE vs full: masked={err_m:.4f} plan@30%={err_p:.4f} "
          f"(signal power {ref:.3f})")

    # 4. cross-step similarity (Thm 2) ---------------------------------------
    traces = np.stack([t["attn"] for t in aux_m["traces"]])
    sims = sim_lib.consecutive_step_similarity(jnp.asarray(traces))
    print(f"== mean consecutive-step attention-output similarity: "
          f"{float(jnp.mean(sims[1:])):.4f} (paper: lower bound is high)")
    print("quickstart done.")


if __name__ == "__main__":
    main()
