"""Per-kernel benchmarks: oracle parity, wall time, and realized bytes.

Two tiers:

  * the legacy microbench rows (dense flash attention, pooled lazy gate,
    ssm scan) — interpret-mode wall time on a CPU host is a
    correctness-path signal only; the BlockSpec tiling is the TPU
    deliverable (full ``run()`` only);
  * the skip-aware kernel acceptance section (ISSUE PR 9): plan-aware
    lazy attention on reduced dit_xl2_256 shapes with the static_router
    plan's skip ratio, plus the fused gate+select and DDIM-update
    kernels.  Emits ``artifacts/BENCH_kernels.json``
    (schema ``repro.bench.kernels/v1``) whose machine-independent metrics
    (bytes-saving fraction, plan skip ratio, cached-serve bit-exactness,
    parity flags) and same-run wall ratios (skip-on vs where-select
    speedups, with MAD noise siblings) are gated by
    ``benchmarks/check_regression.py``.

Realized-bytes columns join two sources: the AOT-compiled XLA executable's
``cost_analysis()['bytes accessed']`` / ``memory_analysis()`` numeric
counters for the select path, and the modeled touch set of the served
branch (cached tile read + output write) — the O(1) memory claim of the
skip bit.  Achieved GB/s divides those bytes by the measured wall
(repro.obs.profile.measure medians)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS, time_fn
from repro import cache as cache_lib
from repro.configs.registry import get_config
from repro.kernels.ddim_update import ops as ddim_ops
from repro.kernels.ddim_update.kernel import ddim_update as ddim_update_kernel
from repro.kernels.ddim_update.ref import ddim_update_ref
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lazy_gate import ops as gate_ops
from repro.kernels.lazy_gate.kernel import lazy_gate_pooled, lazy_gate_select
from repro.kernels.lazy_gate.ref import (lazy_gate_pooled_ref,
                                         lazy_gate_select_ref)
from repro.kernels.ssm_scan.ops import ssd
from repro.kernels.ssm_scan.ref import ssd_naive_ref

SCHEMA = "repro.bench.kernels/v1"

_MEM_ATTRS = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")


def compiled_bytes(fn, *args, static_argnames=()):
    """AOT-compile ``fn`` and pull the numeric byte/FLOP counters.

    Only plain numbers are extracted — never ``serialized_hlo_proto`` or
    other blobs — so the result drops straight into a JSON artifact."""
    compiled = jax.jit(fn, static_argnames=static_argnames).lower(
        *args).compile()
    out = {}
    mem = compiled.memory_analysis()
    for attr in _MEM_ATTRS:
        v = getattr(mem, attr, None)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[attr] = int(v)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    for src, dst in (("bytes accessed", "bytes_accessed"), ("flops", "flops")):
        try:
            v = cost.get(src)
        except AttributeError:
            v = None
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[dst] = float(v)
    return out


def _ratio_with_mad(num_us, num_mad, den_us, den_mad):
    """(ratio, mad) for num/den with first-order error propagation."""
    r = num_us / max(den_us, 1e-9)
    mad = r * (num_mad / max(num_us, 1e-9) + den_mad / max(den_us, 1e-9))
    return round(r, 4), round(mad, 4)


def _gbps(n_bytes, wall_us):
    return round(n_bytes / max(wall_us, 1e-9) / 1e3, 3)  # bytes/us -> GB/s


def _lazy_attention_section(iters: int) -> dict:
    """Acceptance section: plan-aware attention on reduced dit_xl2_256
    shapes at the static_router plan's attention skip ratio.

    On this CPU host the skip bit is realized as the ops-level
    ``lax.cond`` short-circuit (the kernel's ``pl.when`` gating is the
    compiled-Pallas realization of the same contract — see
    kernels/flash_attention/ops.py); the baseline is the pre-PR XLA
    where-select path, which pays full attention regardless of the bit."""
    cfg = get_config("dit_xl2_256").reduced()
    B = 4
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    S = (cfg.dit_input_size // cfg.dit_patch) ** 2
    n_steps = 8
    pol = cache_lib.get_policy("static_router", ratio=0.5)
    plan = pol.compile_plan(n_steps, cfg.n_layers)
    ratio = float(np.asarray(plan.skip)[:, :, 0].mean())  # attention module

    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    cached = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)
    skip_on = jnp.ones((B,), bool)
    skip_off = jnp.zeros((B,), bool)

    @jax.jit
    def where_select(q, k, v, cached, skip):
        """Pre-PR baseline: always-fresh attention + jnp.where."""
        qt, kt = q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3)
        vt, ct = v.transpose(0, 2, 1, 3), cached.transpose(0, 2, 1, 3)
        fresh = attention_ref(qt, kt, vt, causal=False, window=0, softcap=0.0)
        out = jnp.where(skip.reshape(-1, 1, 1, 1), ct, fresh)
        return out.transpose(0, 2, 1, 3)

    def lazy(skip):
        return jax.block_until_ready(flash_ops.lazy_gqa_flash_attention(
            q, k, v, cached, skip))

    def select(skip):
        return jax.block_until_ready(where_select(q, k, v, cached, skip))

    # the acceptance bit-exactness contract: a served-cache step returns
    # the cached tile EXACTLY, and agrees bit-for-bit with select_cached
    served = lazy(skip_on)
    bitexact = (bool(np.array_equal(np.asarray(served), np.asarray(cached)))
                and bool(np.array_equal(np.asarray(served),
                                        np.asarray(select(skip_on)))))
    assert bitexact, "skip-on lazy attention did not serve the cache bit-exactly"
    mixed_err = float(jnp.max(jnp.abs(lazy(skip_off) - select(skip_off))))

    walls = {}
    for name, fn, s in (("lazy_skip_on", lazy, skip_on),
                        ("lazy_skip_off", lazy, skip_off),
                        ("select", select, skip_on)):
        us, mad, kept = time_fn(fn, s, iters=iters, warmup=2)
        walls[name] = {"us": round(us, 1), "us_mad": round(mad, 1),
                       "iters": kept}

    skip_speedup, skip_speedup_mad = _ratio_with_mad(
        walls["select"]["us"], walls["select"]["us_mad"],
        walls["lazy_skip_on"]["us"], walls["lazy_skip_on"]["us_mad"])
    # a trajectory at the plan ratio serves `ratio` of attention steps from
    # cache; the select baseline pays full attention on every step
    blend_us = (ratio * walls["lazy_skip_on"]["us"]
                + (1.0 - ratio) * walls["lazy_skip_off"]["us"])
    blend_mad = (ratio * walls["lazy_skip_on"]["us_mad"]
                 + (1.0 - ratio) * walls["lazy_skip_off"]["us_mad"])
    blended_speedup, blended_speedup_mad = _ratio_with_mad(
        walls["select"]["us"], walls["select"]["us_mad"],
        blend_us, blend_mad)

    # MAD-aware acceptance: skip-on must beat the select path beyond the
    # combined measurement noise, not just on the medians
    lo_select = walls["select"]["us"] - 4.0 * walls["select"]["us_mad"]
    hi_skip = (walls["lazy_skip_on"]["us"]
               + 4.0 * walls["lazy_skip_on"]["us_mad"])
    assert hi_skip < lo_select, (
        f"skip-on wall {walls['lazy_skip_on']['us']}us not separated from "
        f"select {walls['select']['us']}us beyond 4 MADs")

    # realized bytes: XLA's own accounting for the select path vs the
    # modeled touch set of the served branch (cached read + output write)
    select_bytes = compiled_bytes(where_select, q, k, v, cached, skip_on)
    served_modeled = int(cached.nbytes + served.nbytes)
    accessed = select_bytes.get("bytes_accessed", 0.0)
    saving = 1.0 - served_modeled / accessed if accessed else float("nan")
    assert saving > 0.5, f"served-branch bytes saving only {saving:.1%}"

    return {
        "shape": {"batch": B, "heads": H, "seq": S, "head_dim": hd,
                  "arch": "dit_xl2_256 (reduced)"},
        "plan": {"policy": "static_router", "target_ratio": 0.5,
                 "n_steps": n_steps, "n_layers": cfg.n_layers},
        "plan_skip_ratio": round(ratio, 4),
        "wall_us": walls,
        "skip_speedup_vs_select": skip_speedup,
        "skip_speedup_vs_select_mad": skip_speedup_mad,
        "blended_speedup_at_plan": blended_speedup,
        "blended_speedup_at_plan_mad": blended_speedup_mad,
        "cached_serve_bitexact": bitexact,
        "skip_off_max_err_vs_select": mixed_err,
        "bytes": {
            "select_path": select_bytes,
            "served_modeled": served_modeled,
            "achieved_gbps_select": _gbps(accessed, walls["select"]["us"]),
            "achieved_gbps_skip_on": _gbps(served_modeled,
                                           walls["lazy_skip_on"]["us"]),
        },
        "bytes_saving_frac": round(saving, 4),
    }


def _gate_select_section(iters: int) -> dict:
    """Fused gate-score + cache-select kernel vs its oracle and vs the
    unfused core.lazy composition (gate_score then select_cached)."""
    cfg = get_config("dit_xl2_256").reduced()
    B, D = 4, cfg.d_model
    N = (cfg.dit_input_size // cfg.dit_patch) ** 2
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    z = jax.random.normal(ks[0], (B, N, D), jnp.float32)
    w = jax.random.normal(ks[1], (D, 1), jnp.float32) * 0.05
    b = jax.random.normal(ks[2], (1,), jnp.float32) * 0.1
    y_new = jax.random.normal(ks[3], (B, N, D), jnp.float32)
    cache_y = jax.random.normal(ks[4], (B, N, D), jnp.float32)

    y_kern, s_kern = lazy_gate_select(z, w, b, y_new, cache_y,
                                      interpret=True)
    y_ref, s_ref = lazy_gate_select_ref(z, w, b, y_new, cache_y)
    y_err = float(jnp.max(jnp.abs(y_kern - y_ref)))
    s_err = float(jnp.max(jnp.abs(s_kern - s_ref)))
    parity_ok = y_err < 1e-5 and s_err < 1e-5
    assert parity_ok, f"gate_select parity: y_err={y_err} s_err={s_err}"

    def fused(z):
        return jax.block_until_ready(
            gate_ops.lazy_gate_select(z, w, b, y_new, cache_y)[0])

    us, mad, kept = time_fn(fused, z, iters=iters, warmup=2)
    fused_bytes = compiled_bytes(
        lambda z: gate_ops.lazy_gate_select(z, w, b, y_new, cache_y)[0], z)
    return {
        "shape": {"batch": B, "tokens": N, "d_model": D},
        "parity_ok": parity_ok,
        "y_max_err": y_err, "score_max_err": s_err,
        "wall_us": {"fused": {"us": round(us, 1), "us_mad": round(mad, 1),
                              "iters": kept}},
        "bytes": {"fused_path": fused_bytes,
                  "achieved_gbps_fused": _gbps(
                      fused_bytes.get("bytes_accessed", 0.0), us)},
    }


def _ddim_section(iters: int) -> dict:
    """Fused DDIM-update kernel vs its oracle, deterministic + eta>0."""
    cfg = get_config("dit_xl2_256").reduced()
    B = 4
    shape = (B, cfg.dit_input_size, cfg.dit_input_size, cfg.dit_in_channels)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    z = jax.random.normal(ks[0], shape, jnp.float32)
    eps = jax.random.normal(ks[1], shape, jnp.float32)
    noise = jax.random.normal(ks[2], shape, jnp.float32)
    a_t = jnp.full((B,), 0.7, jnp.float32)
    a_p = jnp.full((B,), 0.9, jnp.float32)

    errs = {}
    for eta in (0.0, 0.5):
        n = noise if eta > 0 else None
        got = ddim_update_kernel(z, eps, a_t, a_p, n, eta=eta, interpret=True)
        want = ddim_update_ref(z, eps, a_t, a_p, n, eta=eta)
        errs[f"eta_{eta}"] = float(jnp.max(jnp.abs(got - want)))
    parity_ok = all(e < 1e-5 for e in errs.values())
    assert parity_ok, f"ddim_update parity: {errs}"

    def fused(z):
        return jax.block_until_ready(
            ddim_ops.ddim_update(z, eps, a_t, a_p, noise, eta=0.5))

    us, mad, kept = time_fn(fused, z, iters=iters, warmup=2)
    fused_bytes = compiled_bytes(
        lambda z: ddim_ops.ddim_update(z, eps, a_t, a_p, noise, eta=0.5), z)
    return {
        "shape": {"batch": B, "latent": cfg.dit_input_size,
                  "channels": cfg.dit_in_channels},
        "parity_ok": parity_ok,
        "max_err": errs,
        "wall_us": {"fused": {"us": round(us, 1), "us_mad": round(mad, 1),
                              "iters": kept}},
        "bytes": {"fused_path": fused_bytes,
                  "achieved_gbps_fused": _gbps(
                      fused_bytes.get("bytes_accessed", 0.0), us)},
    }


def _dense_rows() -> list:
    """The pre-existing microbench rows (full run only)."""
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # lazy_gate: DiT-XL-ish tile
    B, N, D = 4, 256, 512
    x = jax.random.normal(ks[0], (B, N, D))
    sc = jax.random.normal(ks[1], (B, D)) * 0.1
    sh = jax.random.normal(ks[2], (B, D)) * 0.1
    w = jax.random.normal(ks[3], (D, 1)) * 0.05
    got = lazy_gate_pooled(x, sc, sh, w, interpret=True)
    want = lazy_gate_pooled_ref(x, sc, sh, w)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: lazy_gate_pooled(a, sc, sh, w, interpret=True), x)
    us_ref, _, _ = time_fn(lambda a: lazy_gate_pooled_ref(a, sc, sh, w), x)
    rows.append(("lazy_gate", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))

    # flash attention: one head tile at prefill-ish length
    Bh, H, S, d = 1, 2, 512, 64
    q = jax.random.normal(ks[4], (Bh, H, S, d))
    k = jax.random.normal(ks[5], (Bh, H, S, d))
    v = jax.random.normal(ks[6], (Bh, H, S, d))
    got = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=0, softcap=0.0)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: flash_attention(a, k, v, interpret=True), q)
    us_ref, _, _ = time_fn(lambda a: attention_ref(a, k, v, causal=True,
                                                   window=0, softcap=0.0), q)
    rows.append(("flash_attention", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))

    # ssm scan
    B2, S2, H2, P2, N2 = 2, 256, 4, 16, 16
    x2 = jax.random.normal(ks[7], (B2, S2, H2, P2))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(9), (B2, S2, H2)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(10), (H2,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(11), (B2, S2, N2))
    Cm = jax.random.normal(jax.random.PRNGKey(12), (B2, S2, N2))
    got = ssd(x2, dt, A, Bm, Cm, chunk=64, use_pallas=True)
    want = ssd_naive_ref(x2, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: ssd(a, dt, A, Bm, Cm, chunk=64), x2)
    us_ref, _, _ = time_fn(lambda a: ssd(a, dt, A, Bm, Cm, chunk=64,
                                         use_pallas=False), x2)
    rows.append(("ssm_scan", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))
    return rows


def run_bench(*, smoke: bool = False):
    iters = 3 if smoke else 7
    lazy_attn = _lazy_attention_section(iters)
    gate_sel = _gate_select_section(iters)
    ddim_upd = _ddim_section(iters)

    payload = {
        "schema": SCHEMA,
        "smoke": smoke,
        "harness": "repro.obs.profile.measure (median + MAD); AOT "
                   "cost_analysis/memory_analysis numeric counters",
        "lazy_attention": lazy_attn,
        "gate_select": gate_sel,
        "ddim_update": ddim_upd,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS, "BENCH_kernels.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

    la, by = lazy_attn, lazy_attn["bytes"]
    rows = [
        ("kernels", "lazy_attention",
         f"skip_on_us={la['wall_us']['lazy_skip_on']['us']:.0f}",
         f"skip_off_us={la['wall_us']['lazy_skip_off']['us']:.0f}",
         f"select_us={la['wall_us']['select']['us']:.0f}",
         f"skip_speedup={la['skip_speedup_vs_select']:.2f}x",
         f"blended_at_ratio_{la['plan_skip_ratio']:.2f}"
         f"={la['blended_speedup_at_plan']:.2f}x",
         f"bitexact={la['cached_serve_bitexact']}"),
        ("kernels", "lazy_attention_bytes",
         f"select_accessed_mb={by['select_path'].get('bytes_accessed', 0) / 1e6:.1f}",
         f"served_modeled_mb={by['served_modeled'] / 1e6:.2f}",
         f"saving_frac={la['bytes_saving_frac']:.3f}",
         f"achieved_gbps_select={by['achieved_gbps_select']}",
         f"achieved_gbps_skip_on={by['achieved_gbps_skip_on']}"),
        ("kernels", "gate_select",
         f"fused_us={gate_sel['wall_us']['fused']['us']:.0f}",
         f"y_max_err={gate_sel['y_max_err']:.1e}",
         f"score_max_err={gate_sel['score_max_err']:.1e}",
         f"bytes_accessed_mb="
         f"{gate_sel['bytes']['fused_path'].get('bytes_accessed', 0) / 1e6:.1f}"),
        ("kernels", "ddim_update",
         f"fused_us={ddim_upd['wall_us']['fused']['us']:.0f}",
         "max_err=" + "/".join(f"{v:.1e}"
                               for v in ddim_upd["max_err"].values()),
         f"bytes_accessed_mb="
         f"{ddim_upd['bytes']['fused_path'].get('bytes_accessed', 0) / 1e6:.1f}"),
        ("kernels", "json", path),
    ]
    return rows, payload


def run() -> list:
    """Full-suite entry (benchmarks.run): dense microbenches + the
    skip-aware acceptance sections."""
    rows = _dense_rows()
    lazy_rows, _ = run_bench(smoke=False)
    return rows + lazy_rows


def run_smoke() -> list:
    """CI smoke entry: same sections/assertions/artifact, fewer iters."""
    rows, _ = run_bench(smoke=True)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iters; same assertions and artifact")
    args = ap.parse_args()
    for row in (run_smoke() if args.smoke else run()):
        print(",".join(str(x) for x in row), flush=True)
