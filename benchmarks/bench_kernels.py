"""Per-kernel microbenchmarks: us/call (interpret-mode wall time on this CPU
host is a correctness-path signal only; the BlockSpec tiling is the TPU
deliverable) and allclose deltas vs the oracles."""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lazy_gate.kernel import lazy_gate_pooled
from repro.kernels.lazy_gate.ref import lazy_gate_pooled_ref
from repro.kernels.ssm_scan.ops import ssd
from repro.kernels.ssm_scan.ref import ssd_naive_ref


def run() -> list:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # lazy_gate: DiT-XL-ish tile
    B, N, D = 4, 256, 512
    x = jax.random.normal(ks[0], (B, N, D))
    sc = jax.random.normal(ks[1], (B, D)) * 0.1
    sh = jax.random.normal(ks[2], (B, D)) * 0.1
    w = jax.random.normal(ks[3], (D, 1)) * 0.05
    got = lazy_gate_pooled(x, sc, sh, w)
    want = lazy_gate_pooled_ref(x, sc, sh, w)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: lazy_gate_pooled(a, sc, sh, w), x)
    us_ref, _, _ = time_fn(lambda a: lazy_gate_pooled_ref(a, sc, sh, w), x)
    rows.append(("lazy_gate", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))

    # flash attention: one head tile at prefill-ish length
    Bh, H, S, d = 1, 2, 512, 64
    q = jax.random.normal(ks[4], (Bh, H, S, d))
    k = jax.random.normal(ks[5], (Bh, H, S, d))
    v = jax.random.normal(ks[6], (Bh, H, S, d))
    got = flash_attention(q, k, v, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=True, window=0, softcap=0.0)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: flash_attention(a, k, v), q)
    us_ref, _, _ = time_fn(lambda a: attention_ref(a, k, v, causal=True, window=0,
                                             softcap=0.0), q)
    rows.append(("flash_attention", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))

    # ssm scan
    B2, S2, H2, P2, N2 = 2, 256, 4, 16, 16
    x2 = jax.random.normal(ks[7], (B2, S2, H2, P2))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(9), (B2, S2, H2)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(10), (H2,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(11), (B2, S2, N2))
    Cm = jax.random.normal(jax.random.PRNGKey(12), (B2, S2, N2))
    got = ssd(x2, dt, A, Bm, Cm, chunk=64, use_pallas=True)
    want = ssd_naive_ref(x2, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(got - want)))
    us, _, _ = time_fn(lambda a: ssd(a, dt, A, Bm, Cm, chunk=64), x2)
    us_ref, _, _ = time_fn(lambda a: ssd(a, dt, A, Bm, Cm, chunk=64,
                                   use_pallas=False), x2)
    rows.append(("ssm_scan", f"us_per_call={us:.0f}",
                 f"ref_us={us_ref:.0f}", f"max_err={err:.2e}"))
    return rows
