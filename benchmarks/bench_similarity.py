"""Paper §3.2 + Figure 4 analogue: cross-step output similarity per module
and the layer-wise laziness distribution of trained probes."""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import lazy_dit_fixture
from repro.core import similarity as sim_lib
from repro.sampling import ddim


def run() -> list:
    cfg, params, sched = lazy_dit_fixture()
    labels = jnp.arange(4) % cfg.dit_n_classes
    _, aux = ddim.ddim_sample(params, cfg, sched, key=jax.random.PRNGKey(5),
                              labels=labels, n_steps=10, lazy_mode="masked",
                              collect_scores=True, collect_traces=True)
    rows = []
    for mod in ("attn", "ffn"):
        traces = np.stack([t[mod] for t in aux["traces"]])     # (T,L,B,N,D)
        sims = np.asarray(sim_lib.consecutive_step_similarity(
            jnp.asarray(traces)))                               # (T-1,L,B)
        # similarity lower bound check (Thm 2): min and mean
        rows.append((f"similarity_{mod}_mean", float(sims[1:].mean())))
        rows.append((f"similarity_{mod}_min", float(sims[1:].min())))
        # layer-wise laziness (Fig 4): trained probe skip freq per layer
        sc = np.stack([s[mod] for s in aux["scores"]])          # (T,L,B)
        layer_ratio = (sc[1:] > 0.5).mean(axis=(0, 2))
        rows.append((f"layerwise_lazy_{mod}",
                     "|".join(f"{r:.2f}" for r in layer_ratio)))
    # Thm 3: linear predictability of similarity from modulated input
    traces = np.stack([t["attn"] for t in aux["traces"]])
    sims = np.asarray(sim_lib.consecutive_step_similarity(jnp.asarray(traces)))
    z = traces[1:].reshape(-1, *traces.shape[-2:])
    _, r2 = sim_lib.linear_probe_fit(z, sims.reshape(-1))
    rows.append(("thm3_linear_fit_r2", float(r2)))
    return rows
