"""Benchmark harness — one module per paper table/figure.

  bench_similarity    — paper §3.2 / Fig. 4 (similarity + layer-wise laziness)
  bench_lazy_tradeoff — paper Tables 1/2/5 (quality vs compute)
  bench_compute       — paper Tables 3/6 (TMACs / compiled-FLOPs vs ratio)
  bench_kernels       — Pallas kernels vs oracles
  bench_roofline      — §Roofline table from dry-run artifacts

Prints ``name,field,...`` CSV rows.  PYTHONPATH=src python -m benchmarks.run
"""
import sys
import time
import traceback


def main() -> None:
    import benchmarks.bench_similarity as b_sim
    import benchmarks.bench_lazy_tradeoff as b_lazy
    import benchmarks.bench_compute as b_comp
    import benchmarks.bench_kernels as b_kern
    import benchmarks.bench_roofline as b_roof

    suites = [("similarity", b_sim), ("lazy_tradeoff", b_lazy),
              ("compute", b_comp), ("kernels", b_kern),
              ("roofline", b_roof)]
    failed = 0
    for name, mod in suites:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            for row in mod.run():
                if isinstance(row, tuple):
                    print(",".join(str(x) for x in row), flush=True)
                else:
                    print(row, flush=True)
        except Exception:
            failed += 1
            print(f"{name},ERROR", flush=True)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
