"""Benchmark harness — one module per paper table/figure.

  bench_similarity    — paper §3.2 / Fig. 4 (similarity + layer-wise laziness)
  bench_lazy_tradeoff — paper Tables 1/2/5 (quality vs compute)
  bench_compute       — paper Tables 3/6 (TMACs / compiled-FLOPs vs ratio)
  bench_kernels       — Pallas kernels vs oracles
  bench_roofline      — §Roofline table from dry-run artifacts
  bench_serving       — continuous vs static batching throughput at lazy
                        ratios (emits artifacts/BENCH_serving.json)
  bench_cache_policies — head-to-head skip/reuse policies (repro.cache)
                        on DiT sampling + LLM decode (emits
                        artifacts/BENCH_cache_policies.json)
  bench_trajectory    — fused single-compile DDIM executor vs host loop:
                        compile count, per-step wall-clock, skip ratio
                        (emits artifacts/BENCH_trajectory.json)

Prints ``name,field,...`` CSV rows.  PYTHONPATH=src python -m benchmarks.run

``--smoke`` runs a minutes-not-hours CI path instead of the full suites:
a barely-trained fixture driven end-to-end (train -> lazy-learn -> DDIM
plan-mode sampling -> compiled-HLO FLOP accounting) asserting structure,
not numbers.
"""
import argparse
import sys
import time
import traceback


def smoke() -> list:
    """Fast end-to-end sanity for CI (see .github/workflows/ci.yml)."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks.common import lazy_dit_fixture
    from repro.core import lazy as lazy_lib
    from repro.dist import hlo as hlo_lib
    from repro.models import dit as dit_lib
    from repro.sampling import ddim

    rows = []
    cfg, params, sched = lazy_dit_fixture(pretrain=3, lazy_steps=2)
    labels = jnp.arange(2) % cfg.dit_n_classes
    plan = lazy_lib.uniform_plan(4, cfg.n_layers, 2, 0.5, seed=0)
    x, _ = ddim.ddim_sample(params, cfg, sched, key=jax.random.PRNGKey(0),
                            labels=labels, n_steps=4, lazy_mode="plan",
                            plan=plan.skip)
    assert bool(jnp.all(jnp.isfinite(x))), "plan-mode sampling produced NaNs"
    rows.append(("smoke_sample",
                 "shape=" + "x".join(str(d) for d in x.shape),
                 f"lazy_ratio={plan.lazy_ratio:.2f}"))

    B = 2
    xb = jnp.zeros((B, cfg.dit_input_size, cfg.dit_input_size,
                    cfg.dit_in_channels), jnp.float32)
    t = jnp.zeros((B,), jnp.float32)
    y = jnp.zeros((B,), jnp.int32)
    cache = dit_lib.init_dit_lazy_cache(cfg, B)
    flops = {}
    for ratio in (0.0, 0.5):
        pr = np.zeros((cfg.n_layers, 2), bool)
        pr.reshape(-1)[: int(round(ratio * pr.size))] = True

        def step(x, c, pr=pr):
            out, nc, _ = dit_lib.dit_forward(params, cfg, x, t, y,
                                             lazy_cache=c, lazy_mode="plan",
                                             plan_row=pr)
            return out, nc

        hlo = jax.jit(step).lower(xb, cache).compile().as_text()
        flops[ratio] = hlo_lib.analyze_module(hlo)["flops"]
    saving = 1.0 - flops[0.5] / flops[0.0]
    assert saving > 0.2, f"plan skip removed only {saving:.1%} of HLO flops"
    rows.append(("smoke_hlo", f"base_gflops={flops[0.0] / 1e9:.3f}",
                 f"flop_reduction_at_50pct={saving:.1%}"))

    # serving: continuous vs static batching on a tiny config; emits
    # artifacts/BENCH_serving.json so the bench trajectory populates in CI
    import benchmarks.bench_serving as b_serve
    rows.extend(b_serve.run_smoke())

    # cache policies head-to-head on tiny configs; emits
    # artifacts/BENCH_cache_policies.json (uploaded as a CI artifact)
    import benchmarks.bench_cache_policies as b_cache
    rows.extend(b_cache.run_smoke())

    # fused trajectory executor vs host loop (compile count + wall-clock);
    # emits artifacts/BENCH_trajectory.json
    import benchmarks.bench_trajectory as b_traj
    rows.extend(b_traj.run_smoke())

    # skip-aware kernels: plan-bit wall/bytes acceptance + oracle parity;
    # emits artifacts/BENCH_kernels.json (gated by check_regression)
    import benchmarks.bench_kernels as b_kern
    rows.extend(b_kern.run_smoke())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI sanity path instead of the full suites")
    args = ap.parse_args()
    if args.smoke:
        t0 = time.time()
        print("# === smoke ===", flush=True)
        for row in smoke():
            print(",".join(str(x) for x in row), flush=True)
        print(f"# smoke done in {time.time() - t0:.1f}s", flush=True)
        return

    import benchmarks.bench_similarity as b_sim
    import benchmarks.bench_lazy_tradeoff as b_lazy
    import benchmarks.bench_compute as b_comp
    import benchmarks.bench_kernels as b_kern
    import benchmarks.bench_roofline as b_roof
    import benchmarks.bench_serving as b_serve
    import benchmarks.bench_cache_policies as b_cache
    import benchmarks.bench_trajectory as b_traj

    suites = [("similarity", b_sim), ("lazy_tradeoff", b_lazy),
              ("compute", b_comp), ("kernels", b_kern),
              ("roofline", b_roof), ("serving", b_serve),
              ("cache_policies", b_cache), ("trajectory", b_traj)]
    failed = 0
    for name, mod in suites:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            for row in mod.run():
                if isinstance(row, tuple):
                    print(",".join(str(x) for x in row), flush=True)
                else:
                    print(row, flush=True)
        except Exception:
            failed += 1
            print(f"{name},ERROR", flush=True)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
