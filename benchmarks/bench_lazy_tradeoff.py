"""Paper Tables 1/2/5 analogue: quality-vs-compute trade-off.

The paper's claim: LazyDiT at (N steps, r lazy) beats DDIM at N·(1-r) steps
for equal compute.  No FID here (no ImageNet in container; DESIGN.md §6) —
quality proxy is sample MSE against a 20-step full-compute reference, which
preserves the comparison's *structure*: rows are (sampler, steps, ratio,
relative-TMACs, quality)."""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import lazy_dit_fixture
from repro.core import lazy as lazy_lib
from repro.sampling import ddim


def sample_mse(a, b) -> float:
    return float(jnp.mean((a - b) ** 2))


def run() -> list:
    cfg, params, sched = lazy_dit_fixture()
    labels = jnp.arange(4) % cfg.dit_n_classes
    key = jax.random.PRNGKey(11)

    ref, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                              n_steps=20, lazy_mode="off")

    # calibrate probe scores once (masked run)
    _, aux = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                              n_steps=20, lazy_mode="masked",
                              collect_scores=True)
    sc = np.stack([np.stack([s["attn"], s["ffn"]], -1) for s in aux["scores"]])
    sc_mean = sc.mean(2)

    rows = []
    # DDIM with fewer steps (the baseline the paper compares against)
    for steps in (20, 14, 10, 7):
        x, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                                n_steps=steps, lazy_mode="off")
        rel = steps / 20.0
        rows.append((f"ddim_steps{steps}", f"tmacs={rel:.2f}",
                     f"mse={sample_mse(x, ref):.5f}"))
    # LazyDiT at 20 steps with learned plans at matching compute
    for ratio in (0.3, 0.5, 0.65):
        plan = lazy_lib.plan_with_target_ratio(sc_mean, ratio)
        x, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                                n_steps=20, lazy_mode="plan", plan=plan.skip)
        rel = 1.0 - plan.lazy_ratio
        rows.append((f"lazy20_ratio{int(ratio*100)}", f"tmacs={rel:.2f}",
                     f"mse={sample_mse(x, ref):.5f}"))
    # ablation: learned plan vs random plan at 50% (the probes must matter)
    rand = lazy_lib.uniform_plan(20, cfg.n_layers, 2, 0.5, seed=0)
    x, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                            n_steps=20, lazy_mode="plan", plan=rand.skip)
    rows.append(("random50_ablation", f"tmacs={1 - rand.lazy_ratio:.2f}",
                 f"mse={sample_mse(x, ref):.5f}"))

    # paper Appendix A.3 / Table 7 analogue: Learn2Cache-style INPUT-
    # INDEPENDENT caching — one fixed (step, layer, module) schedule derived
    # from measured cross-step output similarity (no probes, no per-input
    # adaptivity).  LazyDiT's probe plan should match or beat it.
    from repro.core import similarity as sim_lib
    _, aux_t = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                                n_steps=20, lazy_mode="masked",
                                collect_traces=True)
    sims = []
    for mod in ("attn", "ffn"):
        tr = np.stack([t[mod] for t in aux_t["traces"]])       # (T,L,B,N,D)
        s = np.asarray(sim_lib.consecutive_step_similarity(jnp.asarray(tr)))
        sims.append(np.concatenate([np.zeros((1,) + s.shape[1:]), s]).mean(2))
    sim_scores = np.stack(sims, axis=-1)                        # (T, L, 2)
    l2c = lazy_lib.plan_with_target_ratio(sim_scores, 0.5)
    x, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                            n_steps=20, lazy_mode="plan", plan=l2c.skip)
    rows.append(("l2c_style50_input_independent",
                 f"tmacs={1 - l2c.lazy_ratio:.2f}",
                 f"mse={sample_mse(x, ref):.5f}"))

    # paper Fig. 5 (upper) analogue: INDIVIDUAL laziness — skip only MHSA
    # or only Feedforward at the same overall budget; the paper finds
    # module-individual laziness is strictly worse than joint laziness.
    for mod_idx, name in ((0, "attn_only"), (1, "ffn_only")):
        sc_solo = sc_mean.copy()
        sc_solo[:, :, 1 - mod_idx] = -np.inf     # other module never skips
        plan = lazy_lib.plan_with_target_ratio(sc_solo, 0.5)
        x, _ = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                                n_steps=20, lazy_mode="plan", plan=plan.skip)
        rows.append((f"individual_{name}_50",
                     f"tmacs={1 - plan.lazy_ratio:.2f}",
                     f"mse={sample_mse(x, ref):.5f}"))
    return rows
