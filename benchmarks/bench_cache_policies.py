"""Head-to-head cache-policy benchmark (repro.cache).

Runs every built-in skip/reuse policy — none | stride | lazy_gate |
smoothcache | static_router — through BOTH executors on equal footing:

  * DiT sampling: dit_xl2_256 reduced to a tiny trainable config, briefly
    pretrained + lazy-learned in-process (so probe scores and module
    outputs are meaningful), DDIM over T steps.
  * LLM decode: llama3_2_1b reduced, greedy decode through the static
    Engine (the continuous engine serves identical tokens per request —
    tests/test_serving_scheduler.py).

Per (policy, workload) the benchmark reports
  * realized skip ratio (engine/sampler accounting),
  * plan-mode FLOP saving verified on compiled HLO via dist/hlo — the
    trajectory mean over the policy's schedule rows, each row compiled
    with skipped modules absent from the program (lazy_gate, a dynamic
    policy, is distilled into a static plan via core.lazy.plan_from_scores
    first),
  * output drift vs the no-skip baseline (latent MSE / greedy-token
    disagreement fraction).

Assertions (the subsystem's contract):
  * smoothcache and static_router achieve NONZERO compiled FLOP savings
    on both workloads;
  * the `none` policy routes through the policy layer with EXACT parity
    to the legacy off path;
  * the lazy_gate path at zero skip ratio (threshold above the sigmoid
    range) is token/latent-exact against the baseline.

Emits ``artifacts/BENCH_cache_policies.json``.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS
from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.core import lazy as lazy_lib
from repro.data.synthetic import LatentImageDataset
from repro.dist import hlo as hlo_lib
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.cache import schedule as schedule_lib
from repro.sampling import ddim
from repro.serving.engine import Engine, POLICY_PLAN_STEPS
from repro.train import learned as learned_lib
from repro.train import optim, trainer

SCHEMA = "repro.bench.cache_policies/v1"


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def dit_fixture(*, d_model: int, n_layers: int, input_size: int,
                pretrain: int, lazy_steps: int):
    """dit_xl2_256 shrunk to a trainable size, pretrained + lazy-learned
    in-process so skips have signal to act on."""
    cfg = get_config("dit_xl2_256").reduced(
        n_layers=n_layers, d_model=d_model, n_heads=4, n_kv_heads=4,
        head_dim=0, d_ff=2 * d_model, dit_input_size=input_size,
        dit_n_classes=8,
        lazy=LazyConfig(enabled=True, rho_attn=5e-3, rho_ffn=5e-3))
    key = jax.random.PRNGKey(0)
    params = dit_lib.init_dit(key, cfg)
    sched = ddim.linear_schedule(200)
    it = LatentImageDataset(cfg, seed=0).batches(8, seed=1)
    opt = optim.adamw_init(params)
    for _ in range(pretrain):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, _ = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    opt2 = optim.adamw_init(params)
    for _ in range(lazy_steps):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt2, _ = trainer.lazy_train_step(
            params, opt2, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            n_sample_steps=8, lr=1e-2)
    return cfg, params, sched


def lm_fixture(*, d_model: int, n_layers: int):
    """llama3_2_1b reduced; gate probes rescaled to straddle the threshold
    so the dynamic lazy_gate policy actually skips on an untrained LM."""
    from jax.tree_util import tree_flatten_with_path, tree_unflatten

    cfg = get_config("llama3_2_1b").reduced(
        n_layers=n_layers, d_model=d_model, n_heads=2, n_kv_heads=2,
        head_dim=d_model // 2, d_ff=2 * d_model, vocab_size=97,
        lazy=LazyConfig(enabled=True, mode="masked"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    flat, treedef = tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if any(k in ("g_attn", "g_ffn", "g_block") for k in keys):
            leaf = jnp.zeros_like(leaf) if keys[-1] == "b" else leaf * 40.0
        out.append(leaf)
    return cfg, tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# compiled-HLO FLOP accounting (dist/hlo)
# ---------------------------------------------------------------------------


def trajectory_flop_saving(flops_for_row, plan: lazy_lib.LazyPlan) -> float:
    """Mean per-step compiled-FLOP saving over a schedule: each unique row
    compiles once (skipped modules absent from the HLO), weighted by how
    often the schedule serves it."""
    base = flops_for_row(np.zeros(plan.skip.shape[1:], bool))
    memo: Dict[bytes, float] = {}
    tot = 0.0
    for row in plan.skip:
        k = row.tobytes()
        if k not in memo:
            memo[k] = flops_for_row(row)
        tot += memo[k]
    return 1.0 - tot / (len(plan.skip) * base)


def _memoized(fn):
    memo: Dict[bytes, float] = {}

    def wrapped(row):
        row = np.ascontiguousarray(np.asarray(row, bool))
        k = row.tobytes()
        if k not in memo:
            memo[k] = fn(row)
        return memo[k]
    return wrapped


def dit_flops_for_row(cfg, params, batch: int):
    x = jnp.zeros((batch, cfg.dit_input_size, cfg.dit_input_size,
                   cfg.dit_in_channels), jnp.float32)
    t = jnp.zeros((batch,), jnp.float32)
    y = jnp.zeros((batch,), jnp.int32)
    cache = dit_lib.init_dit_lazy_cache(cfg, batch)

    @_memoized
    def fn(row):
        def step(x, c):
            out, nc, _ = dit_lib.dit_forward(params, cfg, x, t, y,
                                             lazy_cache=c, lazy_mode="plan",
                                             plan_row=row)
            return out, nc

        hlo = jax.jit(step).lower(x, cache).compile().as_text()
        return hlo_lib.analyze_module(hlo)["flops"]
    return fn


def lm_flops_for_row(cfg, params, max_len: int = 32):
    cache = tf.init_decode_cache(cfg, 1, max_len)
    lazy = tf.init_lazy_decode_cache(cfg, 1)
    tok = jnp.zeros((1, 1), jnp.int32)

    @_memoized
    def fn(row):
        def step(params, tok, index, cache, lazy):
            return tf.decode_step_unrolled(params, cfg, tok, index, cache,
                                           lazy, plan_step=row)

        hlo = jax.jit(step).lower(params, tok, jnp.int32(4), cache,
                                  lazy).compile().as_text()
        return hlo_lib.analyze_module(hlo)["flops"]
    return fn


# ---------------------------------------------------------------------------
# the head-to-head
# ---------------------------------------------------------------------------


def _policy_set(calib, scores_mean, threshold_q: float, router_ratio: float):
    """The compared policies.  lazy_gate's distilled plan (for compiled
    FLOP accounting of the dynamic policy) rides along."""
    gate = cache_lib.get_policy("lazy_gate")
    return {
        "none": cache_lib.get_policy("none"),
        "stride": cache_lib.get_policy("stride", stride=2),
        "smoothcache": cache_lib.get_policy(
            "smoothcache", calibration=calib,
            error_threshold=calib.quantile_threshold(threshold_q)),
        "static_router": cache_lib.get_policy(
            "static_router", ratio=router_ratio, calibration=calib),
        "lazy_gate": gate,
    }, (gate.distill(scores_mean) if scores_mean is not None else None)


def _learned_policy_set(params, cfg, sched, scores_mean, calib, *, n_steps,
                        gate_ratio, router_ratio, router_steps):
    """The trained-schedule variants (DESIGN.md §Train), each a first-class
    plan-mode policy the fused executor runs like any other:

      learned_gate   — the fixture's lazy-trained probe scores distilled at
                       a target ratio (train/learned's gate pipeline);
      learned_router — per-layer router logits trained by backprop through
                       the relaxed (mix_cached) trajectory, hardened to the
                       per-layer-quota plan;
      learned_delta  — the Δ-DiT-style depth-banded residual cache, placed
                       by the calibration profile (no gradients needed —
                       the calibrated member of the learned column family).
    """
    art_gate = schedule_lib.distill_scores("lazy_gate", cfg.name,
                                           scores_mean,
                                           target_ratio=gate_ratio)
    theta, _ = learned_lib.train_router(params, cfg, sched, n_steps=n_steps,
                                        target_ratio=router_ratio,
                                        steps=router_steps, batch=2, lr=5e-2)
    art_router = learned_lib.distill_router_schedule(
        theta, cfg, target_ratio=router_ratio)
    return {
        "learned_gate": cache_lib.get_policy("learned", artifact=art_gate),
        "learned_router": cache_lib.get_policy("learned",
                                               artifact=art_router),
        "learned_delta": cache_lib.get_policy("delta", ratio=router_ratio,
                                              calibration=calib),
    }


def run_dit(*, d_model=96, n_layers=4, input_size=16, pretrain=40,
            lazy_steps=40, n_steps=12, batch=2, threshold_q=0.5,
            router_ratio=0.5, gate_ratio=0.35, router_steps=8):
    cfg, params, sched = dit_fixture(
        d_model=d_model, n_layers=n_layers, input_size=input_size,
        pretrain=pretrain, lazy_steps=lazy_steps)
    labels = jnp.arange(batch) % cfg.dit_n_classes
    kw = dict(key=jax.random.PRNGKey(7), labels=labels, n_steps=n_steps,
              cfg_scale=1.5)

    ref, _ = ddim.ddim_sample(params, cfg, sched, lazy_mode="off", **kw)
    _, aux = ddim.ddim_sample(params, cfg, sched, lazy_mode="masked",
                              collect_scores=True, **kw)
    sc = np.stack([np.stack([s["attn"], s["ffn"]], -1)
                   for s in aux["scores"]])            # (T, L, B, 2)
    scores_mean = sc.mean(2)
    calib = calibrate_lib.calibrate_dit(params, cfg, sched,
                                        key=jax.random.PRNGKey(7),
                                        labels=labels, n_steps=n_steps,
                                        cfg_scale=1.5)
    policies, gate_plan = _policy_set(calib, scores_mean, threshold_q,
                                      router_ratio)
    policies.update(_learned_policy_set(
        params, cfg, sched, scores_mean, calib, n_steps=n_steps,
        gate_ratio=gate_ratio, router_ratio=router_ratio,
        router_steps=router_steps))
    flops_fn = dit_flops_for_row(cfg, params, 2 * batch)

    out = {}
    for name, pol in policies.items():
        x, paux = ddim.ddim_sample(params, cfg, sched, policy=pol,
                                   collect_scores=(name == "lazy_gate"),
                                   **kw)
        drift = float(jnp.mean((x - ref) ** 2))
        if name == "lazy_gate":
            psc = np.stack([np.stack([s["attn"], s["ffn"]], -1)
                            for s in paux["scores"]])
            ratio = float((psc > pol.threshold).mean())
            plan = gate_plan
        else:
            plan = pol.compile_plan(n_steps, cfg.n_layers, 2)
            ratio = plan.lazy_ratio if plan is not None else 0.0
        saving = trajectory_flop_saving(flops_fn, plan) if plan is not None \
            else 0.0
        out[name] = {"exec_mode": pol.exec_mode,
                     "realized_skip_ratio": round(ratio, 4),
                     "plan_flop_saving": round(saving, 4),
                     "drift_mse": drift,
                     "flop_saving_distilled": name == "lazy_gate"}

    # parity contracts: the policy layer at zero skips is EXACT
    x_none, _ = ddim.ddim_sample(params, cfg, sched, policy="none", **kw)
    assert bool(jnp.array_equal(x_none, ref)), "none-policy != off baseline"
    out["none"]["parity_with_baseline"] = True
    diligent = cache_lib.get_policy("lazy_gate", threshold=1.1)
    x_dg, _ = ddim.ddim_sample(params, cfg, sched, policy=diligent, **kw)
    assert float(jnp.max(jnp.abs(x_dg - ref))) == 0.0, \
        "lazy_gate at zero skip ratio drifted from the baseline"
    out["lazy_gate"]["parity_at_zero_ratio"] = True

    # learned-schedule acceptance (ROADMAP item 2): the trained lazy-gate
    # schedule must deliver a real skip ratio AND place its skips better
    # than the calibrate-then-threshold baseline does at ITS ratio
    lg = out["learned_gate"]
    assert lg["realized_skip_ratio"] >= 0.30, \
        f"learned_gate skip ratio {lg['realized_skip_ratio']} < 0.30"
    assert lg["drift_mse"] < out["smoothcache"]["drift_mse"], \
        (f"learned_gate drift {lg['drift_mse']:.3g} not below smoothcache "
         f"{out['smoothcache']['drift_mse']:.3g}")
    for name in ("learned_gate", "learned_router", "learned_delta"):
        assert out[name]["plan_flop_saving"] > 0.0, \
            f"{name} removed no compiled FLOPs"

    meta = {"arch": "dit_xl2_256", "reduced": {
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "input_size": cfg.dit_input_size}, "n_steps": n_steps,
        "batch": batch, "cfg_scale": 1.5}
    return meta, out


def served_lm_schedule(pol, n_new: int, n_layers: int):
    """The rows Engine actually serves for a static policy: its cyclic
    decode schedule (policy-derived horizon, engine.POLICY_PLAN_STEPS
    default) over ``n_new`` steps, step 0 primed (runs everything) — so
    the FLOP accounting below describes the SAME schedule the realized
    skip ratio was measured on."""
    full = pol.compile_plan(pol.plan_horizon(POLICY_PLAN_STEPS), n_layers, 2)
    if full is None:
        return None
    skip = full.skip[np.arange(n_new) % full.skip.shape[0]].copy()
    skip[0] = False
    return lazy_lib.LazyPlan(skip)


def run_lm(*, d_model=64, n_layers=2, n_new=12, prompt_len=4, threshold_q=0.5,
           router_ratio=0.5):
    cfg, params = lm_fixture(d_model=d_model, n_layers=n_layers)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, prompt_len)).astype(np.int32)
    max_len = prompt_len + n_new + 8

    ref = Engine(cfg, params, max_len=max_len, lazy_mode="off").generate(
        prompt, n_new=n_new)
    calib = calibrate_lib.calibrate_lm(params, cfg, prompt, n_new)
    policies, _ = _policy_set(calib, None, threshold_q, router_ratio)
    flops_fn = lm_flops_for_row(cfg, params, max_len)

    out = {}
    for name, pol in policies.items():
        res = Engine(cfg, params, max_len=max_len, policy=pol).generate(
            prompt, n_new=n_new)
        gen_ref = ref.tokens[:, prompt_len:]
        gen = res.tokens[:, prompt_len:]
        disagreement = float((gen != gen_ref).mean())
        if name == "lazy_gate":
            # distill the realized masked-mode scores (layer-averaged
            # attn/ffn means; the 'block' column is unused on attn_ffn
            # stacks) into a static plan for compiled FLOP accounting
            plan = (pol.distill(
                np.repeat(res.scores[:, None, :2], cfg.n_layers, axis=1))
                if res.scores is not None else None)
        else:
            plan = served_lm_schedule(pol, n_new, cfg.n_layers)
        ratio = res.realized_lazy_ratio
        saving = trajectory_flop_saving(flops_fn, plan) if plan is not None \
            else 0.0
        out[name] = {"exec_mode": pol.exec_mode,
                     "realized_skip_ratio": round(float(ratio), 4),
                     "plan_flop_saving": round(saving, 4),
                     "token_disagreement": disagreement,
                     "flop_saving_distilled": name == "lazy_gate"}

    res_none = Engine(cfg, params, max_len=max_len, policy="none").generate(
        prompt, n_new=n_new)
    assert np.array_equal(res_none.tokens, ref.tokens), \
        "none-policy tokens != off baseline"
    out["none"]["parity_with_baseline"] = True
    diligent = cache_lib.get_policy("lazy_gate", threshold=1.1)
    res_dg = Engine(cfg, params, max_len=max_len, policy=diligent).generate(
        prompt, n_new=n_new)
    assert np.array_equal(res_dg.tokens, ref.tokens), \
        "lazy_gate at zero skip ratio changed greedy tokens"
    assert res_dg.realized_lazy_ratio == 0.0
    out["lazy_gate"]["parity_at_zero_ratio"] = True

    meta = {"arch": "llama3_2_1b", "reduced": {
        "n_layers": cfg.n_layers, "d_model": cfg.d_model},
        "n_new": n_new, "prompt_len": prompt_len}
    return meta, out


def run_bench(*, smoke: bool = False):
    if smoke:
        # pretrain/lazy_steps large enough that the probes RANK safety:
        # on a near-random trunk the scores track activation magnitude
        # (highest on the noisy early steps — exactly where caching hurts)
        # and the learned_gate acceptance below would compare garbage
        dit_meta, dit_res = run_dit(d_model=64, n_layers=3, input_size=16,
                                    pretrain=16, lazy_steps=64, n_steps=6,
                                    router_steps=4)
        lm_meta, lm_res = run_lm(d_model=32, n_layers=2, n_new=8)
    else:
        dit_meta, dit_res = run_dit()
        lm_meta, lm_res = run_lm()

    for wl, res in (("dit", dit_res), ("lm", lm_res)):
        for must in ("smoothcache", "static_router"):
            s = res[must]["plan_flop_saving"]
            assert s > 0.0, f"{must} removed no compiled FLOPs on {wl}"
        assert res["none"]["plan_flop_saving"] == 0.0
        assert res["none"]["realized_skip_ratio"] == 0.0

    payload = {
        "schema": SCHEMA,
        "smoke": smoke,
        "flop_accounting": "dist/hlo analyze_module over per-row compiled "
                           "HLO (skipped modules absent); trajectory mean "
                           "over the policy schedule",
        "workloads": {
            "dit_xl2_256": {**dit_meta, "policies": dit_res},
            "llama3_2_1b": {**lm_meta, "policies": lm_res},
        },
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS,
                                         "BENCH_cache_policies.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

    rows = []
    for wl, res in (("dit_xl2_256", dit_res), ("llama3_2_1b", lm_res)):
        drift_key = "drift_mse" if wl.startswith("dit") else \
            "token_disagreement"
        for name, r in sorted(res.items()):
            rows.append(("cache_policies", wl, name,
                         f"ratio={r['realized_skip_ratio']:.2f}",
                         f"flop_saving={r['plan_flop_saving']:.2%}",
                         f"{drift_key}={r[drift_key]:.3g}"))
    rows.append(("cache_policies", "json", path))
    return rows, payload


def run():
    """Full-suite entry (benchmarks.run)."""
    rows, _ = run_bench(smoke=False)
    return rows


def run_smoke():
    """CI smoke entry: tiny fixtures, same assertions, same artifact."""
    rows, _ = run_bench(smoke=True)
    return rows
