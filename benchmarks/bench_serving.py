"""Serving throughput: static-batch vs continuous-batch at lazy ratios.

Runs the same deterministic mixed-length Poisson trace
(data/synthetic.request_trace) through the continuous-batching engine and
its batch-synchronous (static batching) degradation, at uniform lazy-plan
ratios 0 / 0.3 / 0.5, and emits ``artifacts/BENCH_serving.json`` with
requests/sec, tokens/sec, and p50/p95 latency per cell.

Throughput is accounted on the *service clock* (serving/metrics.py): the
virtual-time model that charges only executed gated-module calls, i.e. the
request-level projection of the compiled-HLO savings bench_compute
measures.  Host wall-clock on this CPU container says nothing about served
throughput and is not reported.

A second table (``per_policy``) reruns the same trace per cache policy
with obs telemetry on: goodput-under-SLO and the serving-side
cached-vs-fresh drift means (repro.obs.slot_cache_drift) join the gated
baselines — drift is the quality-proxy column, so a policy change that
silently serves staler caches trips the regression gate.

A third table (``overload``) is the front-door sweep: the SLO-class
Poisson trace (data/synthetic.slo_request_trace) offered at 0.5x / 1x /
2x / 4x of the pool's estimated capacity, served by three fixed-policy
engines (none / stride / static_router — one policy pinned for every
request) and by the SLO-aware server (policy bank + admission control +
priority preemption, serving/admission.py).  Goodput counts a request
only if it met its OWN declared deadline AND its assigned skip ratio fit
its OWN quality budget, so a fixed policy loses one side or the other:
diligent `none` blows latency-class deadlines under load, a pinned
high-skip plan fails the quality class's budget outright.  The sweep
asserts the SLO-aware server's goodput strictly beats every fixed policy
at >= 2x offered load (the knee), and the per-load goodput/attainment
cells are regression-gated (benchmarks/check_regression.py).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import ARTIFACTS
from repro import cache as cache_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import request_trace, slo_request_trace
from repro.models import transformer as tf
from repro.serving import metrics as metrics_lib
from repro.serving.admission import (AdmissionController,
                                     default_policy_bank, trace_slo_stats)
from repro.serving.engine import ContinuousBatchingEngine

SCHEMA = "repro.bench.serving/v1"

RATIOS = (0.0, 0.3, 0.5)
PLAN_STEPS = 16

# telemetry-on per-policy cells: the none baseline (drift NaN — no lazy
# cache to drift), the training-free stride floor, and the L2C-shaped
# seeded router
POLICY_CELLS = ("none", "stride", "static_router")


def _cell_policy(name: str, seed: int):
    if name == "none":
        return cache_lib.get_policy("none")
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=0.5, seed=seed)
    raise ValueError(name)


# overload sweep: offered load as a multiple of the pool's estimated
# capacity (1.0 == arrivals match what a diligent pool can absorb)
OVERLOAD_LOADS = (0.5, 1.0, 2.0, 4.0)
OVERLOAD_FIXED = ("none", "stride", "static_router")
SLO_AWARE = "slo_aware"


def capacity_interarrival(trace, n_slots: int) -> float:
    """Virtual seconds per request a diligent pool can absorb: the serial
    prefill charge plus the per-token decode share (a full-pool no-skip
    step costs 1.0 virtual s and advances every slot one token)."""
    pre = float(np.mean([metrics_lib.prefill_cost(len(r.prompt), n_slots)
                         for r in trace]))
    dec = float(np.mean([r.max_new for r in trace])) / n_slots
    return pre + dec


def _overload_engines(cfg, params, n_slots: int, max_len: int, seed: int):
    """{server name: engine factory}; a fresh engine per cell so slot
    caches and jit state never leak across loads."""
    def fixed(name):
        return ContinuousBatchingEngine(
            cfg, params, n_slots=n_slots, max_len=max_len,
            policy=_cell_policy(name, seed))

    servers = {f"fixed:{n}": (lambda n=n: fixed(n)) for n in OVERLOAD_FIXED}
    servers[SLO_AWARE] = lambda: ContinuousBatchingEngine(
        cfg, params, n_slots=n_slots, max_len=max_len,
        policy_bank=default_policy_bank(lazy_ratio=0.5, seed=seed),
        admission=AdmissionController())
    return servers


def run_overload(cfg, params, *, n_slots: int, n_requests: int = 24,
                 seed: int = 0, loads=OVERLOAD_LOADS):
    """The offered-load sweep -> (rows, section dict for the payload).

    Every server sees the SAME SLO-class trace at each load (seeded;
    changing the interarrival scale rescales arrivals without reshuffling
    prompts, outputs, or class draws), so the goodput columns differ only
    by policy selection, shedding, and preemption.  The trace must be
    long enough for queues to actually build — on a short burst every
    server drains its backlog before latency-class deadlines bite and
    shedding only loses requests; 24+ keeps the knee visible."""
    probe = slo_request_trace(n_requests, cfg.vocab_size, seed=seed,
                              short_prompt=(4, 4), long_prompt=(10, 10),
                              short_output=(3, 6), long_output=(8, 14))
    mi_capacity = capacity_interarrival(probe, n_slots)
    section = {
        "mi_capacity": mi_capacity,
        "class_mix": trace_slo_stats(probe),
        "loads": {},
    }
    rows = []
    for load in loads:
        mi = mi_capacity / load
        trace = slo_request_trace(n_requests, cfg.vocab_size, seed=seed,
                                  mean_interarrival=mi,
                                  short_prompt=(4, 4), long_prompt=(10, 10),
                                  short_output=(3, 6), long_output=(8, 14))
        max_len = max(len(r.prompt) + r.max_new for r in trace) + 4
        cells = {}
        for name, make in _overload_engines(cfg, params, n_slots, max_len,
                                            seed).items():
            s = make().run(trace).metrics.summary()
            cells[name] = {
                "goodput_per_s": s["goodput_per_s"],
                "requests_per_s": s["requests_per_s"],
                "slo_attainment": s["slo_attainment"],
                "n_shed": s["n_shed"],
                "n_preemptions": s["n_preemptions"],
            }
            rows.append(("serving", "overload", f"load={load}x", name,
                         f"goodput={s['goodput_per_s']:.3f}/s",
                         f"slo_att={s['slo_attainment']:.2f}",
                         f"shed={s['n_shed']}",
                         f"preempt={s['n_preemptions']}"))
        section["loads"][f"load_{load}x"] = {
            "offered_load": load,
            "mean_interarrival": mi,
            "servers": cells,
        }
        # the acceptance knee: once offered load is at or past 2x
        # capacity, per-request policy selection must strictly beat every
        # one-policy-for-all server on goodput-under-SLO
        if load >= 2.0:
            best_fixed = max(cells[f"fixed:{n}"]["goodput_per_s"]
                             for n in OVERLOAD_FIXED)
            aware = cells[SLO_AWARE]["goodput_per_s"]
            assert aware > best_fixed, (
                f"SLO-aware goodput {aware:.3f}/s does not beat the best "
                f"fixed policy ({best_fixed:.3f}/s) at {load}x load")
            if load == 2.0:
                section["advantage_at_2x"] = aware / max(best_fixed, 1e-9)
    return rows, section


def _cfg(n_layers: int, d_model: int) -> ModelConfig:
    return ModelConfig(
        name="serve-bench", n_layers=n_layers, d_model=d_model, n_heads=4,
        n_kv_heads=2, head_dim=d_model // 4, d_ff=2 * d_model, vocab_size=97,
        dtype="float32", lazy=LazyConfig(enabled=True, mode="plan"))


def run_serving(*, n_layers: int = 4, d_model: int = 64, n_slots: int = 4,
                n_requests: int = 16, overload_requests: int = 24,
                seed: int = 0):
    """Returns (csv_rows, payload) and writes BENCH_serving.json."""
    cfg = _cfg(n_layers, d_model)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    # two prompt-length buckets keep the prefill retrace count bounded while
    # still mixing short/long prompts and outputs
    trace = request_trace(n_requests, cfg.vocab_size, seed=seed,
                          mean_interarrival=0.3,
                          short_prompt=(4, 4), long_prompt=(10, 10),
                          short_output=(3, 6), long_output=(8, 14))
    max_len = max(len(r.prompt) + r.max_new for r in trace) + 4

    results = {"continuous": {}, "static": {}}
    rows = []
    for ratio in RATIOS:
        plan = lazy_lib.uniform_plan(PLAN_STEPS, cfg.n_layers, 2, ratio,
                                     seed=1)
        for policy, sync in (("continuous", False), ("static", True)):
            eng = ContinuousBatchingEngine(
                cfg, params, n_slots=n_slots, max_len=max_len,
                lazy_mode="plan", plan=plan, batch_synchronous=sync)
            s = eng.run(trace).metrics.summary()
            results[policy][f"ratio_{ratio}"] = s
            rows.append(("serving", policy, f"lazy_ratio={ratio}",
                         f"req_per_s={s['requests_per_s']:.3f}",
                         f"tok_per_s={s['tokens_per_s']:.2f}",
                         f"lat_p50={s['latency_p50_s']:.2f}",
                         f"lat_p95={s['latency_p95_s']:.2f}",
                         f"realized_lazy={s['realized_lazy_ratio']:.2f}"))

    for ratio in RATIOS:
        c = results["continuous"][f"ratio_{ratio}"]["requests_per_s"]
        st = results["static"][f"ratio_{ratio}"]["requests_per_s"]
        assert c >= st - 1e-9, \
            f"continuous ({c:.3f}) < static ({st:.3f}) at ratio {ratio}"
    lo = results["continuous"]["ratio_0.0"]["requests_per_s"]
    hi = results["continuous"]["ratio_0.5"]["requests_per_s"]
    assert hi > lo, f"lazy 0.5 ({hi:.3f}) not faster than 0.0 ({lo:.3f})"

    # telemetry-on per-policy cells: drift + goodput columns (repro.obs)
    per_policy = {}
    for name in POLICY_CELLS:
        eng = ContinuousBatchingEngine(
            cfg, params, n_slots=n_slots, max_len=max_len,
            policy=_cell_policy(name, seed), telemetry=True)
        s = eng.run(trace).metrics.summary()
        per_policy[name] = {
            "requests_per_s": s["requests_per_s"],
            "goodput_per_s": s["goodput_per_s"],
            "realized_lazy_ratio": s["realized_lazy_ratio"],
            "drift_rel_l2_mean": s["drift_rel_l2_mean"],
            "drift_cos_mean": s["drift_cos_mean"],
            # phase decomposition: queue + prefill + decode == latency
            # per request (ServingMetrics.record_admit)
            "queue_p50_s": s["queue_p50_s"],
            "queue_p95_s": s["queue_p95_s"],
            "prefill_p50_s": s["prefill_p50_s"],
            "prefill_p95_s": s["prefill_p95_s"],
            "decode_p50_s": s["decode_p50_s"],
            "decode_p95_s": s["decode_p95_s"],
        }
        rows.append(("serving", "policy", name,
                     f"goodput={s['goodput_per_s']:.3f}/s",
                     f"drift_rel_l2={s['drift_rel_l2_mean']:.4f}",
                     f"realized_lazy={s['realized_lazy_ratio']:.2f}",
                     f"queue_p50={s['queue_p50_s']:.2f}",
                     f"prefill_p50={s['prefill_p50_s']:.2f}",
                     f"decode_p50={s['decode_p50_s']:.2f}"))

    overload_rows, overload = run_overload(
        cfg, params, n_slots=n_slots, n_requests=overload_requests,
        seed=seed)
    rows.extend(overload_rows)

    payload = {
        "schema": SCHEMA,
        "model": {"n_layers": n_layers, "d_model": d_model},
        "n_slots": n_slots,
        "n_requests": n_requests,
        "seed": seed,
        "clock": "virtual service clock (serving/metrics.py): "
                 "executed gated-module calls + fixed step overhead",
        "results": results,
        "per_policy": per_policy,
        "overload": overload,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS, "BENCH_serving.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    rows.append(("serving", "json", path))
    return rows, payload


def run():
    """Full-suite entry (benchmarks.run)."""
    rows, _ = run_serving()
    return rows


def run_smoke():
    """CI smoke entry: tiny config, same assertions, same JSON artifact."""
    rows, _ = run_serving(n_layers=2, d_model=32, n_slots=2, n_requests=8)
    return rows
