"""Serving throughput: static-batch vs continuous-batch at lazy ratios.

Runs the same deterministic mixed-length Poisson trace
(data/synthetic.request_trace) through the continuous-batching engine and
its batch-synchronous (static batching) degradation, at uniform lazy-plan
ratios 0 / 0.3 / 0.5, and emits ``artifacts/BENCH_serving.json`` with
requests/sec, tokens/sec, and p50/p95 latency per cell.

Throughput is accounted on the *service clock* (serving/metrics.py): the
virtual-time model that charges only executed gated-module calls, i.e. the
request-level projection of the compiled-HLO savings bench_compute
measures.  Host wall-clock on this CPU container says nothing about served
throughput and is not reported.

A second table (``per_policy``) reruns the same trace per cache policy
with obs telemetry on: goodput-under-SLO and the serving-side
cached-vs-fresh drift means (repro.obs.slot_cache_drift) join the gated
baselines — drift is the quality-proxy column, so a policy change that
silently serves staler caches trips the regression gate.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import ARTIFACTS
from repro import cache as cache_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import request_trace
from repro.models import transformer as tf
from repro.serving.engine import ContinuousBatchingEngine

SCHEMA = "repro.bench.serving/v1"

RATIOS = (0.0, 0.3, 0.5)
PLAN_STEPS = 16

# telemetry-on per-policy cells: the none baseline (drift NaN — no lazy
# cache to drift), the training-free stride floor, and the L2C-shaped
# seeded router
POLICY_CELLS = ("none", "stride", "static_router")


def _cell_policy(name: str, seed: int):
    if name == "none":
        return cache_lib.get_policy("none")
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=0.5, seed=seed)
    raise ValueError(name)


def _cfg(n_layers: int, d_model: int) -> ModelConfig:
    return ModelConfig(
        name="serve-bench", n_layers=n_layers, d_model=d_model, n_heads=4,
        n_kv_heads=2, head_dim=d_model // 4, d_ff=2 * d_model, vocab_size=97,
        dtype="float32", lazy=LazyConfig(enabled=True, mode="plan"))


def run_serving(*, n_layers: int = 4, d_model: int = 64, n_slots: int = 4,
                n_requests: int = 16, seed: int = 0):
    """Returns (csv_rows, payload) and writes BENCH_serving.json."""
    cfg = _cfg(n_layers, d_model)
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    # two prompt-length buckets keep the prefill retrace count bounded while
    # still mixing short/long prompts and outputs
    trace = request_trace(n_requests, cfg.vocab_size, seed=seed,
                          mean_interarrival=0.3,
                          short_prompt=(4, 4), long_prompt=(10, 10),
                          short_output=(3, 6), long_output=(8, 14))
    max_len = max(len(r.prompt) + r.max_new for r in trace) + 4

    results = {"continuous": {}, "static": {}}
    rows = []
    for ratio in RATIOS:
        plan = lazy_lib.uniform_plan(PLAN_STEPS, cfg.n_layers, 2, ratio,
                                     seed=1)
        for policy, sync in (("continuous", False), ("static", True)):
            eng = ContinuousBatchingEngine(
                cfg, params, n_slots=n_slots, max_len=max_len,
                lazy_mode="plan", plan=plan, batch_synchronous=sync)
            s = eng.run(trace).metrics.summary()
            results[policy][f"ratio_{ratio}"] = s
            rows.append(("serving", policy, f"lazy_ratio={ratio}",
                         f"req_per_s={s['requests_per_s']:.3f}",
                         f"tok_per_s={s['tokens_per_s']:.2f}",
                         f"lat_p50={s['latency_p50_s']:.2f}",
                         f"lat_p95={s['latency_p95_s']:.2f}",
                         f"realized_lazy={s['realized_lazy_ratio']:.2f}"))

    for ratio in RATIOS:
        c = results["continuous"][f"ratio_{ratio}"]["requests_per_s"]
        st = results["static"][f"ratio_{ratio}"]["requests_per_s"]
        assert c >= st - 1e-9, \
            f"continuous ({c:.3f}) < static ({st:.3f}) at ratio {ratio}"
    lo = results["continuous"]["ratio_0.0"]["requests_per_s"]
    hi = results["continuous"]["ratio_0.5"]["requests_per_s"]
    assert hi > lo, f"lazy 0.5 ({hi:.3f}) not faster than 0.0 ({lo:.3f})"

    # telemetry-on per-policy cells: drift + goodput columns (repro.obs)
    per_policy = {}
    for name in POLICY_CELLS:
        eng = ContinuousBatchingEngine(
            cfg, params, n_slots=n_slots, max_len=max_len,
            policy=_cell_policy(name, seed), telemetry=True)
        s = eng.run(trace).metrics.summary()
        per_policy[name] = {
            "requests_per_s": s["requests_per_s"],
            "goodput_per_s": s["goodput_per_s"],
            "realized_lazy_ratio": s["realized_lazy_ratio"],
            "drift_rel_l2_mean": s["drift_rel_l2_mean"],
            "drift_cos_mean": s["drift_cos_mean"],
            # phase decomposition: queue + prefill + decode == latency
            # per request (ServingMetrics.record_admit)
            "queue_p50_s": s["queue_p50_s"],
            "queue_p95_s": s["queue_p95_s"],
            "prefill_p50_s": s["prefill_p50_s"],
            "prefill_p95_s": s["prefill_p95_s"],
            "decode_p50_s": s["decode_p50_s"],
            "decode_p95_s": s["decode_p95_s"],
        }
        rows.append(("serving", "policy", name,
                     f"goodput={s['goodput_per_s']:.3f}/s",
                     f"drift_rel_l2={s['drift_rel_l2_mean']:.4f}",
                     f"realized_lazy={s['realized_lazy_ratio']:.2f}",
                     f"queue_p50={s['queue_p50_s']:.2f}",
                     f"prefill_p50={s['prefill_p50_s']:.2f}",
                     f"decode_p50={s['decode_p50_s']:.2f}"))

    payload = {
        "schema": SCHEMA,
        "model": {"n_layers": n_layers, "d_model": d_model},
        "n_slots": n_slots,
        "n_requests": n_requests,
        "seed": seed,
        "clock": "virtual service clock (serving/metrics.py): "
                 "executed gated-module calls + fixed step overhead",
        "results": results,
        "per_policy": per_policy,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS, "BENCH_serving.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    rows.append(("serving", "json", path))
    return rows, payload


def run():
    """Full-suite entry (benchmarks.run)."""
    rows, _ = run_serving()
    return rows


def run_smoke():
    """CI smoke entry: tiny config, same assertions, same JSON artifact."""
    rows, _ = run_serving(n_layers=2, d_model=32, n_slots=2, n_requests=8)
    return rows
