"""Benchmark regression gate (CI `bench-smoke` job).

Compares the freshly produced ``artifacts/BENCH_*.json`` smoke artifacts
against the committed baselines in ``benchmarks/baselines/`` and fails when a
gated metric regresses by more than the tolerance.  Gated metrics are the
machine-independent ones — realized skip ratios and compiled-FLOP savings are
plan/HLO-derived, so a drop means a real behavior change, never runner noise;
wall-clock and speedup numbers are deliberately NOT gated.

Tolerances live HERE, not in the workflow: CI invokes the script bare, so
loosening a gate is a reviewed code change.

    python -m benchmarks.check_regression               # gate (CI step)
    python -m benchmarks.check_regression --update      # refresh baselines
    python -m benchmarks.check_regression --self-test   # prove the gate bites
"""

from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from pathlib import Path

# A gated metric may move against its better-direction by at most this
# fraction of its baseline value before the gate fails (a drop for
# higher-is-better metrics, a rise for lower-is-better ones).
RELATIVE_DROP_TOLERANCE = 0.05

# Baselines at or below this are treated as "legitimately zero" (e.g. the
# `none` policy's skip ratio) and gate nothing.
ZERO_FLOOR = 1e-9

# Metric names ending with one of these gate in the LOWER-is-better
# direction (serving drift: staler served caches are worse).
LOWER_IS_BETTER_SUFFIXES = ("drift_rel_l2_mean",)

GATED_FILES = (
    "BENCH_trajectory.json",
    "BENCH_cache_policies.json",
    "BENCH_serving.json",
)

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_BASELINE_DIR = REPO_ROOT / "benchmarks" / "baselines"
DEFAULT_CURRENT_DIR = REPO_ROOT / "artifacts"


def is_lower_better(metric: str) -> bool:
    return metric.endswith(LOWER_IS_BETTER_SUFFIXES)


def collect_metrics(payload: dict) -> dict[str, float]:
    """Flatten one BENCH_*.json payload into {metric_path: value} for every
    gated, machine-independent metric (direction per is_lower_better)."""
    metrics: dict[str, float] = {}
    schema = str(payload.get("schema", ""))
    if schema.startswith("repro.bench.trajectory"):
        for name, row in payload.get("policies", {}).items():
            key = f"trajectory/{name}/realized_skip_ratio"
            metrics[key] = float(row["realized_skip_ratio"])
    if schema.startswith("repro.bench.cache_policies"):
        for workload, data in payload.get("workloads", {}).items():
            for name, row in data.get("policies", {}).items():
                for field in ("realized_skip_ratio", "plan_flop_saving"):
                    if field in row:
                        key = f"cache_policies/{workload}/{name}/{field}"
                        metrics[key] = float(row[field])
    if schema.startswith("repro.bench.serving"):
        for name, row in payload.get("per_policy", {}).items():
            for field in (
                "goodput_per_s",
                "requests_per_s",
                "realized_lazy_ratio",
                "drift_rel_l2_mean",
                "drift_cos_mean",
            ):
                if field in row:
                    metrics[f"serving/{name}/{field}"] = float(row[field])
    return metrics


def compare(
    baseline: dict[str, float],
    current: dict[str, float],
    tolerance: float = RELATIVE_DROP_TOLERANCE,
) -> list[str]:
    """Failure messages for every gated metric that regressed past the
    tolerance or vanished; metrics with no baseline are informational only.

    NaN on either side means "no data for this metric in that run" (e.g.
    drift of a policy serving no lazy cache, percentiles of a run with no
    completions) — such metrics are skipped, never treated as zero or as
    a regression."""
    failures = []
    for metric in sorted(baseline):
        base = baseline[metric]
        cur = current.get(metric)
        if math.isnan(base) or (cur is not None and math.isnan(cur)):
            continue
        if base <= ZERO_FLOOR:
            continue
        if cur is None:
            failures.append(
                f"{metric}: present in baseline ({base:.4f}) but missing "
                "from the current artifacts"
            )
            continue
        if is_lower_better(metric):
            if cur > base * (1.0 + tolerance):
                rise = cur / base - 1.0
                failures.append(
                    f"{metric}: {base:.4f} -> {cur:.4f} ({rise:.1%} rise "
                    f"exceeds the {tolerance:.0%} tolerance; lower is "
                    "better)"
                )
        elif cur < base * (1.0 - tolerance):
            drop = 1.0 - cur / base
            failures.append(
                f"{metric}: {base:.4f} -> {cur:.4f} ({drop:.1%} drop "
                f"exceeds the {tolerance:.0%} tolerance)"
            )
    return failures


def load_metrics(directory: Path) -> dict[str, float]:
    metrics: dict[str, float] = {}
    for name in GATED_FILES:
        path = directory / name
        if not path.is_file():
            continue
        with open(path) as f:
            metrics.update(collect_metrics(json.load(f)))
    return metrics


def update_baselines(current_dir: Path, baseline_dir: Path) -> list[str]:
    baseline_dir.mkdir(parents=True, exist_ok=True)
    copied = []
    for name in GATED_FILES:
        src = current_dir / name
        if src.is_file():
            shutil.copyfile(src, baseline_dir / name)
            copied.append(name)
    return copied


def self_test(current_dir: Path) -> int:
    """Prove the gate bites: a synthetic baseline perturbed >5% against
    every gated metric's better-direction MUST fail (inflated for
    higher-is-better metrics, deflated for lower-is-better ones), and the
    artifacts compared against themselves MUST pass.  NaN metrics carry
    no data and are excluded from the perturbation."""
    current = load_metrics(current_dir)
    if not current:
        print(
            f"self-test: no gated artifacts under {current_dir} "
            "(run `python -m benchmarks.run --smoke` first)"
        )
        return 1
    perturbed = {
        k: (v * 0.75 if is_lower_better(k) else v * 1.25)
        for k, v in current.items()
        if v > ZERO_FLOOR and not math.isnan(v)
    }
    if not perturbed:
        print("self-test: every gated metric is zero; nothing to perturb")
        return 1
    injected = compare(perturbed, current)
    clean = compare(current, current)
    print(
        f"self-test: {len(current)} gated metrics; injected regression "
        f"flagged {len(injected)}/{len(perturbed)} perturbed baselines; "
        f"clean comparison flagged {len(clean)}"
    )
    if len(injected) != len(perturbed) or clean:
        print("self-test FAILED: the gate does not bite")
        return 1
    print("self-test OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, default=DEFAULT_BASELINE_DIR)
    ap.add_argument("--current-dir", type=Path, default=DEFAULT_CURRENT_DIR)
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the current artifacts over the committed baselines",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="verify the gate fails on an injected >5%% regression",
    )
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.current_dir)
    if args.update:
        copied = update_baselines(args.current_dir, args.baseline_dir)
        print(
            f"baselines updated in {args.baseline_dir}: "
            f"{', '.join(copied) or 'nothing found'}"
        )
        return 0

    baseline = load_metrics(args.baseline_dir)
    if not baseline:
        print(
            f"no baselines under {args.baseline_dir}; run with --update "
            "after a smoke pass to create them"
        )
        return 1
    current = load_metrics(args.current_dir)
    failures = compare(baseline, current)
    gated = sum(1 for v in baseline.values() if v > ZERO_FLOOR)
    if failures:
        print(
            f"BENCHMARK REGRESSION: {len(failures)} of {gated} gated "
            "metrics regressed"
        )
        for line in failures:
            print(f"  {line}")
        return 1
    print(
        f"benchmark gate OK: {gated} gated metrics within "
        f"{RELATIVE_DROP_TOLERANCE:.0%} of baseline "
        f"({len(baseline)} tracked)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
