"""Benchmark regression gate (CI `bench-smoke` job).

Compares the freshly produced ``artifacts/BENCH_*.json`` /
``artifacts/PERF_*.json`` smoke artifacts against the committed baselines in
``benchmarks/baselines/`` and fails when a gated metric regresses by more
than the tolerance.  Most gated metrics are the machine-independent ones —
realized skip ratios and compiled-FLOP savings are plan/HLO-derived, so a
drop means a real behavior change, never runner noise.

Wall-clock joins the gate noise-aware (PERF_trajectory.json): each perf
metric ships its MAD sibling (``<field>_mad``), and the tolerance for perf
metrics widens by ``PERF_MAD_SIGMAS`` robust sigmas of combined baseline +
current noise — a same-machine MAD-sized wobble passes, a structural
slowdown does not.  ``speedup_vs_host`` is a same-run ratio and therefore
machine-independent (gated at PERF_REL_TOLERANCE); the absolute
``wall_ms_median`` is machine-DEPENDENT, so its floor is the catastrophic
WALL_ABS_TOLERANCE — it exists to catch a fused executor silently falling
back to per-step dispatch (~10x), not a slower runner.

The kernel bench (BENCH_kernels.json) gates the same way: its wall ratios
(skip-on vs where-select speedups) ride the perf floors + MAD widening via
the ``perf/`` prefix, while bytes-saving fraction, plan skip ratio, and
the bit-exactness/parity flags are machine-independent and use the
default tolerance.

Tolerances live HERE, not in the workflow: CI invokes the script bare, so
loosening a gate is a reviewed code change.

    python -m benchmarks.check_regression               # gate (CI step)
    python -m benchmarks.check_regression --update      # refresh baselines
    python -m benchmarks.check_regression --self-test   # prove the gate bites
"""

from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from pathlib import Path

# A gated metric may move against its better-direction by at most this
# fraction of its baseline value before the gate fails (a drop for
# higher-is-better metrics, a rise for lower-is-better ones).
RELATIVE_DROP_TOLERANCE = 0.05

# Baselines at or below this are treated as "legitimately zero" (e.g. the
# `none` policy's skip ratio) and gate nothing.
ZERO_FLOOR = 1e-9

# Metric names ending with one of these gate in the LOWER-is-better
# direction (serving drift: staler served caches are worse; wall-clock:
# slower is worse).
LOWER_IS_BETTER_SUFFIXES = ("drift_rel_l2_mean", "wall_ms_median")

# Perf metrics (repro.bench.perf payloads) use these relative floors
# instead of RELATIVE_DROP_TOLERANCE, widened by the MAD noise channel.
# speedup_vs_host is a ratio of two measurements from the SAME run on the
# SAME machine, so it transfers across runners; wall_ms_median does not,
# and its floor only catches catastrophic (~2x+) structural slowdowns.
PERF_REL_TOLERANCE = 0.35
WALL_ABS_TOLERANCE = 1.00

# Noise widening: a perf metric's tolerance grows by this many robust
# sigmas of (baseline MAD + current MAD) / baseline.
PERF_MAD_SIGMAS = 4.0

# Perf payload fields that gate (each also ships a `<field>_mad` sibling
# feeding collect_noise).
PERF_GATED_FIELDS = ("wall_ms_median", "speedup_vs_host")

# Kernel-bench wall ratios that gate with the perf floors + MAD widening
# (each ships a `<field>_mad` sibling): both are same-run ratios on the
# same machine, so they transfer across runners like speedup_vs_host.
KERNEL_PERF_FIELDS = ("skip_speedup_vs_select", "blended_speedup_at_plan")

GATED_FILES = (
    "BENCH_trajectory.json",
    "BENCH_cache_policies.json",
    "BENCH_serving.json",
    "BENCH_kernels.json",
    "PERF_trajectory.json",
)

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_BASELINE_DIR = REPO_ROOT / "benchmarks" / "baselines"
DEFAULT_CURRENT_DIR = REPO_ROOT / "artifacts"


def is_lower_better(metric: str) -> bool:
    return metric.endswith(LOWER_IS_BETTER_SUFFIXES)


def metric_tolerance(metric: str, default: float) -> float:
    """Relative floor for one metric before noise widening: perf metrics
    carry their own floors (see module constants); everything else uses the
    caller's default."""
    if metric.startswith("perf/"):
        if metric.endswith("wall_ms_median"):
            return WALL_ABS_TOLERANCE
        return PERF_REL_TOLERANCE
    return default


def collect_metrics(payload: dict) -> dict[str, float]:
    """Flatten one BENCH_*.json payload into {metric_path: value} for every
    gated, machine-independent metric (direction per is_lower_better)."""
    metrics: dict[str, float] = {}
    schema = str(payload.get("schema", ""))
    if schema.startswith("repro.bench.trajectory"):
        for name, row in payload.get("policies", {}).items():
            key = f"trajectory/{name}/realized_skip_ratio"
            metrics[key] = float(row["realized_skip_ratio"])
    if schema.startswith("repro.bench.cache_policies"):
        for workload, data in payload.get("workloads", {}).items():
            for name, row in data.get("policies", {}).items():
                for field in ("realized_skip_ratio", "plan_flop_saving"):
                    if field in row:
                        key = f"cache_policies/{workload}/{name}/{field}"
                        metrics[key] = float(row[field])
    if schema.startswith("repro.bench.serving"):
        for name, row in payload.get("per_policy", {}).items():
            for field in (
                "goodput_per_s",
                "requests_per_s",
                "realized_lazy_ratio",
                "drift_rel_l2_mean",
                "drift_cos_mean",
            ):
                if field in row:
                    metrics[f"serving/{name}/{field}"] = float(row[field])
        overload = payload.get("overload", {})
        for lkey, load_row in overload.get("loads", {}).items():
            for server, cell in load_row.get("servers", {}).items():
                for field in ("goodput_per_s", "slo_attainment"):
                    if field in cell:
                        key = f"serving_overload/{lkey}/{server}/{field}"
                        metrics[key] = float(cell[field])
        if "advantage_at_2x" in overload:
            # the acceptance knee: SLO-aware goodput over the best fixed
            # policy at 2x offered load; must stay > 1 and not erode
            metrics["serving_overload/advantage_at_2x"] = float(
                overload["advantage_at_2x"]
            )
    if schema.startswith("repro.bench.kernels"):
        la = payload.get("lazy_attention", {})
        for field in KERNEL_PERF_FIELDS:
            if field in la:
                # "perf/" prefix opts into the perf floors + MAD widening
                metrics[f"perf/kernels_lazy_attention/{field}"] = float(la[field])
        for field in ("bytes_saving_frac", "plan_skip_ratio"):
            if field in la:
                metrics[f"kernels/lazy_attention/{field}"] = float(la[field])
        if "cached_serve_bitexact" in la:
            metrics["kernels/lazy_attention/cached_serve_bitexact"] = float(
                bool(la["cached_serve_bitexact"])
            )
        for section in ("gate_select", "ddim_update"):
            row = payload.get(section, {})
            if "parity_ok" in row:
                metrics[f"kernels/{section}/parity_ok"] = float(bool(row["parity_ok"]))
    if schema.startswith("repro.bench.perf"):
        for name, row in payload.get("policies", {}).items():
            for field in PERF_GATED_FIELDS:
                if field in row:
                    metrics[f"perf/{name}/{field}"] = float(row[field])
    return metrics


def collect_noise(payload: dict) -> dict[str, float]:
    """Flatten one payload's MAD noise channel: for every gated perf metric
    ``perf/<name>/<field>`` whose ``<field>_mad`` sibling is present, its
    dispersion in the same units as the metric."""
    noise: dict[str, float] = {}
    schema = str(payload.get("schema", ""))
    if schema.startswith("repro.bench.kernels"):
        la = payload.get("lazy_attention", {})
        for field in KERNEL_PERF_FIELDS:
            if f"{field}_mad" in la:
                noise[f"perf/kernels_lazy_attention/{field}"] = float(
                    la[f"{field}_mad"]
                )
    if schema.startswith("repro.bench.perf"):
        for name, row in payload.get("policies", {}).items():
            for field in PERF_GATED_FIELDS:
                if f"{field}_mad" in row:
                    noise[f"perf/{name}/{field}"] = float(row[f"{field}_mad"])
    return noise


def effective_tolerance(
    metric: str,
    base: float,
    tolerance: float,
    baseline_noise: dict[str, float] | None,
    current_noise: dict[str, float] | None,
) -> float:
    """Per-metric relative tolerance: the metric's floor widened by
    PERF_MAD_SIGMAS robust sigmas of combined measurement noise relative to
    the baseline value.  Metrics without a noise channel keep their floor."""
    tol = metric_tolerance(metric, tolerance)
    mad = (baseline_noise or {}).get(metric, 0.0) + (current_noise or {}).get(
        metric, 0.0
    )
    if mad > 0.0 and base > ZERO_FLOOR:
        tol += PERF_MAD_SIGMAS * mad / base
    return tol


def compare(
    baseline: dict[str, float],
    current: dict[str, float],
    tolerance: float = RELATIVE_DROP_TOLERANCE,
    *,
    baseline_noise: dict[str, float] | None = None,
    current_noise: dict[str, float] | None = None,
) -> list[str]:
    """Failure messages for every gated metric that regressed past its
    effective tolerance or vanished; metrics with no baseline are
    informational only.

    NaN on either side means "no data for this metric in that run" (e.g.
    drift of a policy serving no lazy cache, percentiles of a run with no
    completions) — such metrics are skipped, never treated as zero or as
    a regression.  The noise dicts (collect_noise/load_noise) carry each
    metric's MAD; see effective_tolerance for how they widen the gate."""
    failures = []
    for metric in sorted(baseline):
        base = baseline[metric]
        cur = current.get(metric)
        if math.isnan(base) or (cur is not None and math.isnan(cur)):
            continue
        if base <= ZERO_FLOOR:
            continue
        if cur is None:
            failures.append(
                f"{metric}: present in baseline ({base:.4f}) but missing "
                "from the current artifacts"
            )
            continue
        tol = effective_tolerance(
            metric, base, tolerance, baseline_noise, current_noise
        )
        if is_lower_better(metric):
            if cur > base * (1.0 + tol):
                rise = cur / base - 1.0
                failures.append(
                    f"{metric}: {base:.4f} -> {cur:.4f} ({rise:.1%} rise "
                    f"exceeds the {tol:.0%} tolerance; lower is better)"
                )
        elif cur < base * (1.0 - tol):
            drop = 1.0 - cur / base
            failures.append(
                f"{metric}: {base:.4f} -> {cur:.4f} ({drop:.1%} drop "
                f"exceeds the {tol:.0%} tolerance)"
            )
    return failures


def load_metrics(directory: Path) -> dict[str, float]:
    metrics: dict[str, float] = {}
    for name in GATED_FILES:
        path = directory / name
        if not path.is_file():
            continue
        with open(path) as f:
            metrics.update(collect_metrics(json.load(f)))
    return metrics


def load_noise(directory: Path) -> dict[str, float]:
    noise: dict[str, float] = {}
    for name in GATED_FILES:
        path = directory / name
        if not path.is_file():
            continue
        with open(path) as f:
            noise.update(collect_noise(json.load(f)))
    return noise


def update_baselines(current_dir: Path, baseline_dir: Path) -> list[str]:
    baseline_dir.mkdir(parents=True, exist_ok=True)
    copied = []
    for name in GATED_FILES:
        src = current_dir / name
        if src.is_file():
            shutil.copyfile(src, baseline_dir / name)
            copied.append(name)
    return copied


def biting_baseline(
    metric: str, value: float, noise: dict[str, float]
) -> float | None:
    """A synthetic baseline guaranteed to trip the gate against ``value``
    under the metric's own effective tolerance (floor + noise widening), or
    None when measurement noise swamps the floor — the gate deliberately
    cannot bite there, so the metric is excluded from the perturbation."""
    if math.isnan(value) or value <= ZERO_FLOOR:
        return None
    tol = metric_tolerance(metric, RELATIVE_DROP_TOLERANCE)
    # both sides of the self-test comparison reuse the same noise map
    slack = PERF_MAD_SIGMAS * 2.0 * noise.get(metric, 0.0)
    if is_lower_better(metric):
        base = (value - slack) * 0.99 / (1.0 + tol)
        return base if base > ZERO_FLOOR else None
    return (value + slack) * 1.01 / (1.0 - tol)


def noise_demo() -> list[str]:
    """Synthetic proof that the wall gate is noise-AWARE, not noise-blind:
    a structural slowdown on quiet measurements is flagged, the same drop
    under MAD-scale dispersion is tolerated, and a wall-clock wobble under
    the catastrophic floor passes.  Returns problem descriptions (empty ==
    the demo holds)."""
    problems = []
    speedup = "perf/demo/speedup_vs_host"
    wall = "perf/demo/wall_ms_median"
    quiet = compare({speedup: 10.0, wall: 100.0}, {speedup: 6.0, wall: 250.0})
    if len(quiet) != 2:
        problems.append(
            "quiet structural slowdown (speedup 10->6, wall 100->250) "
            f"flagged {len(quiet)}/2 metrics"
        )
    noisy = compare(
        {speedup: 10.0},
        {speedup: 6.0},
        baseline_noise={speedup: 1.0},
        current_noise={speedup: 1.0},
    )
    if noisy:
        problems.append(
            "MAD-scale noise (speedup 10->6 with mad 1.0 both sides) was "
            "flagged instead of tolerated"
        )
    wobble = compare({wall: 100.0}, {wall: 180.0})
    if wobble:
        problems.append(
            "wall 100->180ms (under the catastrophic floor) was flagged"
        )
    return problems


def self_test(current_dir: Path) -> int:
    """Prove the gate bites: a synthetic baseline shifted just past every
    gated metric's effective tolerance MUST fail (deflated for
    higher-is-better metrics, inflated-above for lower-is-better ones), the
    artifacts compared against themselves MUST pass, and the synthetic
    noise demo MUST hold.  NaN metrics carry no data and metrics whose
    noise swamps their floor are excluded from the perturbation."""
    current = load_metrics(current_dir)
    if not current:
        print(
            f"self-test: no gated artifacts under {current_dir} "
            "(run `python -m benchmarks.run --smoke` first)"
        )
        return 1
    noise = load_noise(current_dir)
    perturbed = {}
    for k, v in current.items():
        base = biting_baseline(k, v, noise)
        if base is not None:
            perturbed[k] = base
    if not perturbed:
        print("self-test: every gated metric is zero; nothing to perturb")
        return 1
    injected = compare(
        perturbed, current, baseline_noise=noise, current_noise=noise
    )
    clean = compare(
        current, current, baseline_noise=noise, current_noise=noise
    )
    demo = noise_demo()
    print(
        f"self-test: {len(current)} gated metrics; injected regression "
        f"flagged {len(injected)}/{len(perturbed)} perturbed baselines; "
        f"clean comparison flagged {len(clean)}; noise demo problems: "
        f"{len(demo)}"
    )
    for line in demo:
        print(f"  noise demo: {line}")
    if len(injected) != len(perturbed) or clean or demo:
        print("self-test FAILED: the gate does not bite")
        return 1
    print("self-test OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, default=DEFAULT_BASELINE_DIR)
    ap.add_argument("--current-dir", type=Path, default=DEFAULT_CURRENT_DIR)
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the current artifacts over the committed baselines",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="verify the gate bites past each metric's effective "
        "tolerance and tolerates MAD-scale noise",
    )
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.current_dir)
    if args.update:
        copied = update_baselines(args.current_dir, args.baseline_dir)
        print(
            f"baselines updated in {args.baseline_dir}: "
            f"{', '.join(copied) or 'nothing found'}"
        )
        return 0

    baseline = load_metrics(args.baseline_dir)
    if not baseline:
        print(
            f"no baselines under {args.baseline_dir}; run with --update "
            "after a smoke pass to create them"
        )
        return 1
    current = load_metrics(args.current_dir)
    failures = compare(
        baseline,
        current,
        baseline_noise=load_noise(args.baseline_dir),
        current_noise=load_noise(args.current_dir),
    )
    gated = sum(1 for v in baseline.values() if v > ZERO_FLOOR)
    if failures:
        print(
            f"BENCHMARK REGRESSION: {len(failures)} of {gated} gated "
            "metrics regressed"
        )
        for line in failures:
            print(f"  {line}")
        return 1
    print(
        f"benchmark gate OK: {gated} gated metrics within their "
        f"tolerances (default {RELATIVE_DROP_TOLERANCE:.0%}, perf "
        f"floors {PERF_REL_TOLERANCE:.0%}/{WALL_ABS_TOLERANCE:.0%} + "
        f"MAD widening; {len(baseline)} tracked)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
