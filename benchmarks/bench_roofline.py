"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and emits
the per-(arch x shape) three-term roofline for the single-pod mesh."""
import glob
import json
import os

from benchmarks.common import ARTIFACTS


def run() -> list:
    rows = []
    files = sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*__16x16.json")))
    if not files:
        return [("roofline", "no dry-run artifacts yet — run "
                 "`python -m repro.launch.dryrun --all --both-meshes`")]
    for f in files:
        r = json.load(open(f))
        if r.get("skipped"):
            rows.append((f"{r['arch']}/{r['shape']}", "SKIP", r["why"]))
            continue
        rl = r["roofline"]
        rows.append((
            f"{r['arch']}/{r['shape']}",
            f"compute_s={rl['compute_s']:.4f}",
            f"memory_s={rl['memory_s']:.4f}",
            f"collective_s={rl['collective_s']:.4f}",
            f"dominant={rl['dominant'].replace('_s','')}",
            f"useful={rl['useful_compute_ratio']:.3f}"
            if rl["useful_compute_ratio"] else "useful=n/a",
        ))
    return rows
