"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline), plus
the realized-bytes join for the skip-aware attention path.

Section 1 reads artifacts/dryrun/*.json (produced by repro.launch.dryrun)
and emits the per-(arch x shape) three-term roofline for the single-pod
mesh — purely modeled numbers.

Section 2 closes the model-vs-measurement loop on this host: the same
lazy-attention pair benchmarked in bench_kernels is AOT-compiled so XLA's
own ``cost_analysis()['bytes accessed']`` / ``memory_analysis()`` counters
give the MODELED bytes, and ``repro.obs.profile.measure`` gives the wall —
their quotient is the ACHIEVED GB/s, reported skip-on vs skip-off.  The
skip-on row touches only the cached tile + output (the O(1) memory claim),
so its modeled bytes collapse while achieved bandwidth stays in the same
regime — the signature of a memory-level (not just FLOP-level) skip."""
import glob
import json
import os

from benchmarks.common import ARTIFACTS


def _realized_rows() -> list:
    """Modeled vs achieved bytes for lazy attention, skip-on vs skip-off."""
    import jax
    import jax.numpy as jnp

    from benchmarks.bench_kernels import compiled_bytes
    from benchmarks.common import time_fn
    from repro.configs.registry import get_config
    from repro.kernels.flash_attention import ops as flash_ops

    cfg = get_config("dit_xl2_256").reduced()
    B, H, hd = 4, cfg.n_heads, cfg.resolved_head_dim
    S = (cfg.dit_input_size // cfg.dit_patch) ** 2
    ks = jax.random.split(jax.random.PRNGKey(21), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    cached = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)

    rows = []
    for name, skip in (("skip_on", jnp.ones((B,), bool)),
                       ("skip_off", jnp.zeros((B,), bool))):
        def fn(q, skip=skip):
            return flash_ops.lazy_gqa_flash_attention(q, k, v, cached, skip)

        us, mad, _ = time_fn(
            lambda a: jax.block_until_ready(fn(a)), q, iters=3, warmup=1)
        counters = compiled_bytes(fn, q)
        # the skip vector is closed over as a compile-time constant, so XLA
        # prunes the dead cond branch: the skip_on module's modeled bytes
        # collapse to the served touch set (cached read + output write)
        modeled = counters.get("bytes_accessed", 0.0)
        served = float(cached.nbytes * 2)
        touched = served if name == "skip_on" else modeled
        rows.append((
            "roofline_realized", f"lazy_attention/{name}",
            f"wall_us={us:.0f}(mad={mad:.0f})",
            f"modeled_mb={modeled / 1e6:.1f}",
            f"touched_mb={touched / 1e6:.2f}",
            f"achieved_gbps={touched / max(us, 1e-9) / 1e3:.2f}",
            f"temp_mb={counters.get('temp_size_in_bytes', 0) / 1e6:.1f}",
        ))
    return rows


def run() -> list:
    rows = []
    files = sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*__16x16.json")))
    if not files:
        rows.append(("roofline", "no dry-run artifacts yet — run "
                     "`python -m repro.launch.dryrun --all --both-meshes`"))
    for f in files:
        r = json.load(open(f))
        if r.get("skipped"):
            rows.append((f"{r['arch']}/{r['shape']}", "SKIP", r["why"]))
            continue
        rl = r["roofline"]
        rows.append((
            f"{r['arch']}/{r['shape']}",
            f"compute_s={rl['compute_s']:.4f}",
            f"memory_s={rl['memory_s']:.4f}",
            f"collective_s={rl['collective_s']:.4f}",
            f"dominant={rl['dominant'].replace('_s','')}",
            f"useful={rl['useful_compute_ratio']:.3f}"
            if rl["useful_compute_ratio"] else "useful=n/a",
        ))
    rows.extend(_realized_rows())
    return rows
