"""Fused vs host-loop DDIM sampler benchmark (the trajectory executor).

The host-loop sampler (sampling/ddim.ddim_sample_reference) pays one XLA
compilation per DISTINCT static plan row plus per-step dispatch; the
fused executor (sampling/trajectory.py) compiles the whole trajectory as
one ``lax.scan`` with plan rows scanned as device arrays.  Per policy on
a reduced dit_xl2_256 this benchmark reports

  * compile count — ``jax.monitoring`` backend-compile events during the
    cold run, plus the jit trace-cache probe (``fn._cache_size()``) that
    pins the fused executor to exactly ONE entry even across schedules;
  * wall-clock per step — warm, median over repeats;
  * realized skip ratio — the fused executor's in-carry accounting;
  * bit-exactness of fused vs host output.

Asserts the compile-once contract and that the fused sampler's per-step
wall-clock is no worse than the host loop's.  Emits
``artifacts/BENCH_trajectory.json`` (uploaded by CI with all BENCH_*).
"""
from __future__ import annotations

import json
import os
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS, lazy_dit_fixture, time_fn
from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.sampling import ddim, trajectory

SCHEMA = "repro.bench.trajectory/v1"

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@contextmanager
def compile_counter():
    """Counts XLA backend compilations via jax.monitoring events."""
    from jax._src import monitoring as _mon

    counts = {"n": 0}

    def _listener(event, duration, **kw):
        if event == _COMPILE_EVENT:
            counts["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(_listener)
    try:
        yield counts
    finally:
        _mon._unregister_event_duration_listener_by_callback(_listener)


def _median_ms(fn) -> float:
    """Median wall-clock ms/call via the shared benchmark timer."""
    return time_fn(fn, iters=3, warmup=1) / 1e3


def _policies(cfg, params, sched, labels, n_steps, *, with_smoothcache):
    out = {
        "none": cache_lib.get_policy("none"),
        "stride": cache_lib.get_policy("stride", stride=2),
        "static_router": cache_lib.get_policy("static_router", ratio=0.5),
    }
    if with_smoothcache:
        calib = calibrate_lib.calibrate_dit(
            params, cfg, sched, key=jax.random.PRNGKey(7), labels=labels,
            n_steps=n_steps, cfg_scale=1.5)
        out["smoothcache"] = cache_lib.get_policy(
            "smoothcache", calibration=calib,
            error_threshold=calib.quantile_threshold(0.5))
    return out


def run_bench(*, smoke: bool = False):
    if smoke:
        cfg, params, sched = lazy_dit_fixture(pretrain=3, lazy_steps=2)
        n_steps, with_sc = 6, False
    else:
        cfg, params, sched = lazy_dit_fixture()
        n_steps, with_sc = 16, True
    batch = 2
    labels = jnp.arange(batch) % cfg.dit_n_classes
    key = jax.random.PRNGKey(11)
    kw = dict(key=key, labels=labels, n_steps=n_steps, cfg_scale=1.5)

    policies = _policies(cfg, params, sched, labels, n_steps,
                         with_smoothcache=with_sc)
    results = {}
    for name, pol in policies.items():
        # ---- host loop: cold compile count, then warm per-step time
        with compile_counter() as host_cold:
            x_host, _ = ddim.ddim_sample_reference(params, cfg, sched,
                                                   policy=pol, **kw)
            jax.block_until_ready(x_host)
        host_ms = _median_ms(lambda: ddim.ddim_sample_reference(
            params, cfg, sched, policy=pol, **kw)[0])

        # ---- fused: cold compile count + trace-cache probe + warm time
        trajectory.build_sampler.cache_clear()
        with compile_counter() as fused_cold:
            x_fused, aux = trajectory.sample_trajectory(params, cfg, sched,
                                                        policy=pol, **kw)
            jax.block_until_ready(x_fused)
        fn = trajectory.build_sampler(cfg, pol, n_steps, 1.5)
        fused_ms = _median_ms(lambda: trajectory.sample_trajectory(
            params, cfg, sched, policy=pol, **kw)[0])
        # the compile-once contract: warm fused samples compile NOTHING
        # (cold counts include incidental eager-op compiles shared with
        # whatever ran first in the process, so they are reported, not
        # compared)
        with compile_counter() as fused_warm:
            jax.block_until_ready(trajectory.sample_trajectory(
                params, cfg, sched, policy=pol, **kw)[0])

        exact = bool(np.array_equal(np.asarray(x_host), np.asarray(x_fused)))
        cache_size = int(fn._cache_size())
        assert exact, f"{name}: fused output != host-loop reference"
        assert cache_size == 1, \
            f"{name}: fused sampler traced {cache_size} times, expected 1"
        assert fused_warm["n"] == 0, \
            f"{name}: warm fused sample compiled {fused_warm['n']} times"

        results[name] = {
            "exec_mode": pol.exec_mode,
            "realized_skip_ratio": round(aux["realized_skip_ratio"], 4),
            "bit_exact_vs_host": exact,
            "host": {"cold_backend_compiles": host_cold["n"],
                     "per_step_ms": round(host_ms / n_steps, 4),
                     "total_ms": round(host_ms, 3)},
            "fused": {"cold_backend_compiles": fused_cold["n"],
                      "warm_backend_compiles": fused_warm["n"],
                      "trace_cache_size": cache_size,
                      "per_step_ms": round(fused_ms / n_steps, 4),
                      "total_ms": round(fused_ms, 3)},
            "fused_speedup": round(host_ms / max(fused_ms, 1e-9), 3),
        }

    # acceptance: fused per-step wall-clock <= host-loop per-step wall-clock
    for name, r in results.items():
        assert r["fused"]["per_step_ms"] <= r["host"]["per_step_ms"], \
            (f"{name}: fused {r['fused']['per_step_ms']}ms/step slower than "
             f"host {r['host']['per_step_ms']}ms/step")

    payload = {
        "schema": SCHEMA,
        "smoke": smoke,
        "arch": "dit_xl2_256 (reduced bench fixture)",
        "reduced": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                    "input_size": cfg.dit_input_size},
        "n_steps": n_steps, "batch": batch, "cfg_scale": 1.5,
        "compile_probe": "jax.monitoring backend_compile events (cold run) "
                         "+ jit trace-cache size (fused fn)",
        "policies": results,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS, "BENCH_trajectory.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

    rows = []
    for name, r in sorted(results.items()):
        rows.append(("trajectory", name,
                     f"host_compiles={r['host']['cold_backend_compiles']}",
                     f"fused_compiles={r['fused']['cold_backend_compiles']}",
                     f"host_ms_per_step={r['host']['per_step_ms']:.3f}",
                     f"fused_ms_per_step={r['fused']['per_step_ms']:.3f}",
                     f"speedup={r['fused_speedup']:.2f}x",
                     f"ratio={r['realized_skip_ratio']:.2f}"))
    rows.append(("trajectory", "json", path))
    return rows, payload


def run():
    """Full-suite entry (benchmarks.run)."""
    rows, _ = run_bench(smoke=False)
    return rows


def run_smoke():
    """CI smoke entry: tiny fixture, same assertions, same artifact."""
    rows, _ = run_bench(smoke=True)
    return rows
