"""Fused vs host-loop DDIM sampler benchmark (the trajectory executor).

The host-loop sampler (sampling/ddim.ddim_sample_reference) pays one XLA
compilation per DISTINCT static plan row plus per-step dispatch; the
fused executor (sampling/trajectory.py) compiles the whole trajectory as
one ``lax.scan`` with plan rows scanned as device arrays.  Per policy on
a reduced dit_xl2_256 this benchmark reports

  * compile count — ``jax.monitoring`` backend-compile events during the
    cold run, plus the jit trace-cache probe (``fn._cache_size()``) that
    pins the fused executor to exactly ONE entry even across schedules;
  * wall-clock per step — warm, median over repeats;
  * realized skip ratio — the fused executor's in-carry accounting;
  * bit-exactness of fused vs host output.

Asserts the compile-once contract and that the fused sampler's per-step
wall-clock is no worse than the host loop's.  With >= 8 devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) a mesh-scaling
section additionally shards the fused scan over data=1 vs data=8 meshes
and records the modeled batch-throughput scaling from per-device
compiled FLOPs (dist/hlo.sharded_totals) — wall-clock is reported but
NOT asserted, because forced host devices share one physical CPU.  Emits
``artifacts/BENCH_trajectory.json`` (uploaded by CI with all BENCH_*).
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS, lazy_dit_fixture, time_fn
from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.dist import ctx as dist_ctx
from repro.dist import hlo as hlo_lib
from repro.obs import profile as profile_lib
from repro.sampling import ddim, trajectory

MESH_SIZES = (1, 8)
MIN_MODELED_SCALING = 4.0     # acceptance floor for data=1 -> data=8

SCHEMA = "repro.bench.trajectory/v1"
PERF_SCHEMA = "repro.bench.perf/v1"

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@contextmanager
def compile_counter():
    """Counts XLA backend compilations via jax.monitoring events."""
    from jax._src import monitoring as _mon

    counts = {"n": 0}

    def _listener(event, duration, **kw):
        if event == _COMPILE_EVENT:
            counts["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(_listener)
    try:
        yield counts
    finally:
        _mon._unregister_event_duration_listener_by_callback(_listener)


def _measure_ms(fn):
    """(median_ms, mad_ms, iters_kept) via the shared benchmark timer."""
    us, mad_us, iters = time_fn(fn, iters=3, warmup=1)
    return us / 1e3, mad_us / 1e3, iters


def _policies(cfg, params, sched, labels, n_steps, *, with_smoothcache):
    out = {
        "none": cache_lib.get_policy("none"),
        "stride": cache_lib.get_policy("stride", stride=2),
        "static_router": cache_lib.get_policy("static_router", ratio=0.5),
    }
    if with_smoothcache:
        calib = calibrate_lib.calibrate_dit(
            params, cfg, sched, key=jax.random.PRNGKey(7), labels=labels,
            n_steps=n_steps, cfg_scale=1.5)
        out["smoothcache"] = cache_lib.get_policy(
            "smoothcache", calibration=calib,
            error_threshold=calib.quantile_threshold(0.5))
    return out


def _mesh_scaling(cfg, params, sched, n_steps: int) -> dict:
    """Shard the fused executor over data=1 vs data=8 and account the
    scaling three ways: modeled batch throughput (per-device compiled
    FLOPs via dist/hlo — the machine-independent number the regression
    gate can trust), per-example bit-exactness across mesh sizes, and
    informational wall-clock (forced host devices share one CPU, so wall
    time shows SPMD overhead, not real speedup)."""
    n_dev = len(jax.devices())
    if n_dev < max(MESH_SIZES):
        return {"available": False,
                "why": f"needs {max(MESH_SIZES)} devices, have {n_dev} "
                       "(set XLA_FLAGS=--xla_force_host_platform_"
                       "device_count=8)"}
    batch = max(MESH_SIZES)
    labels = jnp.arange(batch) % cfg.dit_n_classes
    pol = cache_lib.get_policy("static_router", ratio=0.5)
    kw = dict(key=jax.random.PRNGKey(13), labels=labels, n_steps=n_steps,
              cfg_scale=1.5, policy=pol)
    meshes = {}
    outputs = {}
    for n_data in MESH_SIZES:
        trajectory.build_sampler.cache_clear()
        # bit-exactness across mesh sizes needs the strict matmul path:
        # at default precision XLA CPU picks its GEMM backend by shape, so
        # per-shard and full-batch matmuls round differently
        with jax.default_matmul_precision("highest"), \
                dist_ctx.mesh(data=n_data):
            x, aux = trajectory.sample_trajectory(params, cfg, sched, **kw)
            jax.block_until_ready(x)
            wall_ms, _, _ = _measure_ms(lambda: jax.block_until_ready(
                trajectory.sample_trajectory(params, cfg, sched, **kw)[0]))
            fn = trajectory.build_sampler(cfg, pol, n_steps, 1.5,
                                          batch=batch)
            args = trajectory.prepare_inputs(
                cfg, sched, pol, key=jax.random.PRNGKey(13), labels=labels,
                n_steps=n_steps)
            mod = hlo_lib.sharded_totals(
                fn.lower(params, *args).compile().as_text())
        outputs[n_data] = np.asarray(x)
        meshes[f"data={n_data}"] = {
            "partitions": mod["partitions"],
            "flops_per_device": mod["flops"],
            "flops_global": mod["flops_global"],
            "collectives": {k: v["count"]
                            for k, v in mod["collective"].items()},
            "wall_ms": round(wall_ms, 3),
            "realized_skip_ratio": round(aux["realized_skip_ratio"], 4),
        }
    lo, hi = min(MESH_SIZES), max(MESH_SIZES)
    scaling = (meshes[f"data={lo}"]["flops_per_device"]
               / max(meshes[f"data={hi}"]["flops_per_device"], 1.0))
    # Parity: bit-exactness across mesh sizes is the TESTED contract on
    # the shapes CI pins (tests/test_trajectory_sharded.py, and the serve
    # CLI digest diff on dit_xl2_256) — on this bench fixture's GEMM
    # shapes XLA CPU's blocking heuristics can legally differ per shard
    # size, so the bench records exactness and gates at ulp scale
    # (~1 ulp/step accumulation) instead of asserting zero.
    exact = bool(np.array_equal(outputs[lo], outputs[hi]))
    max_abs_diff = float(np.abs(outputs[lo] - outputs[hi]).max())
    assert max_abs_diff <= 1e-4 * n_steps, \
        (f"data={lo} vs data={hi} diverged by {max_abs_diff:.2e} — far "
         "beyond GEMM-blocking ulp noise; the sharded scan is broken")
    assert scaling >= MIN_MODELED_SCALING, \
        (f"data={lo} -> data={hi} modeled throughput scaling {scaling:.2f}x "
         f"< {MIN_MODELED_SCALING}x: the sharded scan is not partitioning "
         "the batch")
    return {"available": True, "batch": batch, "policy": "static_router",
            "meshes": meshes, "bit_exact_across_meshes": exact,
            "max_abs_diff_across_meshes": max_abs_diff,
            "modeled_throughput_scaling": round(scaling, 3)}


def run_bench(*, smoke: bool = False):
    if smoke:
        cfg, params, sched = lazy_dit_fixture(pretrain=3, lazy_steps=2)
        n_steps, with_sc = 6, False
    else:
        cfg, params, sched = lazy_dit_fixture()
        n_steps, with_sc = 16, True
    batch = 2
    labels = jnp.arange(batch) % cfg.dit_n_classes
    key = jax.random.PRNGKey(11)
    kw = dict(key=key, labels=labels, n_steps=n_steps, cfg_scale=1.5)

    policies = _policies(cfg, params, sched, labels, n_steps,
                         with_smoothcache=with_sc)
    results = {}
    for name, pol in policies.items():
        # ---- host loop: cold compile count, then warm per-step time
        with compile_counter() as host_cold:
            x_host, _ = ddim.ddim_sample_reference(params, cfg, sched,
                                                   policy=pol, **kw)
            jax.block_until_ready(x_host)
        host_ms, host_mad_ms, host_iters = _measure_ms(
            lambda: ddim.ddim_sample_reference(
                params, cfg, sched, policy=pol, **kw)[0])

        # ---- fused: cold compile count + trace-cache probe + warm time
        trajectory.build_sampler.cache_clear()
        with compile_counter() as fused_cold:
            x_fused, aux = trajectory.sample_trajectory(params, cfg, sched,
                                                        policy=pol, **kw)
            jax.block_until_ready(x_fused)
        fn = trajectory.build_sampler(cfg, pol, n_steps, 1.5)
        fused_ms, fused_mad_ms, fused_iters = _measure_ms(
            lambda: trajectory.sample_trajectory(
                params, cfg, sched, policy=pol, **kw)[0])
        # the compile-once contract: warm fused samples compile NOTHING
        # (cold counts include incidental eager-op compiles shared with
        # whatever ran first in the process, so they are reported, not
        # compared)
        with compile_counter() as fused_warm:
            jax.block_until_ready(trajectory.sample_trajectory(
                params, cfg, sched, policy=pol, **kw)[0])

        exact = bool(np.array_equal(np.asarray(x_host), np.asarray(x_fused)))
        cache_size = int(fn._cache_size())
        assert exact, f"{name}: fused output != host-loop reference"
        assert cache_size == 1, \
            f"{name}: fused sampler traced {cache_size} times, expected 1"
        assert fused_warm["n"] == 0, \
            f"{name}: warm fused sample compiled {fused_warm['n']} times"

        results[name] = {
            "exec_mode": pol.exec_mode,
            "realized_skip_ratio": round(aux["realized_skip_ratio"], 4),
            "bit_exact_vs_host": exact,
            "host": {"cold_backend_compiles": host_cold["n"],
                     "per_step_ms": round(host_ms / n_steps, 4),
                     "total_ms": round(host_ms, 3),
                     "total_ms_mad": round(host_mad_ms, 3),
                     "iters": host_iters},
            "fused": {"cold_backend_compiles": fused_cold["n"],
                      "warm_backend_compiles": fused_warm["n"],
                      "trace_cache_size": cache_size,
                      "per_step_ms": round(fused_ms / n_steps, 4),
                      "total_ms": round(fused_ms, 3),
                      "total_ms_mad": round(fused_mad_ms, 3),
                      "iters": fused_iters},
            "fused_speedup": round(host_ms / max(fused_ms, 1e-9), 3),
        }

    # acceptance: fused per-step wall-clock <= host-loop per-step wall-clock
    for name, r in results.items():
        assert r["fused"]["per_step_ms"] <= r["host"]["per_step_ms"], \
            (f"{name}: fused {r['fused']['per_step_ms']}ms/step slower than "
             f"host {r['host']['per_step_ms']}ms/step")

    mesh_scaling = _mesh_scaling(cfg, params, sched, n_steps)

    payload = {
        "schema": SCHEMA,
        "smoke": smoke,
        "arch": "dit_xl2_256 (reduced bench fixture)",
        "reduced": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                    "input_size": cfg.dit_input_size},
        "n_steps": n_steps, "batch": batch, "cfg_scale": 1.5,
        "compile_probe": "jax.monitoring backend_compile events (cold run) "
                         "+ jit trace-cache size (fused fn)",
        "policies": results,
        "mesh_scaling": mesh_scaling,
    }
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.normpath(os.path.join(ARTIFACTS, "BENCH_trajectory.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

    # ---- realized-performance artifact: wall medians + MAD noise channel
    # wall_ms_median is machine-dependent (gated only against catastrophic
    # regressions); speedup_vs_host is a same-run ratio and therefore the
    # machine-independent gated signal (benchmarks/check_regression.py).
    perf_policies = {}
    for name, r in results.items():
        f_ms, f_mad = r["fused"]["total_ms"], r["fused"]["total_ms_mad"]
        h_ms, h_mad = r["host"]["total_ms"], r["host"]["total_ms_mad"]
        speedup = h_ms / max(f_ms, 1e-9)
        # first-order error propagation for the ratio of two medians
        speedup_mad = speedup * (f_mad / max(f_ms, 1e-9)
                                 + h_mad / max(h_ms, 1e-9))
        perf_policies[name] = {
            "wall_ms_median": f_ms,
            "wall_ms_median_mad": f_mad,
            "per_step_ms_median": r["fused"]["per_step_ms"],
            "host_wall_ms_median": h_ms,
            "host_wall_ms_median_mad": h_mad,
            "speedup_vs_host": round(speedup, 4),
            "speedup_vs_host_mad": round(speedup_mad, 4),
            "iters": r["fused"]["iters"],
        }
    perf_payload = {
        "schema": PERF_SCHEMA,
        "smoke": smoke,
        "arch": payload["arch"],
        "n_steps": n_steps, "batch": batch,
        "harness": "repro.obs.profile.measure (median + MAD, "
                   "outlier-rejected, warmup-until-stable)",
        "memory_watermarks": profile_lib.memory_watermarks(),
        "policies": perf_policies,
    }
    perf_path = os.path.normpath(
        os.path.join(ARTIFACTS, "PERF_trajectory.json"))
    with open(perf_path, "w") as f:
        json.dump(perf_payload, f, indent=1, sort_keys=True)
    profile_lib.append_trend(
        os.path.normpath(os.path.join(ARTIFACTS, "PERF_trajectory.jsonl")),
        {"schema": PERF_SCHEMA, "unix_time": round(time.time(), 1),
         "smoke": smoke, "n_steps": n_steps,
         "policies": {n: {"wall_ms_median": p["wall_ms_median"],
                          "wall_ms_median_mad": p["wall_ms_median_mad"],
                          "speedup_vs_host": p["speedup_vs_host"]}
                      for n, p in perf_policies.items()}})

    rows = []
    for name, r in sorted(results.items()):
        rows.append(("trajectory", name,
                     f"host_compiles={r['host']['cold_backend_compiles']}",
                     f"fused_compiles={r['fused']['cold_backend_compiles']}",
                     f"host_ms_per_step={r['host']['per_step_ms']:.3f}",
                     f"fused_ms_per_step={r['fused']['per_step_ms']:.3f}",
                     f"speedup={r['fused_speedup']:.2f}x",
                     f"ratio={r['realized_skip_ratio']:.2f}"))
    if mesh_scaling.get("available"):
        rows.append(("trajectory", "mesh_scaling",
                     f"modeled={mesh_scaling['modeled_throughput_scaling']:.2f}x",
                     f"bit_exact={mesh_scaling['bit_exact_across_meshes']}",
                     f"max_abs_diff={mesh_scaling['max_abs_diff_across_meshes']:.1e}",
                     "wall_ms=" + "/".join(
                         f"{m['wall_ms']:.1f}"
                         for m in mesh_scaling["meshes"].values())))
    else:
        # no silent caps: say the section was skipped and why
        rows.append(("trajectory", "mesh_scaling", "SKIPPED",
                     mesh_scaling["why"]))
    rows.append(("trajectory", "json", path))
    rows.append(("trajectory", "perf_json", perf_path))
    return rows, payload


def run():
    """Full-suite entry (benchmarks.run)."""
    rows, _ = run_bench(smoke=False)
    return rows


def run_smoke():
    """CI smoke entry: tiny fixture, same assertions, same artifact."""
    rows, _ = run_bench(smoke=True)
    return rows
