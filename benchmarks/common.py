"""Shared benchmark helpers: timing + a pretrained tiny DiT fixture."""
from __future__ import annotations

import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import LazyConfig, ModelConfig
from repro.data.synthetic import LatentImageDataset
from repro.models import dit as dit_lib
from repro.obs import profile as profile_lib
from repro.sampling import ddim
from repro.train import optim, trainer

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Steady-state (median_us, mad_us, iters_kept) per call (post-jit).

    Delegates to the shared ``repro.obs.profile.measure`` harness so every
    benchmark reports the same robust statistic (median + MAD over
    outlier-rejected samples) instead of a hand-rolled loop."""
    m = profile_lib.measure(fn, *args, iters=iters, warmup=warmup)
    return m.median_us, m.mad_us, m.iters


@functools.lru_cache(maxsize=1)
def lazy_dit_fixture(pretrain: int = 80, lazy_steps: int = 60):
    """Tiny DiT pretrained + lazy-learned; shared across benchmarks."""
    cfg = ModelConfig(
        name="dit-bench", family="dit", n_layers=4, d_model=96, n_heads=4,
        n_kv_heads=4, d_ff=256, rope_type="none", dit_patch=2,
        dit_input_size=16, dit_in_channels=4, dit_n_classes=8,
        dtype="float32",
        lazy=LazyConfig(enabled=True, rho_attn=5e-3, rho_ffn=5e-3))
    key = jax.random.PRNGKey(0)
    params = dit_lib.init_dit(key, cfg)
    sched = ddim.linear_schedule(200)
    data = LatentImageDataset(cfg, seed=0)
    it = data.batches(16, seed=1)
    opt = optim.adamw_init(params)
    for _ in range(pretrain):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, _ = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    opt2 = optim.adamw_init(params)
    for _ in range(lazy_steps):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt2, _ = trainer.lazy_train_step(
            params, opt2, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            n_sample_steps=10, lr=1e-2)
    return cfg, params, sched
