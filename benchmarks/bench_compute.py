"""Paper Tables 3/6 analogue: TMACs / latency vs lazy ratio.

Two measurements per lazy ratio:
  * analytic TMACs of the denoiser eval (matches the paper's
    pytorch-OpCounter accounting), and
  * compiled-HLO FLOPs of a plan-mode step (proves the skip REMOVES compute
    from the XLA program — the TPU analogue of the paper's measured mobile
    latency), plus wall time on this host as a sanity signal."""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import lazy_dit_fixture, time_fn
from repro.dist import hlo as hlo_lib
from repro.models import dit as dit_lib


def dit_tmacs(cfg, lazy_ratio: float = 0.0) -> float:
    """Analytic MACs per denoiser eval (batch 1), pytorch-OpCounter style
    (paper Tables 3/6).  DiT MLP is fc1->gelu->fc2 (2 matmuls)."""
    N = (cfg.dit_input_size // cfg.dit_patch) ** 2
    D, F, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    attn = 4 * N * D * D + 2 * N * N * D
    ffn = 2 * N * D * F
    per_layer = (attn + ffn) * (1.0 - lazy_ratio)
    probes = 2 * N * D
    return (L * (per_layer + probes)) / 1e12


def run() -> list:
    cfg, params, sched = lazy_dit_fixture()
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.dit_input_size,
                                                  cfg.dit_input_size,
                                                  cfg.dit_in_channels))
    t = jnp.full((B,), 10.0)
    y = jnp.arange(B) % cfg.dit_n_classes
    cache = dit_lib.init_dit_lazy_cache(cfg, B)

    rows = []
    for ratio in (0.0, 0.2, 0.5):
        plan_row = np.zeros((cfg.n_layers, 2), bool)
        n_skip = int(round(ratio * plan_row.size))
        plan_row.reshape(-1)[:n_skip] = True       # deterministic skip set

        def step(x, cache, pr=plan_row):
            out, nc, _ = dit_lib.dit_forward(params, cfg, x, t, y,
                                             lazy_cache=cache,
                                             lazy_mode="plan", plan_row=pr)
            return out, nc

        jitted = jax.jit(step)
        compiled = jitted.lower(x, cache).compile()
        mod = hlo_lib.analyze_module(compiled.as_text())
        us, _, _ = time_fn(lambda a, b: jitted(a, b)[0], x, cache)
        rows.append((f"plan_ratio{int(ratio*100)}",
                     f"us_per_call={us:.0f}",
                     f"hlo_gflops={mod['flops']/1e9:.3f}",
                     f"analytic_tmacs={dit_tmacs(cfg, ratio):.6f}"))
    # relative FLOP reduction must track the ratio
    base = float(rows[0][2].split("=")[1])
    half = float(rows[2][2].split("=")[1])
    rows.append(("flop_reduction_at_50pct", f"{1 - half / base:.1%}"))

    # ---- full-scale DiT-XL/2-256 (paper's flagship): LOWER-only (no exec)
    from repro.configs.registry import get_config
    xl = get_config("dit_xl2_256")
    px = dit_lib.init_dit(jax.random.PRNGKey(0), xl.replace(dtype="float32"))
    Bx = 2
    xx = jnp.zeros((Bx, 32, 32, 4), jnp.float32)
    tx = jnp.zeros((Bx,), jnp.float32)
    yx = jnp.zeros((Bx,), jnp.int32)
    cx = dit_lib.init_dit_lazy_cache(xl, Bx)
    for ratio in (0.0, 0.5):
        pr = np.zeros((xl.n_layers, 2), bool)
        pr.reshape(-1)[: int(round(ratio * pr.size))] = True

        def xstep(x, cache, pr=pr):
            out, nc, _ = dit_lib.dit_forward(px, xl, x, tx, yx,
                                             lazy_cache=cache,
                                             lazy_mode="plan", plan_row=pr)
            return out, nc

        compiled = jax.jit(xstep).lower(xx, cx).compile()
        mod = hlo_lib.analyze_module(compiled.as_text())
        # paper Table 3 accounting: TMACs at batch 1 per denoiser eval
        rows.append((f"dit_xl2_256_plan{int(ratio*100)}",
                     f"hlo_tflops_b2={mod['flops']/1e12:.3f}",
                     f"analytic_tmacs_b1={dit_tmacs(xl, ratio):.3f}"))
    return rows
