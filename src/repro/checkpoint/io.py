"""Checkpointing: flat .npz with path-keyed entries, shard-aware restore.

Arrays are pulled to host (fully replicated view) on save; on restore they
are device_put with the caller-provided shardings (or left on host).  For
the CPU examples this is exact; on a real pod one would swap in a
tensorstore backend behind the same two functions.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params: Any, extra: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__/{k}"] = np.asarray(v)
    np.savez(path, **flat)


def load_extras(path: str) -> dict:
    """The ``extra`` scalars/arrays a checkpoint was saved with (step
    counters, optimizer step, recipe metadata) — the counterpart of
    ``save_checkpoint``'s ``extra`` argument, used by the lazy-training
    resume path (train/learned.py) to continue a recipe mid-run."""
    data = np.load(path)
    return {k.split("/", 1)[1]: data[k] for k in data.files
            if k.startswith("__extra__/")}


def restore_checkpoint(path: str, params_template: Any, shardings=None):
    """Restore into the structure of ``params_template``."""
    data = np.load(path)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(params_template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = np.asarray(data[key]).astype(leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_template), leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
