"""Serving engines.

``Engine`` — static batch: all sequences share one position counter, one
prefill + jitted decode loop.  Skip/reuse decisions route through one
cache policy (repro.cache; DESIGN.md §Cache) — pass ``policy=`` directly,
or the legacy lazy modes 'off' | 'masked' (per-sample select) | 'plan'
(boolean rows threaded into the decode step as traced per-step selects),
which map onto the `none` / `lazy_gate` / `plan` policies.

``ContinuousBatchingEngine`` — slot-based continuous batching: a fixed
pool of decode lanes over shared stacked caches (slots.SlotPool), FCFS
join-on-free-slot admission with lazy-aware cost accounting
(scheduler.Scheduler), one jitted *mixed-position* decode step over all
slots (transformer.decode_step_mixed), and eviction on EOS / output budget
/ max_len.  Each request's greedy tokens are identical to decoding it
alone through ``Engine`` (tests/test_serving_scheduler.py); what changes
is request-level throughput, accounted on the service clock (metrics.py).

Per-slot policy state is the TRACED pytree protocol from the fused
trajectory executor (CachePolicy.init_traced_state /
update_traced_state), slot-stacked like the KV/lazy caches: the jitted
step gathers each slot's current plan row from the policy's device plan
by its traced step counter, masks fresh slots, runs the mixed decode,
and advances every slot's state — all under one jit, no host-side
per-slot plan dicts (DESIGN.md §Serve).  Admission scatters the initial
state back into the slot (reset-then-join), exactly like the lazy-cache
reset.  Under an active ``dist.ctx`` mesh the slot axis of every stacked
tree shards over the data axis — one decode lane per shard.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import RequestSpec
from repro.models import transformer as tf
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from repro.serving import metrics as metrics_lib
from repro.serving.scheduler import PendingEntry, Scheduler
from repro.serving.slots import SlotPool

LAZY_MODES = ("off", "masked", "plan")

# default plan horizon for policies with no intrinsic schedule length;
# each policy may override via CachePolicy.plan_horizon (e.g. smoothcache
# serves its full calibrated schedule, stride aligns the horizon to its
# refresh period, explicit plans keep their own length) so row cycling
# never truncates or misaligns a schedule whose length isn't a divisor of
# this default.  Decode steps cycle the rows over the derived horizon.
POLICY_PLAN_STEPS = 16


def _resolve_serving_policy(policy, lazy_mode, plan, cfg):
    """(policy | legacy flags) -> a CachePolicy whose exec_mode serving
    supports.  'soft' is a training mixture, not a serving mode."""
    if policy is None and lazy_mode not in LAZY_MODES:
        raise ValueError(
            f"lazy_mode must be one of {LAZY_MODES}, got {lazy_mode!r}")
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    if pol.exec_mode not in LAZY_MODES:
        raise ValueError(
            f"policy {pol.name!r} drives exec_mode {pol.exec_mode!r}; "
            f"serving supports {LAZY_MODES}")
    return pol


class GenerationResult(NamedTuple):
    tokens: np.ndarray            # (B, prompt + generated)
    scores: Optional[np.ndarray]  # (steps, n_module_kinds) mean probe scores
    realized_lazy_ratio: float


class ServingResult(NamedTuple):
    outputs: Dict[int, np.ndarray]        # rid -> (prompt + generated) int32
    metrics: metrics_lib.ServingMetrics


def _row_skips(row: np.ndarray, attn_like: np.ndarray) -> int:
    """Gated module calls a plan row removes: attn-family layers consume
    both plan columns, single-module (SSM/xLSTM) layers only column 1."""
    return int(row[:, 0][attn_like].sum() + row[:, 1].sum())


def _validate_prompt(prompt, n_new: int, max_len: int) -> np.ndarray:
    prompt = np.asarray(prompt)
    if prompt.ndim != 2:
        raise ValueError(f"prompt must be (B, P), got shape {prompt.shape}")
    if not np.issubdtype(prompt.dtype, np.integer):
        raise ValueError(
            f"prompt must be an integer token array, got dtype {prompt.dtype}")
    if prompt.shape[1] < 1:
        raise ValueError("prompt must contain at least one token per row")
    if prompt.shape[1] + n_new > max_len:
        raise ValueError(
            f"prompt_len {prompt.shape[1]} + n_new {n_new} exceeds "
            f"max_len {max_len}")
    return prompt.astype(np.int32)


class Engine:
    """Static-batch decode engine (one shared position counter).

    Skip/reuse decisions route through one cache policy (repro.cache;
    DESIGN.md §Cache): ``policy`` names or carries it, while the legacy
    (``lazy_mode``: 'off' | 'masked' | 'plan', ``plan``) pair is an alias
    mapped onto a policy.  Plan-driving policies thread their per-step
    boolean rows into the jitted decode step as traced selects (one
    compile; the compile-time FLOP-removing variant lives in
    decode_step_unrolled / benchmarks.bench_compute)."""

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 lazy_mode: str = "off",
                 plan=None,
                 policy=None,
                 window_override: Optional[int] = None):
        self.policy = _resolve_serving_policy(policy, lazy_mode, plan, cfg)
        self.lazy_mode = mode = self.policy.exec_mode
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window_override = window_override
        self._attn_like = metrics_lib.attn_like_mask(
            cfg, window_override=window_override)
        self._modules = metrics_lib.gated_module_calls(
            cfg, window_override=window_override)
        self.plan_horizon = self.policy.plan_horizon(POLICY_PLAN_STEPS)
        if mode == "plan":
            # fail fast on a plan/model shape mismatch (legacy behavior)
            # or a plan-mode policy that compiles no schedule at all
            if self.policy.compile_plan(self.plan_horizon,
                                        cfg.n_layers, 2) is None:
                raise ValueError(
                    f"policy {self.policy.name!r} drives 'plan' mode but "
                    "compiled no plan")
        pol = self.policy

        @functools.partial(jax.jit, static_argnames=())
        def _prefill(params, tokens, cache):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache,
                window_override=window_override)
            return logits, cache

        @functools.partial(jax.jit, static_argnames=("first",))
        def _decode(params, tok, index, cache, lazy_cache, plan_row,
                    first=False):
            logits, cache, lazy_cache, scores = tf.decode_step(
                params, cfg, tok, index, cache, lazy_cache=lazy_cache,
                lazy_mode=mode, lazy_first_step=first, policy=pol,
                plan_row=plan_row, window_override=window_override)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache, lazy_cache, scores

        self._prefill = _prefill
        self._decode = _decode

    def generate(self, prompt: np.ndarray, n_new: int) -> GenerationResult:
        """prompt: (B, P) int32.  Greedy decoding.

        Emission convention (inherited from the seed engine and pinned by
        the continuous-batching parity tests): the prefill's argmax token
        is the first decode *input*; the emitted tokens are the ``n_new``
        decode *outputs*."""
        cfg = self.cfg
        prompt = _validate_prompt(prompt, n_new, self.max_len)
        B, P = prompt.shape
        cache = tf.init_decode_cache(cfg, B, self.max_len,
                                     window_override=self.window_override)
        lazy_cache = None
        if self.lazy_mode != "off":
            lazy_cache = tf.init_lazy_decode_cache(
                cfg, B, window_override=self.window_override)
        # decode schedules are cyclic over the policy-derived horizon so a
        # policy serves IDENTICAL rows through the static and continuous
        # engines — the token-parity contract
        pstate = self.policy.init_state(
            n_steps=self.plan_horizon, n_layers=cfg.n_layers, n_modules=2)
        use_plan = self.lazy_mode == "plan"

        # single-token prompts go through the same prefill path (S==1 decode
        # against the fresh cache): position 0 is written and the first
        # decode step is not special-cased.
        prompt_j = jnp.asarray(prompt, jnp.int32)
        logits, cache = self._prefill(self.params, prompt_j, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        start = P

        toks = [prompt]
        score_log = []
        plan_skips = 0
        for i in range(n_new):
            # the first lazy step primes the cache (runs every module)
            first = self.lazy_mode != "off" and i == 0
            plan_row = None
            if use_plan:
                row = np.asarray(self.policy.plan_row(i, pstate), bool)
                if not first:
                    plan_skips += _row_skips(row, self._attn_like)
                plan_row = jnp.asarray(row)
            nxt, cache, lazy_cache, scores = self._decode(
                self.params, nxt[:, None], jnp.int32(start + i), cache,
                lazy_cache, plan_row, first=first)
            if scores and not first:
                score_log.append(np.array([float(jnp.mean(v))
                                           for v in scores.values()]))
            toks.append(np.asarray(nxt)[:, None])
            pstate = self.policy.update_state(pstate, step=i)

        scores_arr = np.stack(score_log) if score_log else None
        if use_plan:
            ratio = plan_skips / max(self._modules * n_new, 1)
        elif scores_arr is not None:
            ratio = float((scores_arr > self.policy.threshold).mean())
        else:
            ratio = 0.0
        return GenerationResult(np.concatenate(toks, axis=1), scores_arr,
                                float(ratio))


class ContinuousBatchingEngine:
    """Slot-based continuous batching with lazy-aware FCFS scheduling.

    ``batch_synchronous=True`` turns admission into static batching (new
    requests join only when the pool has fully drained) — the baseline
    bench_serving compares against with otherwise identical machinery.
    ``cost_budget`` caps the scheduler's lazy-aware step-cost estimate
    (virtual seconds per decode step); None means slots are the only limit.

    SLO-aware front-door mode: ``policy_bank={class name: plan-compatible
    policy}`` compiles every class's schedule into one (K, H, L, 2) device
    array (H = lcm of the class horizons, so bank rows equal each class's
    own rows exactly) and serves a PER-SLOT policy mix in the same jitted
    step; ``admission=`` (serving/admission.AdmissionController) then
    selects a class per request from its declared SLO/quality budget,
    sheds infeasible requests at admission, and unlocks priority
    preemption (see EngineSession).  Incremental use: ``session()``
    returns an EngineSession whose ``step()`` yields streaming lifecycle
    events — ``run()`` is the batch wrapper around it.

    Observability (repro.obs): ``telemetry=True`` makes the jitted step
    also return per-slot cached-vs-fresh lazy-cache drift
    (obs.telemetry.slot_cache_drift) — the host masks fresh / inactive
    slots and records the step means into ServingMetrics, at zero cost
    and unchanged tokens when off.  ``tracer=`` (an obs.trace.Tracer)
    lands admission / prefill / step / first-token / completion events on
    the virtual service-clock track.
    """

    def __init__(self, cfg: ModelConfig, params: dict, *,
                 n_slots: int = 4, max_len: int = 512,
                 lazy_mode: str = "off", plan=None,
                 policy=None,
                 policy_bank: Optional[Dict[str, object]] = None,
                 admission=None,
                 eos_id: Optional[int] = None,
                 cost_budget: Optional[float] = None,
                 batch_synchronous: bool = False,
                 window_override: Optional[int] = None,
                 telemetry: bool = False,
                 tracer=None):
        if policy_bank is not None and policy is not None:
            raise ValueError("pass either policy= or policy_bank=, not both")
        if admission is not None and policy_bank is None:
            raise ValueError("admission control requires a policy_bank")
        if policy_bank is not None:
            # per-request policy bank: every class must be plan-compatible
            # (off = the all-False plan) so one jitted step serves the whole
            # mix; traced per-slot state is the base step counter, which is
            # all the bank row gather reads
            self.policy = cache_policy.CachePolicy()
            self.lazy_mode = mode = "plan"
        else:
            self.policy = _resolve_serving_policy(policy, lazy_mode, plan,
                                                  cfg)
            self.lazy_mode = mode = self.policy.exec_mode
        self.admission = admission
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cost_budget = cost_budget
        self.batch_synchronous = batch_synchronous
        self.window_override = window_override
        self.telemetry = telemetry
        self.tracer = tracer
        self._attn_like = metrics_lib.attn_like_mask(
            cfg, window_override=window_override)
        self.modules_per_slot = metrics_lib.gated_module_calls(
            cfg, window_override=window_override)
        # slots sit at different request steps t_i, so the policy serves a
        # per-slot row — gathered IN-JIT from the compiled device plan by
        # each slot's traced step counter.  The horizon is policy-derived
        # (plan_horizon) so odd-length schedules cycle without truncation
        # or misalignment; the host-side compiled plan survives only as
        # the scheduler's admission-time skip-budget estimate.
        self._device_plan = None
        self.plan_ratio = 0.0
        self.bank_classes: Tuple[str, ...] = ()
        self.bank_ratios: Dict[str, float] = {}
        self._class_index: Dict[str, int] = {}
        if policy_bank is not None:
            horizon = self._compile_bank(policy_bank)
            self.plan_horizon = horizon
            if admission is not None:
                admission.bind(self.bank_ratios, n_slots)
        else:
            self.plan_horizon = horizon = self.policy.plan_horizon(
                POLICY_PLAN_STEPS)
            if mode == "plan":
                self._device_plan = self.policy.device_plan(
                    horizon, cfg.n_layers, 2)
                if self._device_plan is None:
                    raise ValueError(
                        f"policy {self.policy.name!r} drives 'plan' mode "
                        "but compiled no plan")
                plan_arr = np.asarray(self._device_plan)
                total = self.modules_per_slot * len(plan_arr)
                self.plan_ratio = sum(
                    _row_skips(r, self._attn_like)
                    for r in plan_arr) / max(total, 1)
        self._init_state = self.policy.init_traced_state(
            n_steps=horizon, n_layers=cfg.n_layers, n_modules=2)
        pol = self.policy

        @jax.jit
        def _prefill(params, tokens, cache):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache,
                window_override=window_override)
            return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                    cache)

        @jax.jit
        def _step(params, tok, index, cache, lazy_cache, fresh, slot_state,
                  plan, policy_idx):
            """One mixed-position decode step, policy decisions included:
            per-slot plan rows come from the traced step counters in
            ``slot_state`` (cycled over the policy horizon), fresh slots
            serve all-False rows, and every slot's traced state advances
            via the policy's pure pytree transform (vmapped over the slot
            axis) — the whole per-step decision path is inside this one
            compiled program.  With a policy bank, ``plan`` is (K, H, L, 2)
            and ``policy_idx`` maps each slot to its admission-assigned
            class, so one compiled program serves the whole per-request
            policy mix.  With telemetry on the step additionally reduces
            per-slot lazy-cache drift (repro.obs); off, the drift output
            is None (zero pytree leaves, program unchanged)."""
            rows = None
            if plan is not None:
                step_idx = slot_state["step"] % horizon
                if policy_idx is not None:
                    rows = plan[policy_idx, step_idx]          # (B, L, 2)
                else:
                    rows = plan[step_idx]                      # (B, L, 2)
                if fresh is not None:
                    rows = jnp.where(fresh[:, None, None], False, rows)
            old_lazy_cache = lazy_cache
            logits, cache, lazy_cache, scores = tf.decode_step_mixed(
                params, cfg, tok, index, cache, lazy_cache=lazy_cache,
                lazy_mode=mode, fresh=fresh, plan_rows=rows,
                policy=pol, window_override=window_override)
            if rows is not None:
                new_state = jax.vmap(
                    lambda s, r: pol.update_traced_state(s, plan_row=r))(
                        slot_state, rows)
            else:
                new_state = jax.vmap(
                    lambda s: pol.update_traced_state(s))(slot_state)
            drift = None
            if telemetry and lazy_cache is not None \
                    and old_lazy_cache is not None:
                drift = obs_telemetry.slot_cache_drift(lazy_cache,
                                                       old_lazy_cache)
            return logits, cache, lazy_cache, scores, new_state, rows, drift

        self._prefill = _prefill
        self._step = _step

    # ------------------------------------------------------------ policy bank
    def _compile_bank(self, policy_bank: Dict[str, object]) -> int:
        """Compile {class name: plan-compatible policy} into one
        (K, H, L, 2) bool device array, H = lcm of the per-class horizons.
        Because every class horizon divides H, ``bank[k, t % H]`` equals
        class k's own ``rows[t % h_k]`` at EVERY step t — bank execution
        is exact, not an approximation of the single-policy engines (the
        parity test in tests/test_admission.py pins this).  Realized
        per-class skip ratios land in ``bank_ratios`` for the admission
        controller and the scheduler's cost estimates."""
        cfg = self.cfg
        rows_by_class = []
        for name, p in policy_bank.items():
            p = cache_policy.get_policy(p) if isinstance(p, str) else p
            h = p.plan_horizon(POLICY_PLAN_STEPS)
            if p.exec_mode == "off":
                rows = np.zeros((h, cfg.n_layers, 2), bool)
            elif p.exec_mode == "plan":
                dp = p.device_plan(h, cfg.n_layers, 2)
                if dp is None:
                    raise ValueError(
                        f"bank class {name!r}: policy {p.name!r} drives "
                        "'plan' mode but compiled no plan")
                rows = np.asarray(dp, bool)
            else:
                raise ValueError(
                    f"bank class {name!r}: policy {p.name!r} drives "
                    f"exec_mode {p.exec_mode!r}; a policy bank supports "
                    "'off' and 'plan'")
            rows_by_class.append((name, rows))
        if not rows_by_class:
            raise ValueError("policy_bank is empty")
        H = 1
        for _, rows in rows_by_class:
            H = math.lcm(H, len(rows))
        if H > 4096:
            raise ValueError(
                f"policy bank horizon lcm {H} > 4096; align the per-class "
                "schedule lengths")
        bank = np.zeros((len(rows_by_class), H, cfg.n_layers, 2), bool)
        total = self.modules_per_slot * H
        for k, (name, rows) in enumerate(rows_by_class):
            bank[k] = np.tile(rows, (H // len(rows), 1, 1))
            self.bank_ratios[name] = sum(
                _row_skips(r, self._attn_like) for r in bank[k]
            ) / max(total, 1)
            self._class_index[name] = k
        self.bank_classes = tuple(n for n, _ in rows_by_class)
        self._device_plan = jnp.asarray(bank)
        return H

    def request_ratio(self, req) -> float:
        """Planned skip ratio the engine will serve ``req`` at: its
        admission-assigned bank class's realized ratio, or the engine-wide
        plan ratio outside bank mode."""
        if not self.bank_ratios:
            return self.plan_ratio
        return self.bank_ratios[self._class_of(req)]

    def _class_of(self, req) -> str:
        cls = getattr(req, "policy_class", "") or ""
        return cls if cls in self._class_index else self.bank_classes[0]

    # ------------------------------------------------------------ internals
    def _step_accounting(self, pool: SlotPool, scores, rows
                         ) -> Tuple[float, float]:
        """(executed, skipped) gated module calls for this decode step.
        Plan mode reads the rows the jitted step ACTUALLY served (already
        fresh-masked); masked mode estimates per-slot skips from the
        layer-averaged probe scores (the same statistic Engine's realized
        ratio thresholds)."""
        M = self.modules_per_slot
        executed = skipped = 0.0
        kinds = (["attn", "ffn"] if self._attn_like.any() else [])
        if not self._attn_like.all():
            kinds.append("block")
        thr = self.policy.threshold
        # one device->host transfer per score key, not one per (slot, kind)
        sc = {k: np.asarray(v) for k, v in scores.items()} if scores else {}
        rows_np = np.asarray(rows, bool) if rows is not None else None
        for i in pool.active_slots():
            s = pool.slots[i]
            if self.lazy_mode == "plan" and rows_np is not None:
                k = _row_skips(rows_np[i], self._attn_like)
            elif self.lazy_mode == "masked" and not s.fresh and sc:
                k = M * float(np.mean([sc[k][i] > thr for k in kinds]))
            else:
                k = 0.0
            executed += M - k
            skipped += k
        return executed, skipped

    # ------------------------------------------------------------ main loop
    def session(self) -> "EngineSession":
        """An incremental serving session (the front door pumps this)."""
        return EngineSession(self)

    def run(self, requests: Iterable[RequestSpec]) -> ServingResult:
        """Serve a trace to completion on the virtual service clock."""
        sess = self.session()
        sess.submit(list(requests))
        while sess.has_work():
            sess.step()
        return sess.result()


class StreamEvent(NamedTuple):
    """One observable request-lifecycle event from EngineSession.step().
    ``kind``: shed | policy_assigned | admitted | preempted | resumed |
    token | first_token | done.  The asyncio front door
    (serving/server.py) forwards these to the owning connection as
    streaming chunks; batch callers ignore them."""

    kind: str
    rid: int
    now: float                 # virtual service-clock time of the event
    data: Dict


class EngineSession:
    """Incremental driver of a ContinuousBatchingEngine.

    One ``step()`` = one scheduling round (admission-control the inbox,
    maybe preempt, admit into free slots) plus at most one jitted decode
    step, returning the lifecycle events it produced.  ``run()`` is the
    batch wrapper (submit a trace, pump until drained); the asyncio front
    door pumps a session from its worker thread and streams the events.

    With an admission controller (engine ``admission=`` +
    ``policy_bank=``), submitted requests first land in an arrival-sorted
    inbox; the moment the virtual clock reaches a request's arrival the
    controller either assigns it a policy class (queueing it with its
    class's skip ratio and service estimate) or sheds it — a shed request
    NEVER enters the scheduler queue.  Preemption: when no slot is free
    and a strictly higher-priority request is waiting, the lowest-priority
    active slot is snapshotted (KV + lazy caches + traced policy state +
    host bookkeeping), evicted, and requeued at its original arrival; on
    resume the snapshot is scattered back and the request continues
    BIT-IDENTICALLY (gather-then-scatter is the identity and decode lanes
    are independent), charged one STEP_OVERHEAD swap-in instead of a
    re-prefill.  Without admission control the session reduces exactly to
    the pre-front-door engine loop (same clock, metrics, and tokens).
    """

    def __init__(self, engine: ContinuousBatchingEngine):
        eng = self.engine = engine
        self.lazy = eng.lazy_mode != "off"
        self.sched = Scheduler(eng.n_slots, cost_budget=eng.cost_budget,
                               batch_synchronous=eng.batch_synchronous,
                               tracer=eng.tracer)
        self.pool = SlotPool(eng.cfg, eng.n_slots, eng.max_len,
                             lazy=self.lazy,
                             window_override=eng.window_override)
        # slot-stacked traced policy state, placed like the slot caches
        # (sharded over the data axis under an active mesh)
        self.slot_state = self.pool.place(
            lazy_lib.stack_for_slots(eng._init_state, eng.n_slots))
        eng._slot_state = self.slot_state        # test/debug introspection
        self.met = metrics_lib.ServingMetrics(eng.n_slots,
                                              eng.modules_per_slot)
        self.outputs: Dict[int, np.ndarray] = {}
        self.now = 0.0
        self._inbox: List[RequestSpec] = []      # awaiting admission decision
        self._suspended: Dict[int, Dict] = {}    # rid -> preemption snapshot

    # ------------------------------------------------------------ intake
    def submit(self, requests: Iterable[RequestSpec], *,
               live: bool = False) -> None:
        """Queue requests.  ``live=True`` stamps arrivals at the session's
        current clock (front-door submissions happen "now"; trace-driven
        runs keep their scripted future arrivals)."""
        reqs = list(requests)
        # validate up front: a malformed request must fail fast, not abort
        # the run mid-flight after others completed
        for req in reqs:
            try:
                _validate_prompt(req.prompt[None], 1, self.engine.max_len)
            except ValueError as e:
                raise ValueError(f"request rid={req.rid}: {e}") from e
            if live:
                req.arrival = self.now
        if self.engine.admission is not None:
            self._inbox.extend(reqs)
            self._inbox.sort(key=lambda r: (r.arrival, r.rid))
        elif self.engine.bank_ratios:
            # bank without admission control: classes are caller-assigned
            for req in reqs:
                self.sched.submit([req],
                                  skip_ratio=self.engine.request_ratio(req))
        else:
            self.sched.submit(reqs)

    def has_work(self) -> bool:
        return (bool(self._inbox) or self.sched.has_pending()
                or self.pool.any_active())

    def result(self) -> ServingResult:
        return ServingResult(self.outputs, self.met)

    # ------------------------------------------------------ admission control
    def _process_inbox(self, events: List[StreamEvent]) -> None:
        """Admission-control every inbox request whose arrival the clock
        has reached: assign a policy class or shed IMMEDIATELY — a shed
        request never enters the scheduler queue (the unsatisfiable-SLO
        contract in tests/test_admission.py)."""
        eng = self.engine
        tracer = eng.tracer
        svc_us = obs_trace.Tracer.service_us
        while self._inbox and self._inbox[0].arrival <= self.now + 1e-9:
            req = self._inbox.pop(0)
            # work ahead of THIS request: only pending entries at its
            # priority or above (admission is priority-ordered and higher
            # classes preempt past lower ones)
            wait = self.sched.pending_work(
                self.now, int(getattr(req, "priority", 0))) / eng.n_slots
            dec = eng.admission.decide(req, queue_wait_s=wait)
            if not dec.admitted:
                self.met.record_shed(req.rid, self.now, dec.reason)
                if tracer is not None:
                    tracer.instant(
                        "shed", ts_us=svc_us(self.now),
                        pid=obs_trace.PID_SERVICE, cat="admission",
                        args={"rid": req.rid, "reason": dec.reason,
                              "queue_wait_est": wait,
                              "slo_latency_s": float(getattr(
                                  req, "slo_latency_s", float("inf")))})
                events.append(StreamEvent("shed", req.rid, self.now,
                                          {"reason": dec.reason}))
                continue
            req.policy_class = dec.policy_class
            self.sched.submit_entry(PendingEntry(
                req, priority=int(getattr(req, "priority", 0)),
                skip_ratio=eng.bank_ratios[dec.policy_class],
                est_service_s=dec.est_service_s))
            if tracer is not None:
                tracer.instant(
                    "policy_assigned", ts_us=svc_us(self.now),
                    pid=obs_trace.PID_SERVICE, cat="admission",
                    args={"rid": req.rid, "policy_class": dec.policy_class,
                          "est_service_s": dec.est_service_s,
                          "queue_wait_est": wait})
            events.append(StreamEvent(
                "policy_assigned", req.rid, self.now,
                {"policy_class": dec.policy_class,
                 "est_service_s": dec.est_service_s}))

    # ---------------------------------------------------------- preemption
    def _maybe_preempt(self, events: List[StreamEvent]) -> None:
        """Free a slot for a strictly higher-priority waiter by suspending
        the weakest active slot (at most one per scheduling round, so a
        burst preempts incrementally instead of thrashing the pool)."""
        pool, sched = self.pool, self.sched
        while not pool.free_slots():
            p = sched.preemption_priority(self.now)
            if p is None:
                break
            cand = [(int(getattr(pool.slots[i].req, "priority", 0)),
                     pool.slots[i].produced, pool.slots[i].req.rid, i)
                    for i in pool.active_slots()]
            prio, _, _, victim = min(cand)
            if prio >= p:
                break
            self._preempt(victim, events)

    def _preempt(self, i: int, events: List[StreamEvent]) -> None:
        eng, pool = self.engine, self.pool
        s = pool.slots[i]
        rid = s.req.rid
        kv, lz = pool.snapshot(i)
        self._suspended[rid] = dict(
            kv=kv, lazy=lz,
            pstate=lazy_lib.slot_cache_gather(self.slot_state, i),
            index=s.index, produced=s.produced, t=s.t, fresh=s.fresh,
            last_token=s.last_token, tokens=list(s.tokens))
        ratio = eng.request_ratio(s.req)
        remaining = max(s.req.max_new - s.produced, 0)
        est = remaining * (metrics_lib.STEP_OVERHEAD
                           + metrics_lib.MODULE_COST * (1.0 - ratio))
        # requeue at the ORIGINAL arrival: within its priority class the
        # victim resumes ahead of later arrivals
        self.sched.submit_entry(PendingEntry(
            s.req, priority=int(getattr(s.req, "priority", 0)),
            skip_ratio=ratio, est_service_s=est))
        self.met.record_preemption(rid, self.now)
        if eng.tracer is not None:
            eng.tracer.instant(
                "preempted", ts_us=obs_trace.Tracer.service_us(self.now),
                pid=obs_trace.PID_SERVICE, cat="admission",
                args={"rid": rid, "produced": s.produced,
                      "priority": int(getattr(s.req, "priority", 0))})
        events.append(StreamEvent("preempted", rid, self.now,
                                  {"produced": s.produced}))
        pool.evict(i)

    def _resume(self, i: int, req, events: List[StreamEvent]) -> None:
        cont = self._suspended.pop(req.rid)
        self.pool.restore(i, req, cont["kv"], cont["lazy"],
                          index=cont["index"], produced=cont["produced"],
                          t=cont["t"], fresh=cont["fresh"],
                          last_token=cont["last_token"],
                          tokens=cont["tokens"])
        self.slot_state = lazy_lib.slot_cache_scatter(
            self.slot_state, i, cont["pstate"])
        self.engine._slot_state = self.slot_state
        # swap-in: restoring device state costs one step overhead on the
        # service clock — a state scatter, not a re-prefill
        self.now += metrics_lib.STEP_OVERHEAD
        if self.engine.tracer is not None:
            self.engine.tracer.instant(
                "resumed", ts_us=obs_trace.Tracer.service_us(self.now),
                pid=obs_trace.PID_SERVICE, cat="admission",
                args={"rid": req.rid, "produced": cont["produced"]})
        events.append(StreamEvent("resumed", req.rid, self.now,
                                  {"produced": cont["produced"]}))

    # ------------------------------------------------------------ main step
    def step(self) -> List[StreamEvent]:
        """One scheduling round + at most one jitted decode step."""
        eng = self.engine
        tracer = eng.tracer
        svc_us = obs_trace.Tracer.service_us
        pool, sched, met = self.pool, self.sched, self.met
        events: List[StreamEvent] = []

        if not pool.any_active():
            arrivals = [a for a in (
                sched.next_arrival(),
                self._inbox[0].arrival if self._inbox else None)
                if a is not None]
            if arrivals and min(arrivals) > self.now:
                self.now = min(arrivals)          # idle: jump to next arrival

        if eng.admission is not None:
            self._process_inbox(events)
            self._maybe_preempt(events)

        free = pool.free_slots()
        n_active = eng.n_slots - len(free)
        active_ratios = ([eng.request_ratio(pool.slots[i].req)
                          for i in pool.active_slots()]
                         if eng.bank_ratios
                         else [eng.plan_ratio] * n_active)
        admitted = sched.admit(self.now, len(free), active_ratios,
                               eng.plan_ratio)
        for req in admitted:
            i = free.pop(0)
            if req.rid in self._suspended:
                self._resume(i, req, events)
                continue
            # the prompt plus one decode step must fit; an output budget
            # beyond max_len is truncated by eviction, not rejected
            prompt = _validate_prompt(req.prompt[None], 1, eng.max_len)
            cache1 = tf.init_decode_cache(
                eng.cfg, 1, eng.max_len,
                window_override=eng.window_override)
            tok0, cache1 = eng._prefill(
                eng.params, jnp.asarray(prompt, jnp.int32), cache1)
            t_prefill = self.now
            self.now += metrics_lib.prefill_cost(prompt.shape[1],
                                                 eng.n_slots)
            if tracer is not None:
                tracer.complete(
                    "prefill", svc_us(t_prefill),
                    svc_us(self.now - t_prefill),
                    pid=obs_trace.PID_SERVICE, cat="serve",
                    args={"rid": req.rid,
                          "prompt_len": int(prompt.shape[1])})
            pool.admit(i, req, cache1, int(tok0[0]))
            # reset-then-join: the new occupant starts from the policy's
            # initial traced state, same rule as the lazy cache (a slot
            # must never inherit its predecessor's step counter or
            # reuse-run lengths)
            self.slot_state = lazy_lib.slot_cache_scatter(
                self.slot_state, i, eng._init_state)
            # SLO bookkeeping follows what the REQUEST declares, not the
            # engine mode: a fixed-policy engine serving an SLO trace is
            # judged against the same per-request deadlines and quality
            # budgets (the bench's fixed-vs-SLO-aware comparison); plain
            # requests keep the legacy defaults
            slo = getattr(req, "slo_latency_s", None)
            budget = getattr(req, "max_skip_ratio", None)
            met.record_admit(
                req.rid, req.arrival, self.now, prompt.shape[1],
                prefill_s=self.now - t_prefill,
                slo_latency_s=None if slo is None else float(slo),
                quality_ok=(budget is None
                            or eng.request_ratio(req)
                            <= float(budget) + 1e-9),
                policy_class=getattr(req, "policy_class", ""),
                priority=int(getattr(req, "priority", 0)))
            events.append(StreamEvent(
                "admitted", req.rid, self.now,
                {"policy_class": getattr(req, "policy_class", "")}))
            # empty output budget, or the model's very first greedy token
            # is EOS (a naturally empty response): complete now
            if req.max_new <= 0 or (eng.eos_id is not None
                                    and int(tok0[0]) == eng.eos_id):
                self.outputs[req.rid] = np.asarray(req.prompt, np.int32)
                met.record_completion(req.rid, self.now, 0)
                pool.evict(i)
                events.append(StreamEvent("done", req.rid, self.now,
                                          {"n_out": 0, "tokens": []}))

        active = pool.active_slots()
        if not active:
            return events

        fresh = pool.fresh_vector() if self.lazy else None
        policy_idx = None
        if eng.bank_ratios:
            policy_idx = jnp.asarray(
                [eng._class_index[eng._class_of(s.req)] if s.active else 0
                 for s in pool.slots], jnp.int32)
        (logits, cache, lazy_cache, scores, self.slot_state, rows,
         drift) = eng._step(
            eng.params, pool.token_vector(), pool.index_vector(),
            pool.cache, pool.lazy_cache, fresh, self.slot_state,
            eng._device_plan, policy_idx)
        eng._slot_state = self.slot_state
        pool.cache = cache
        if self.lazy:
            pool.lazy_cache = lazy_cache
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)

        # per-slot drift means over ESTABLISHED active slots: a fresh
        # slot's cache was just primed (its "old" entries are the reset
        # values), an inactive slot's holds a stale occupant — neither
        # measures cached-vs-fresh drift
        drift_rel = drift_cos = None
        if drift is not None:
            fresh_np = np.asarray(fresh, bool)
            established = [i for i in active if not fresh_np[i]]
            if established:
                cos_np, rel_np = (np.asarray(d, np.float64)
                                  for d in drift)
                drift_cos = float(cos_np[established].mean())
                drift_rel = float(rel_np[established].mean())

        t_step = self.now
        executed, skipped = eng._step_accounting(pool, scores, rows)
        self.now += metrics_lib.step_cost(executed, eng.n_slots,
                                          eng.modules_per_slot)
        met.record_step(self.now, len(active), sched.queue_depth(),
                        executed, skipped, len(active),
                        drift_rel=drift_rel, drift_cos=drift_cos)
        if tracer is not None:
            args = {"n_active": len(active),
                    "executed": executed, "skipped": skipped}
            if drift_rel is not None:
                args["drift_rel_l2"] = drift_rel
            tracer.complete("decode_step", svc_us(t_step),
                            svc_us(self.now - t_step),
                            pid=obs_trace.PID_SERVICE, cat="serve",
                            args=args)
            tracer.counter("pool", {"active": len(active),
                                    "queue_depth": sched.queue_depth()},
                           ts_us=svc_us(self.now),
                           pid=obs_trace.PID_SERVICE)

        for i in active:
            pool.advance(i, nxt[i])
            s = pool.slots[i]
            events.append(StreamEvent("token", s.req.rid, self.now,
                                      {"token": int(nxt[i]),
                                       "n": s.produced}))
            if s.produced == 1:
                met.record_first_token(s.req.rid, self.now)
                if tracer is not None:
                    tracer.instant("first_token", ts_us=svc_us(self.now),
                                   pid=obs_trace.PID_SERVICE,
                                   cat="serve", args={"rid": s.req.rid})
                events.append(StreamEvent("first_token", s.req.rid,
                                          self.now, {}))
            if (pool.should_evict(i)
                    or (eng.eos_id is not None
                        and int(nxt[i]) == eng.eos_id)):
                self.outputs[s.req.rid] = np.concatenate(
                    [np.asarray(s.req.prompt, np.int32),
                     np.asarray(s.tokens, np.int32)])
                met.record_completion(s.req.rid, self.now, s.produced)
                if tracer is not None:
                    tracer.instant("completed", ts_us=svc_us(self.now),
                                   pid=obs_trace.PID_SERVICE,
                                   cat="serve",
                                   args={"rid": s.req.rid,
                                         "n_out": s.produced})
                events.append(StreamEvent(
                    "done", s.req.rid, self.now,
                    {"n_out": s.produced, "tokens": list(s.tokens)}))
                pool.evict(i)
        return events
