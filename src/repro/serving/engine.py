"""Batched serving engine: one-shot prefill + jitted decode loop with
optional LazyDiT-style lazy decode (masked or planned)."""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf

Array = jax.Array


class GenerationResult(NamedTuple):
    tokens: np.ndarray            # (B, prompt + generated)
    scores: Optional[np.ndarray]  # (steps, n_module_kinds) mean probe scores
    realized_lazy_ratio: float


class Engine:
    """Static-batch decode engine.

    All sequences in a batch share one position counter (standard static
    batching; continuous batching is out of scope for the dry-run target).
    ``lazy_mode``: 'off' | 'masked' (per-sample select, faithful semantics)
    — 'plan' mode lives in the unrolled benchmark path (benchmarks/bench_compute).
    """

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 lazy_mode: str = "off",
                 window_override: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.lazy_mode = lazy_mode
        self.window_override = window_override

        @functools.partial(jax.jit, static_argnames=())
        def _prefill(params, tokens, cache):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache,
                window_override=window_override)
            return logits, cache

        @functools.partial(jax.jit, static_argnames=("first",))
        def _decode(params, tok, index, cache, lazy_cache, first=False):
            logits, cache, lazy_cache, scores = tf.decode_step(
                params, cfg, tok, index, cache, lazy_cache=lazy_cache,
                lazy_mode=lazy_mode, lazy_first_step=first,
                window_override=window_override)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache, lazy_cache, scores

        self._prefill = _prefill
        self._decode = _decode

    def generate(self, prompt: np.ndarray, n_new: int, key=None
                 ) -> GenerationResult:
        """prompt: (B, P) int32.  Greedy decoding."""
        cfg = self.cfg
        B, P = prompt.shape
        assert P + n_new <= self.max_len
        key = key if key is not None else jax.random.PRNGKey(0)
        cache = tf.init_decode_cache(cfg, B, self.max_len,
                                     window_override=self.window_override)
        lazy_cache = None
        if self.lazy_mode != "off":
            lazy_cache = tf.init_lazy_decode_cache(
                cfg, B, window_override=self.window_override)

        prompt_j = jnp.asarray(prompt, jnp.int32)
        if P > 1:
            logits, cache = self._prefill(self.params, prompt_j, cache)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            start = P
        else:
            nxt = prompt_j[:, 0]
            start = P if P else 0

        toks = [prompt]
        score_log = []
        for i in range(n_new):
            # the first lazy step primes the cache (runs every module)
            first = self.lazy_mode != "off" and i == 0
            nxt, cache, lazy_cache, scores = self._decode(
                self.params, nxt[:, None], jnp.int32(start + i), cache,
                lazy_cache, first=first)
            if scores and not first:
                score_log.append(np.array([float(jnp.mean(v))
                                           for v in scores.values()]))
            toks.append(np.asarray(nxt)[:, None])

        scores_arr = np.stack(score_log) if score_log else None
        ratio = float((scores_arr > self.cfg.lazy.threshold).mean()) \
            if scores_arr is not None else 0.0
        return GenerationResult(np.concatenate(toks, axis=1), scores_arr, ratio)
