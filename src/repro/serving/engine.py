"""Serving engines.

``Engine`` — static batch: all sequences share one position counter, one
prefill + jitted decode loop.  Skip/reuse decisions route through one
cache policy (repro.cache; DESIGN.md §Cache) — pass ``policy=`` directly,
or the legacy lazy modes 'off' | 'masked' (per-sample select) | 'plan'
(boolean rows threaded into the decode step as traced per-step selects),
which map onto the `none` / `lazy_gate` / `plan` policies.

``ContinuousBatchingEngine`` — slot-based continuous batching: a fixed
pool of decode lanes over shared stacked caches (slots.SlotPool), FCFS
join-on-free-slot admission with lazy-aware cost accounting
(scheduler.Scheduler), one jitted *mixed-position* decode step over all
slots (transformer.decode_step_mixed), and eviction on EOS / output budget
/ max_len.  Each request's greedy tokens are identical to decoding it
alone through ``Engine`` (tests/test_serving_scheduler.py); what changes
is request-level throughput, accounted on the service clock (metrics.py).

Per-slot policy state is the TRACED pytree protocol from the fused
trajectory executor (CachePolicy.init_traced_state /
update_traced_state), slot-stacked like the KV/lazy caches: the jitted
step gathers each slot's current plan row from the policy's device plan
by its traced step counter, masks fresh slots, runs the mixed decode,
and advances every slot's state — all under one jit, no host-side
per-slot plan dicts (DESIGN.md §Serve).  Admission scatters the initial
state back into the slot (reset-then-join), exactly like the lazy-cache
reset.  Under an active ``dist.ctx`` mesh the slot axis of every stacked
tree shards over the data axis — one decode lane per shard.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import RequestSpec
from repro.models import transformer as tf
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from repro.serving import metrics as metrics_lib
from repro.serving.scheduler import Scheduler
from repro.serving.slots import SlotPool

LAZY_MODES = ("off", "masked", "plan")

# default plan horizon for policies with no intrinsic schedule length;
# each policy may override via CachePolicy.plan_horizon (e.g. smoothcache
# serves its full calibrated schedule, stride aligns the horizon to its
# refresh period, explicit plans keep their own length) so row cycling
# never truncates or misaligns a schedule whose length isn't a divisor of
# this default.  Decode steps cycle the rows over the derived horizon.
POLICY_PLAN_STEPS = 16


def _resolve_serving_policy(policy, lazy_mode, plan, cfg):
    """(policy | legacy flags) -> a CachePolicy whose exec_mode serving
    supports.  'soft' is a training mixture, not a serving mode."""
    if policy is None and lazy_mode not in LAZY_MODES:
        raise ValueError(
            f"lazy_mode must be one of {LAZY_MODES}, got {lazy_mode!r}")
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    if pol.exec_mode not in LAZY_MODES:
        raise ValueError(
            f"policy {pol.name!r} drives exec_mode {pol.exec_mode!r}; "
            f"serving supports {LAZY_MODES}")
    return pol


class GenerationResult(NamedTuple):
    tokens: np.ndarray            # (B, prompt + generated)
    scores: Optional[np.ndarray]  # (steps, n_module_kinds) mean probe scores
    realized_lazy_ratio: float


class ServingResult(NamedTuple):
    outputs: Dict[int, np.ndarray]        # rid -> (prompt + generated) int32
    metrics: metrics_lib.ServingMetrics


def _row_skips(row: np.ndarray, attn_like: np.ndarray) -> int:
    """Gated module calls a plan row removes: attn-family layers consume
    both plan columns, single-module (SSM/xLSTM) layers only column 1."""
    return int(row[:, 0][attn_like].sum() + row[:, 1].sum())


def _validate_prompt(prompt, n_new: int, max_len: int) -> np.ndarray:
    prompt = np.asarray(prompt)
    if prompt.ndim != 2:
        raise ValueError(f"prompt must be (B, P), got shape {prompt.shape}")
    if not np.issubdtype(prompt.dtype, np.integer):
        raise ValueError(
            f"prompt must be an integer token array, got dtype {prompt.dtype}")
    if prompt.shape[1] < 1:
        raise ValueError("prompt must contain at least one token per row")
    if prompt.shape[1] + n_new > max_len:
        raise ValueError(
            f"prompt_len {prompt.shape[1]} + n_new {n_new} exceeds "
            f"max_len {max_len}")
    return prompt.astype(np.int32)


class Engine:
    """Static-batch decode engine (one shared position counter).

    Skip/reuse decisions route through one cache policy (repro.cache;
    DESIGN.md §Cache): ``policy`` names or carries it, while the legacy
    (``lazy_mode``: 'off' | 'masked' | 'plan', ``plan``) pair is an alias
    mapped onto a policy.  Plan-driving policies thread their per-step
    boolean rows into the jitted decode step as traced selects (one
    compile; the compile-time FLOP-removing variant lives in
    decode_step_unrolled / benchmarks.bench_compute)."""

    def __init__(self, cfg: ModelConfig, params: dict, max_len: int = 512,
                 lazy_mode: str = "off",
                 plan=None,
                 policy=None,
                 window_override: Optional[int] = None):
        self.policy = _resolve_serving_policy(policy, lazy_mode, plan, cfg)
        self.lazy_mode = mode = self.policy.exec_mode
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window_override = window_override
        self._attn_like = metrics_lib.attn_like_mask(
            cfg, window_override=window_override)
        self._modules = metrics_lib.gated_module_calls(
            cfg, window_override=window_override)
        self.plan_horizon = self.policy.plan_horizon(POLICY_PLAN_STEPS)
        if mode == "plan":
            # fail fast on a plan/model shape mismatch (legacy behavior)
            # or a plan-mode policy that compiles no schedule at all
            if self.policy.compile_plan(self.plan_horizon,
                                        cfg.n_layers, 2) is None:
                raise ValueError(
                    f"policy {self.policy.name!r} drives 'plan' mode but "
                    "compiled no plan")
        pol = self.policy

        @functools.partial(jax.jit, static_argnames=())
        def _prefill(params, tokens, cache):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache,
                window_override=window_override)
            return logits, cache

        @functools.partial(jax.jit, static_argnames=("first",))
        def _decode(params, tok, index, cache, lazy_cache, plan_row,
                    first=False):
            logits, cache, lazy_cache, scores = tf.decode_step(
                params, cfg, tok, index, cache, lazy_cache=lazy_cache,
                lazy_mode=mode, lazy_first_step=first, policy=pol,
                plan_row=plan_row, window_override=window_override)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache, lazy_cache, scores

        self._prefill = _prefill
        self._decode = _decode

    def generate(self, prompt: np.ndarray, n_new: int) -> GenerationResult:
        """prompt: (B, P) int32.  Greedy decoding.

        Emission convention (inherited from the seed engine and pinned by
        the continuous-batching parity tests): the prefill's argmax token
        is the first decode *input*; the emitted tokens are the ``n_new``
        decode *outputs*."""
        cfg = self.cfg
        prompt = _validate_prompt(prompt, n_new, self.max_len)
        B, P = prompt.shape
        cache = tf.init_decode_cache(cfg, B, self.max_len,
                                     window_override=self.window_override)
        lazy_cache = None
        if self.lazy_mode != "off":
            lazy_cache = tf.init_lazy_decode_cache(
                cfg, B, window_override=self.window_override)
        # decode schedules are cyclic over the policy-derived horizon so a
        # policy serves IDENTICAL rows through the static and continuous
        # engines — the token-parity contract
        pstate = self.policy.init_state(
            n_steps=self.plan_horizon, n_layers=cfg.n_layers, n_modules=2)
        use_plan = self.lazy_mode == "plan"

        # single-token prompts go through the same prefill path (S==1 decode
        # against the fresh cache): position 0 is written and the first
        # decode step is not special-cased.
        prompt_j = jnp.asarray(prompt, jnp.int32)
        logits, cache = self._prefill(self.params, prompt_j, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        start = P

        toks = [prompt]
        score_log = []
        plan_skips = 0
        for i in range(n_new):
            # the first lazy step primes the cache (runs every module)
            first = self.lazy_mode != "off" and i == 0
            plan_row = None
            if use_plan:
                row = np.asarray(self.policy.plan_row(i, pstate), bool)
                if not first:
                    plan_skips += _row_skips(row, self._attn_like)
                plan_row = jnp.asarray(row)
            nxt, cache, lazy_cache, scores = self._decode(
                self.params, nxt[:, None], jnp.int32(start + i), cache,
                lazy_cache, plan_row, first=first)
            if scores and not first:
                score_log.append(np.array([float(jnp.mean(v))
                                           for v in scores.values()]))
            toks.append(np.asarray(nxt)[:, None])
            pstate = self.policy.update_state(pstate, step=i)

        scores_arr = np.stack(score_log) if score_log else None
        if use_plan:
            ratio = plan_skips / max(self._modules * n_new, 1)
        elif scores_arr is not None:
            ratio = float((scores_arr > self.policy.threshold).mean())
        else:
            ratio = 0.0
        return GenerationResult(np.concatenate(toks, axis=1), scores_arr,
                                float(ratio))


class ContinuousBatchingEngine:
    """Slot-based continuous batching with lazy-aware FCFS scheduling.

    ``batch_synchronous=True`` turns admission into static batching (new
    requests join only when the pool has fully drained) — the baseline
    bench_serving compares against with otherwise identical machinery.
    ``cost_budget`` caps the scheduler's lazy-aware step-cost estimate
    (virtual seconds per decode step); None means slots are the only limit.

    Observability (repro.obs): ``telemetry=True`` makes the jitted step
    also return per-slot cached-vs-fresh lazy-cache drift
    (obs.telemetry.slot_cache_drift) — the host masks fresh / inactive
    slots and records the step means into ServingMetrics, at zero cost
    and unchanged tokens when off.  ``tracer=`` (an obs.trace.Tracer)
    lands admission / prefill / step / first-token / completion events on
    the virtual service-clock track.
    """

    def __init__(self, cfg: ModelConfig, params: dict, *,
                 n_slots: int = 4, max_len: int = 512,
                 lazy_mode: str = "off", plan=None,
                 policy=None,
                 eos_id: Optional[int] = None,
                 cost_budget: Optional[float] = None,
                 batch_synchronous: bool = False,
                 window_override: Optional[int] = None,
                 telemetry: bool = False,
                 tracer=None):
        self.policy = _resolve_serving_policy(policy, lazy_mode, plan, cfg)
        self.lazy_mode = mode = self.policy.exec_mode
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cost_budget = cost_budget
        self.batch_synchronous = batch_synchronous
        self.window_override = window_override
        self.telemetry = telemetry
        self.tracer = tracer
        self._attn_like = metrics_lib.attn_like_mask(
            cfg, window_override=window_override)
        self.modules_per_slot = metrics_lib.gated_module_calls(
            cfg, window_override=window_override)
        # slots sit at different request steps t_i, so the policy serves a
        # per-slot row — gathered IN-JIT from the compiled device plan by
        # each slot's traced step counter.  The horizon is policy-derived
        # (plan_horizon) so odd-length schedules cycle without truncation
        # or misalignment; the host-side compiled plan survives only as
        # the scheduler's admission-time skip-budget estimate.
        self.plan_horizon = horizon = self.policy.plan_horizon(
            POLICY_PLAN_STEPS)
        self._init_state = self.policy.init_traced_state(
            n_steps=horizon, n_layers=cfg.n_layers, n_modules=2)
        self._device_plan = None
        self.plan_ratio = 0.0
        if mode == "plan":
            self._device_plan = self.policy.device_plan(
                horizon, cfg.n_layers, 2)
            if self._device_plan is None:
                raise ValueError(
                    f"policy {self.policy.name!r} drives 'plan' mode but "
                    "compiled no plan")
            plan_arr = np.asarray(self._device_plan)
            total = self.modules_per_slot * len(plan_arr)
            self.plan_ratio = sum(
                _row_skips(r, self._attn_like) for r in plan_arr) / max(total, 1)
        pol = self.policy

        @jax.jit
        def _prefill(params, tokens, cache):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache,
                window_override=window_override)
            return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                    cache)

        @jax.jit
        def _step(params, tok, index, cache, lazy_cache, fresh, slot_state,
                  plan):
            """One mixed-position decode step, policy decisions included:
            per-slot plan rows come from the traced step counters in
            ``slot_state`` (cycled over the policy horizon), fresh slots
            serve all-False rows, and every slot's traced state advances
            via the policy's pure pytree transform (vmapped over the slot
            axis) — the whole per-step decision path is inside this one
            compiled program.  With telemetry on the step additionally
            reduces per-slot lazy-cache drift (repro.obs); off, the drift
            output is None (zero pytree leaves, program unchanged)."""
            rows = None
            if plan is not None:
                rows = plan[slot_state["step"] % horizon]      # (B, L, 2)
                if fresh is not None:
                    rows = jnp.where(fresh[:, None, None], False, rows)
            old_lazy_cache = lazy_cache
            logits, cache, lazy_cache, scores = tf.decode_step_mixed(
                params, cfg, tok, index, cache, lazy_cache=lazy_cache,
                lazy_mode=mode, fresh=fresh, plan_rows=rows,
                policy=pol, window_override=window_override)
            if rows is not None:
                new_state = jax.vmap(
                    lambda s, r: pol.update_traced_state(s, plan_row=r))(
                        slot_state, rows)
            else:
                new_state = jax.vmap(
                    lambda s: pol.update_traced_state(s))(slot_state)
            drift = None
            if telemetry and lazy_cache is not None \
                    and old_lazy_cache is not None:
                drift = obs_telemetry.slot_cache_drift(lazy_cache,
                                                       old_lazy_cache)
            return logits, cache, lazy_cache, scores, new_state, rows, drift

        self._prefill = _prefill
        self._step = _step

    # ------------------------------------------------------------ internals
    def _step_accounting(self, pool: SlotPool, scores, rows
                         ) -> Tuple[float, float]:
        """(executed, skipped) gated module calls for this decode step.
        Plan mode reads the rows the jitted step ACTUALLY served (already
        fresh-masked); masked mode estimates per-slot skips from the
        layer-averaged probe scores (the same statistic Engine's realized
        ratio thresholds)."""
        M = self.modules_per_slot
        executed = skipped = 0.0
        kinds = (["attn", "ffn"] if self._attn_like.any() else [])
        if not self._attn_like.all():
            kinds.append("block")
        thr = self.policy.threshold
        # one device->host transfer per score key, not one per (slot, kind)
        sc = {k: np.asarray(v) for k, v in scores.items()} if scores else {}
        rows_np = np.asarray(rows, bool) if rows is not None else None
        for i in pool.active_slots():
            s = pool.slots[i]
            if self.lazy_mode == "plan" and rows_np is not None:
                k = _row_skips(rows_np[i], self._attn_like)
            elif self.lazy_mode == "masked" and not s.fresh and sc:
                k = M * float(np.mean([sc[k][i] > thr for k in kinds]))
            else:
                k = 0.0
            executed += M - k
            skipped += k
        return executed, skipped

    # ------------------------------------------------------------ main loop
    def run(self, requests: Iterable[RequestSpec]) -> ServingResult:
        """Serve a trace to completion on the virtual service clock."""
        lazy = self.lazy_mode != "off"
        requests = list(requests)
        # validate the whole trace up front: a malformed request must fail
        # fast, not abort the run mid-flight after others completed
        for req in requests:
            try:
                _validate_prompt(req.prompt[None], 1, self.max_len)
            except ValueError as e:
                raise ValueError(f"request rid={req.rid}: {e}") from e
        sched = Scheduler(self.n_slots, cost_budget=self.cost_budget,
                          batch_synchronous=self.batch_synchronous,
                          tracer=self.tracer)
        sched.submit(requests)
        tracer = self.tracer
        svc_us = obs_trace.Tracer.service_us
        pool = SlotPool(self.cfg, self.n_slots, self.max_len, lazy=lazy,
                        window_override=self.window_override)
        # slot-stacked traced policy state, placed like the slot caches
        # (sharded over the data axis under an active mesh)
        slot_state = pool.place(
            lazy_lib.stack_for_slots(self._init_state, self.n_slots))
        self._slot_state = slot_state            # test/debug introspection
        met = metrics_lib.ServingMetrics(self.n_slots, self.modules_per_slot)
        outputs: Dict[int, np.ndarray] = {}
        now = 0.0

        while sched.has_pending() or pool.any_active():
            if not pool.any_active():
                na = sched.next_arrival()
                if na is not None and na > now:
                    now = na                      # idle: jump to next arrival

            free = pool.free_slots()
            n_active = self.n_slots - len(free)
            admitted = sched.admit(now, len(free),
                                   [self.plan_ratio] * n_active,
                                   self.plan_ratio)
            for req in admitted:
                # the prompt plus one decode step must fit; an output budget
                # beyond max_len is truncated by eviction, not rejected
                prompt = _validate_prompt(req.prompt[None], 1, self.max_len)
                cache1 = tf.init_decode_cache(
                    self.cfg, 1, self.max_len,
                    window_override=self.window_override)
                tok0, cache1 = self._prefill(
                    self.params, jnp.asarray(prompt, jnp.int32), cache1)
                t_prefill = now
                now += metrics_lib.prefill_cost(prompt.shape[1], self.n_slots)
                if tracer is not None:
                    tracer.complete(
                        "prefill", svc_us(t_prefill), svc_us(now - t_prefill),
                        pid=obs_trace.PID_SERVICE, cat="serve",
                        args={"rid": req.rid,
                              "prompt_len": int(prompt.shape[1])})
                i = free.pop(0)
                pool.admit(i, req, cache1, int(tok0[0]))
                # reset-then-join: the new occupant starts from the
                # policy's initial traced state, same rule as the lazy
                # cache (a slot must never inherit its predecessor's step
                # counter or reuse-run lengths)
                slot_state = lazy_lib.slot_cache_scatter(
                    slot_state, i, self._init_state)
                met.record_admit(req.rid, req.arrival, now, prompt.shape[1],
                                 prefill_s=now - t_prefill)
                # empty output budget, or the model's very first greedy
                # token is EOS (a naturally empty response): complete now
                if req.max_new <= 0 or (self.eos_id is not None
                                        and int(tok0[0]) == self.eos_id):
                    outputs[req.rid] = np.asarray(req.prompt, np.int32)
                    met.record_completion(req.rid, now, 0)
                    pool.evict(i)

            active = pool.active_slots()
            if not active:
                continue

            fresh = pool.fresh_vector() if lazy else None
            (logits, cache, lazy_cache, scores, slot_state, rows,
             drift) = self._step(
                self.params, pool.token_vector(), pool.index_vector(),
                pool.cache, pool.lazy_cache, fresh, slot_state,
                self._device_plan)
            self._slot_state = slot_state
            pool.cache = cache
            if lazy:
                pool.lazy_cache = lazy_cache
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)

            # per-slot drift means over ESTABLISHED active slots: a fresh
            # slot's cache was just primed (its "old" entries are the reset
            # values), an inactive slot's holds a stale occupant — neither
            # measures cached-vs-fresh drift
            drift_rel = drift_cos = None
            if drift is not None:
                fresh_np = np.asarray(fresh, bool)
                established = [i for i in active if not fresh_np[i]]
                if established:
                    cos_np, rel_np = (np.asarray(d, np.float64)
                                      for d in drift)
                    drift_cos = float(cos_np[established].mean())
                    drift_rel = float(rel_np[established].mean())

            t_step = now
            executed, skipped = self._step_accounting(pool, scores, rows)
            now += metrics_lib.step_cost(executed, self.n_slots,
                                         self.modules_per_slot)
            met.record_step(now, len(active), sched.queue_depth(),
                            executed, skipped, len(active),
                            drift_rel=drift_rel, drift_cos=drift_cos)
            if tracer is not None:
                args = {"n_active": len(active),
                        "executed": executed, "skipped": skipped}
                if drift_rel is not None:
                    args["drift_rel_l2"] = drift_rel
                tracer.complete("decode_step", svc_us(t_step),
                                svc_us(now - t_step),
                                pid=obs_trace.PID_SERVICE, cat="serve",
                                args=args)
                tracer.counter("pool", {"active": len(active),
                                        "queue_depth": sched.queue_depth()},
                               ts_us=svc_us(now), pid=obs_trace.PID_SERVICE)

            for i in active:
                pool.advance(i, nxt[i])
                s = pool.slots[i]
                if s.produced == 1:
                    met.record_first_token(s.req.rid, now)
                    if tracer is not None:
                        tracer.instant("first_token", ts_us=svc_us(now),
                                       pid=obs_trace.PID_SERVICE,
                                       cat="serve", args={"rid": s.req.rid})
                if (pool.should_evict(i)
                        or (self.eos_id is not None
                            and int(nxt[i]) == self.eos_id)):
                    outputs[s.req.rid] = np.concatenate(
                        [np.asarray(s.req.prompt, np.int32),
                         np.asarray(s.tokens, np.int32)])
                    met.record_completion(s.req.rid, now, s.produced)
                    if tracer is not None:
                        tracer.instant("completed", ts_us=svc_us(now),
                                       pid=obs_trace.PID_SERVICE,
                                       cat="serve",
                                       args={"rid": s.req.rid,
                                             "n_out": s.produced})
                    pool.evict(i)

        return ServingResult(outputs, met)
