"""Asyncio streaming front door for the continuous-batching engine.

The production request path (ROADMAP item 3): an asyncio TCP server
speaking newline-delimited JSON (NDJSON — stdlib only, no HTTP framework
in the container) that streams per-token chunks from an EngineSession.

Threading model — the engine is synchronous JAX, the front door is
asyncio, and they meet at exactly two seams:

  * submissions flow front door -> engine through a thread-safe
    ``queue.Queue`` drained by the session worker thread;
  * lifecycle events flow engine -> front door through
    ``loop.call_soon_threadsafe`` onto per-request ``asyncio.Queue``s.

The worker thread owns the EngineSession outright (slot pool, scheduler,
device caches); the asyncio side never touches engine state, so there is
no lock around jitted steps and a slow step never blocks accepting
connections.  The session runs with ``live=True`` submissions: a request's
virtual arrival is stamped when the worker picks it up, so admission
control, priority preemption, and load shedding behave exactly as in the
trace-driven benchmarks.

Wire protocol (one JSON object per line):

  -> {"prompt": [3, 1, 4], "max_new": 8,
      "slo_latency_s": 9.0, "max_skip_ratio": 0.9, "priority": 2}
  <- {"event": "accepted", "rid": 0}
  <- {"event": "policy_assigned", "rid": 0, "policy_class": "latency", ...}
  <- {"event": "token", "rid": 0, "token": 17, "n": 1}
  ...
  <- {"event": "done", "rid": 0, "tokens": [...], "n_out": 8}

A shed request ends with {"event": "shed", "reason": ...} instead of
"done".  ``{"op": "stats"}`` returns one JSON line of server statistics
(including wall-clock first-chunk latency percentiles — the CI smoke
asserts these are recorded); ``{"op": "shutdown"}`` stops the server.
"""
from __future__ import annotations

import asyncio
import json
import queue
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.data.synthetic import SLORequestSpec

# event kinds that terminate a request's stream
_TERMINAL = ("done", "shed")
# worker idle poll: how long to block on the submit queue when the
# session has no work (keeps shutdown latency bounded without spinning)
_IDLE_POLL_S = 0.05


def _to_payload(ev) -> Dict:
    out = {"event": ev.kind, "rid": ev.rid, "t_service": ev.now}
    out.update(ev.data)
    return out


class _SessionWorker(threading.Thread):
    """Owns the EngineSession: drains submissions, pumps ``step()``, and
    posts lifecycle events back to the asyncio loop thread-safely."""

    def __init__(self, session, loop: asyncio.AbstractEventLoop):
        super().__init__(name="engine-session", daemon=True)
        self.session = session
        self.loop = loop
        self.submissions: "queue.Queue" = queue.Queue()
        self._halt = threading.Event()
        # rid -> asyncio.Queue living on the loop thread; mutated only
        # via register() (loop thread, before submit) and _dispatch
        # (posted back onto the loop thread), so never concurrently
        self.streams: Dict[int, asyncio.Queue] = {}
        self.error: Optional[BaseException] = None

    def register(self, rid: int, stream: asyncio.Queue) -> None:
        self.streams[rid] = stream

    def submit(self, req: SLORequestSpec) -> None:
        self.submissions.put(req)

    def stop(self) -> None:
        self._halt.set()

    # ----------------------------------------------------------- worker side
    def _drain_submissions(self) -> List[SLORequestSpec]:
        out = []
        try:
            while True:
                out.append(self.submissions.get_nowait())
        except queue.Empty:
            return out

    def _post(self, payloads: List[Dict]) -> None:
        def deliver():
            for p in payloads:
                stream = self.streams.get(p["rid"])
                if stream is not None:
                    stream.put_nowait(p)
                if p["event"] in _TERMINAL:
                    self.streams.pop(p["rid"], None)
        if payloads:
            self.loop.call_soon_threadsafe(deliver)

    def run(self) -> None:
        try:
            while not self._halt.is_set():
                reqs = self._drain_submissions()
                if reqs:
                    # live submissions arrive "now" on the virtual clock
                    self.session.submit(reqs, live=True)
                if self.session.has_work():
                    self._post([_to_payload(ev)
                                for ev in self.session.step()])
                else:
                    try:
                        req = self.submissions.get(timeout=_IDLE_POLL_S)
                    except queue.Empty:
                        continue
                    self.session.submit([req], live=True)
        except BaseException as e:       # surface engine crashes to clients
            self.error = e
            self.loop.call_soon_threadsafe(self._fail_all, repr(e))

    def _fail_all(self, message: str) -> None:
        for rid, stream in list(self.streams.items()):
            stream.put_nowait({"event": "error", "rid": rid,
                               "error": message})
        self.streams.clear()


class StreamingServer:
    """NDJSON-over-TCP streaming server around one engine.

    ``port=0`` binds an ephemeral port (read ``server.port`` after
    ``start()``) — the tests and the CI smoke use this."""

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0):
        self.engine = engine
        self.host = host
        self._requested_port = port
        self._server: Optional[asyncio.AbstractServer] = None
        self._worker: Optional[_SessionWorker] = None
        self._rid = 0
        self._shutdown = asyncio.Event()
        # wall-clock serving stats (the virtual clock lives in
        # ServingMetrics; these time the ACTUAL asyncio path)
        self.first_chunk_latency_s: List[float] = []
        self.n_requests = 0
        self.n_shed = 0

    # ------------------------------------------------------------- lifecycle
    @property
    def port(self) -> int:
        assert self._server is not None, "server not started"
        return self._server.sockets[0].getsockname()[1]

    async def start(self) -> None:
        loop = asyncio.get_running_loop()
        self._worker = _SessionWorker(self.engine.session(), loop)
        self._server = await asyncio.start_server(
            self._handle, self.host, self._requested_port)
        self._worker.start()

    async def serve_until_shutdown(self) -> None:
        assert self._server is not None
        async with self._server:
            await self._shutdown.wait()
        await self.stop()

    async def stop(self) -> None:
        if self._worker is not None:
            self._worker.stop()
            self._worker.join(timeout=5.0)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict:
        lat = self.first_chunk_latency_s
        met = self._worker.session.met if self._worker else None
        out = {
            "n_requests": self.n_requests,
            "n_shed": self.n_shed,
            "first_chunk_latency_s": {
                "n": len(lat),
                "p50": float(np.percentile(lat, 50)) if lat else None,
                "p95": float(np.percentile(lat, 95)) if lat else None,
            },
        }
        if met is not None:
            out["service_clock"] = met.summary()
        return out

    # ------------------------------------------------------------ connection
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except json.JSONDecodeError as e:
                    await self._send(writer, {"event": "error",
                                              "error": f"bad json: {e}"})
                    continue
                op = msg.get("op", "generate")
                if op == "stats":
                    await self._send(writer, self.stats())
                elif op == "shutdown":
                    await self._send(writer, {"event": "bye"})
                    self._shutdown.set()
                    break
                elif op == "generate":
                    await self._stream_request(writer, msg)
                else:
                    await self._send(writer, {"event": "error",
                                              "error": f"unknown op {op!r}"})
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _stream_request(self, writer: asyncio.StreamWriter,
                              msg: Dict) -> None:
        worker = self._worker
        assert worker is not None
        try:
            prompt = np.asarray(msg["prompt"], np.int32)
            req = SLORequestSpec(
                rid=self._rid, arrival=0.0, prompt=prompt,
                max_new=int(msg.get("max_new", 16)),
                slo_latency_s=float(msg.get("slo_latency_s", np.inf)),
                max_skip_ratio=float(msg.get("max_skip_ratio", 1.0)),
                priority=int(msg.get("priority", 0)),
                slo_class=str(msg.get("slo_class", "")))
        except (KeyError, TypeError, ValueError) as e:
            await self._send(writer, {"event": "error",
                                      "error": f"bad request: {e}"})
            return
        self._rid += 1
        self.n_requests += 1
        stream: asyncio.Queue = asyncio.Queue()
        worker.register(req.rid, stream)
        t0 = time.perf_counter()
        worker.submit(req)
        await self._send(writer, {"event": "accepted", "rid": req.rid})
        first = True
        while True:
            payload = await stream.get()
            if first:
                self.first_chunk_latency_s.append(time.perf_counter() - t0)
                first = False
            await self._send(writer, payload)
            if payload["event"] in _TERMINAL or payload["event"] == "error":
                if payload["event"] == "shed":
                    self.n_shed += 1
                return

    @staticmethod
    async def _send(writer: asyncio.StreamWriter, payload: Dict) -> None:
        writer.write((json.dumps(payload) + "\n").encode())
        await writer.drain()


# --------------------------------------------------------------------------
# Blocking client helpers (CI smoke, tests, launch/serve.py --smoke-client)
# --------------------------------------------------------------------------


def request_once(host: str, port: int, prompt, max_new: int = 8, *,
                 slo_latency_s: float = float("inf"),
                 max_skip_ratio: float = 1.0, priority: int = 0,
                 timeout: float = 60.0) -> List[Dict]:
    """Send one generate request over a fresh TCP connection and return
    every streamed event line (blocking; runs fine outside any loop)."""
    import socket

    msg = {"prompt": [int(t) for t in prompt], "max_new": max_new,
           "slo_latency_s": slo_latency_s,
           "max_skip_ratio": max_skip_ratio, "priority": priority}
    events = []
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.settimeout(timeout)
        sock.sendall((json.dumps(msg) + "\n").encode())
        buf = b""
        while True:
            chunk = sock.recv(65536)
            if not chunk:
                break
            buf += chunk
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                ev = json.loads(line)
                events.append(ev)
                if ev.get("event") in _TERMINAL or ev.get("event") == "error":
                    return events
    return events


def fetch_stats(host: str, port: int, *, timeout: float = 30.0) -> Dict:
    """Fetch the server's stats line (blocking)."""
    import socket

    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.settimeout(timeout)
        sock.sendall(b'{"op": "stats"}\n')
        buf = b""
        while b"\n" not in buf:
            chunk = sock.recv(65536)
            if not chunk:
                raise ConnectionError("server closed before stats reply")
            buf += chunk
        return json.loads(buf.split(b"\n", 1)[0])


def shutdown(host: str, port: int, *, timeout: float = 10.0) -> None:
    """Ask the server to shut down (blocking, best-effort)."""
    import socket

    try:
        with socket.create_connection((host, port), timeout=timeout) as sock:
            sock.sendall(b'{"op": "shutdown"}\n')
            sock.recv(4096)
    except OSError:
        pass
