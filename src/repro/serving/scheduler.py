"""Request scheduling for the continuous-batching engine.

FCFS admission with join-on-free-slot: a pending request is admitted the
moment (a) it has arrived on the virtual clock, (b) a slot is free, and
(c) the *lazy-aware* step-cost estimate stays inside the cost budget.

The lazy-aware part: each slot's planned skip budget (the fraction of its
gated module calls a lazy plan removes) discounts its contribution to the
estimated cost of the next decode step, using the same service-clock
constants as metrics.py.  Under a cost budget, lazy slots therefore pack
denser than diligent ones — the scheduler converts LazyDiT's per-request
compute savings into admission headroom.

``batch_synchronous=True`` degrades admission to static batching (join only
when the pool has fully drained); it is the baseline bench_serving compares
against, using identical machinery so the comparison is apples-to-apples.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional, Sequence

from repro.data.synthetic import RequestSpec
from repro.serving import metrics as metrics_lib


class Scheduler:
    def __init__(self, n_slots: int, *,
                 cost_budget: Optional[float] = None,
                 batch_synchronous: bool = False,
                 step_overhead: float = metrics_lib.STEP_OVERHEAD,
                 module_cost: float = metrics_lib.MODULE_COST,
                 tracer=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.cost_budget = cost_budget
        self.batch_synchronous = batch_synchronous
        self.step_overhead = step_overhead
        self.module_cost = module_cost
        # optional repro.obs tracer: admission decisions land as instant
        # events on the virtual service clock track
        self.tracer = tracer
        self.pending: deque = deque()

    # ------------------------------------------------------------ queue ops
    def submit(self, requests: Iterable[RequestSpec]) -> None:
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self.pending.extend(reqs)

    def has_pending(self) -> bool:
        return bool(self.pending)

    def queue_depth(self) -> int:
        return len(self.pending)

    def next_arrival(self) -> Optional[float]:
        return self.pending[0].arrival if self.pending else None

    # ------------------------------------------------------------ cost model
    def estimate_step_cost(self, slot_skip_ratios: Sequence[float]) -> float:
        """Modeled virtual seconds of the next decode step, given each
        active slot's planned skip ratio (0.0 = diligent, runs everything)."""
        executed_frac = sum(1.0 - r for r in slot_skip_ratios)
        return self.step_overhead + self.module_cost * executed_frac / self.n_slots

    # ------------------------------------------------------------ admission
    def admit(self, now: float, free_slots: int,
              active_skip_ratios: Sequence[float],
              new_skip_ratio: float = 0.0) -> List[RequestSpec]:
        """Pop the FCFS-eligible requests that join this scheduling round.

        ``active_skip_ratios``: planned skip ratio of each currently active
        slot; ``new_skip_ratio``: the ratio an admitted request will run at.
        """
        if self.batch_synchronous and active_skip_ratios:
            return []
        out: List[RequestSpec] = []
        ratios = list(active_skip_ratios)
        while (self.pending and len(out) < free_slots
               and self.pending[0].arrival <= now + 1e-9):
            # progress guarantee: an empty pool always admits its first
            # request, even under a budget below the one-slot step cost
            if (self.cost_budget is not None and ratios
                    and self.estimate_step_cost(ratios + [new_skip_ratio])
                    > self.cost_budget + 1e-9):
                if self.tracer is not None:
                    from repro.obs import trace as trace_lib
                    self.tracer.instant(
                        "admission_deferred",
                        ts_us=trace_lib.Tracer.service_us(now),
                        pid=trace_lib.PID_SERVICE, cat="sched",
                        args={"rid": self.pending[0].rid,
                              "queue_depth": len(self.pending),
                              "est_cost": self.estimate_step_cost(
                                  ratios + [new_skip_ratio]),
                              "cost_budget": self.cost_budget})
                break
            req = self.pending.popleft()
            out.append(req)
            ratios.append(new_skip_ratio)
            if self.tracer is not None:
                from repro.obs import trace as trace_lib
                self.tracer.instant(
                    "admitted", ts_us=trace_lib.Tracer.service_us(now),
                    pid=trace_lib.PID_SERVICE, cat="sched",
                    args={"rid": req.rid, "arrival": req.arrival,
                          "queue_depth": len(self.pending)})
        return out
