"""Request scheduling for the continuous-batching engine.

Priority-then-FCFS admission with join-on-free-slot: a pending request is
admitted the moment (a) it has arrived on the virtual clock, (b) a slot is
free, and (c) the *lazy-aware* step-cost estimate stays inside the cost
budget.  Requests order by (priority desc, arrival, rid) — with every
priority at the default 0 this degenerates to the original pure FCFS, so
the pre-SLO behavior (and its tests) are a special case, not a second
code path.

The lazy-aware part: each slot's planned skip budget (the fraction of its
gated module calls a lazy plan removes) discounts its contribution to the
estimated cost of the next decode step, using the same service-clock
constants as metrics.py.  Under a cost budget, lazy slots therefore pack
denser than diligent ones — the scheduler converts LazyDiT's per-request
compute savings into admission headroom.  With a per-request policy bank
(serving/admission.py) each pending entry carries its OWN assigned skip
ratio, so the estimate prices the actual mix instead of one global ratio.

Priority + preemption: ``preemption_priority(now)`` exposes the strongest
eligible pending priority so the engine can decide whether to preempt an
active slot (engine.py owns victim selection and state save/restore; the
scheduler only orders the queue).  A preempted request re-enters via
``submit`` with its original arrival, so within its priority class it
resumes ahead of later arrivals.

``batch_synchronous=True`` degrades admission to static batching (join only
when the pool has fully drained); it is the baseline bench_serving compares
against, using identical machinery so the comparison is apples-to-apples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence

from repro.data.synthetic import RequestSpec
from repro.serving import metrics as metrics_lib


@dataclass
class PendingEntry:
    """One queued request plus the admission-time knowledge about it."""

    req: RequestSpec
    priority: int = 0
    # planned skip ratio of the policy this request will run under (None:
    # use the engine-wide default passed to admit)
    skip_ratio: Optional[float] = None
    # estimated virtual seconds of service (prefill + decode) — the
    # admission controller's feasibility estimate, kept for pending_work()
    est_service_s: float = 0.0

    def sort_key(self):
        return (-self.priority, self.req.arrival, self.req.rid)


class Scheduler:
    def __init__(self, n_slots: int, *,
                 cost_budget: Optional[float] = None,
                 batch_synchronous: bool = False,
                 step_overhead: float = metrics_lib.STEP_OVERHEAD,
                 module_cost: float = metrics_lib.MODULE_COST,
                 tracer=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.cost_budget = cost_budget
        self.batch_synchronous = batch_synchronous
        self.step_overhead = step_overhead
        self.module_cost = module_cost
        # optional repro.obs tracer: admission decisions land as instant
        # events on the virtual service clock track
        self.tracer = tracer
        self.pending: List[PendingEntry] = []

    # ------------------------------------------------------------ queue ops
    def submit(self, requests: Iterable[RequestSpec], *,
               skip_ratio: Optional[float] = None,
               est_service_s: float = 0.0) -> None:
        """Queue requests; a request's own ``priority`` attribute (SLO
        specs) orders it, plain RequestSpecs queue at priority 0."""
        for req in requests:
            self.pending.append(PendingEntry(
                req, priority=int(getattr(req, "priority", 0)),
                skip_ratio=skip_ratio, est_service_s=est_service_s))
        self.pending.sort(key=PendingEntry.sort_key)

    def submit_entry(self, entry: PendingEntry) -> None:
        self.pending.append(entry)
        self.pending.sort(key=PendingEntry.sort_key)

    def has_pending(self) -> bool:
        return bool(self.pending)

    def queue_depth(self) -> int:
        return len(self.pending)

    def next_arrival(self) -> Optional[float]:
        return (min(e.req.arrival for e in self.pending)
                if self.pending else None)

    def pending_work(self, now: float,
                     min_priority: Optional[int] = None) -> float:
        """Estimated virtual seconds of service already queued ahead of a
        new arrival (requests with arrival <= now).  ``min_priority``
        restricts the sum to entries at that priority or above — the work
        actually AHEAD of a new request at that priority, since admission
        is priority-ordered and higher classes preempt past lower ones."""
        return sum(e.est_service_s for e in self.pending
                   if e.req.arrival <= now + 1e-9
                   and (min_priority is None or e.priority >= min_priority))

    def preemption_priority(self, now: float) -> Optional[int]:
        """Priority of the strongest ELIGIBLE pending request, or None.
        The engine preempts an active slot when this is strictly higher
        than the slot's priority and no slot is free."""
        eligible = [e.priority for e in self.pending
                    if e.req.arrival <= now + 1e-9]
        return max(eligible) if eligible else None

    # ------------------------------------------------------------ cost model
    def estimate_step_cost(self, slot_skip_ratios: Sequence[float]) -> float:
        """Modeled virtual seconds of the next decode step, given each
        active slot's planned skip ratio (0.0 = diligent, runs everything)."""
        executed_frac = sum(1.0 - r for r in slot_skip_ratios)
        return self.step_overhead + self.module_cost * executed_frac / self.n_slots

    # ------------------------------------------------------------ admission
    def admit(self, now: float, free_slots: int,
              active_skip_ratios: Sequence[float],
              new_skip_ratio: float = 0.0) -> List[RequestSpec]:
        """Pop the eligible requests that join this scheduling round, in
        (priority desc, arrival, rid) order.

        ``active_skip_ratios``: planned skip ratio of each currently active
        slot; ``new_skip_ratio``: the default ratio an admitted request
        runs at, overridden per entry when the queue knows better (policy
        assigned at admission, serving/admission.py).  The budget check is
        head-of-line per round: the strongest pending request failing the
        budget blocks this round's weaker ones (no skip-ahead — a cheap
        low-priority request must not starve an expensive high-priority
        one forever).
        """
        if self.batch_synchronous and active_skip_ratios:
            return []
        out: List[RequestSpec] = []
        ratios = list(active_skip_ratios)
        while len(out) < free_slots:
            head = next((e for e in self.pending
                         if e.req.arrival <= now + 1e-9), None)
            if head is None:
                break
            r_new = (head.skip_ratio if head.skip_ratio is not None
                     else new_skip_ratio)
            # progress guarantee: an empty pool always admits its first
            # request, even under a budget below the one-slot step cost
            if (self.cost_budget is not None and ratios
                    and self.estimate_step_cost(ratios + [r_new])
                    > self.cost_budget + 1e-9):
                if self.tracer is not None:
                    from repro.obs import trace as trace_lib
                    self.tracer.instant(
                        "admission_deferred",
                        ts_us=trace_lib.Tracer.service_us(now),
                        pid=trace_lib.PID_SERVICE, cat="sched",
                        args={"rid": head.req.rid,
                              "queue_depth": len(self.pending),
                              "est_cost": self.estimate_step_cost(
                                  ratios + [r_new]),
                              "cost_budget": self.cost_budget})
                break
            self.pending.remove(head)
            out.append(head.req)
            ratios.append(r_new)
            if self.tracer is not None:
                from repro.obs import trace as trace_lib
                self.tracer.instant(
                    "admitted", ts_us=trace_lib.Tracer.service_us(now),
                    pid=trace_lib.PID_SERVICE, cat="sched",
                    args={"rid": head.req.rid, "arrival": head.req.arrival,
                          "priority": head.priority,
                          "queue_depth": len(self.pending)})
        return out
