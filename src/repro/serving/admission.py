"""SLO-aware admission control: per-request cache-policy selection.

Schedule-based caching differentiates per request, not per deployment:
Learning-to-Cache-style routers (arXiv:2406.01733) and Δ-DiT bands
(arXiv:2406.01125) trade quality for speed differently from a diligent
no-skip run, so the front door can pick the right policy for EACH request
from its declared budget instead of pinning one policy for the whole
server.  A request (data/synthetic.SLORequestSpec) declares

  * ``slo_latency_s``   — end-to-end deadline on the virtual service clock;
  * ``max_skip_ratio``  — quality budget: the largest plan skip ratio it
    accepts (the serving quality proxy; BENCH_serving.json's per-policy
    drift columns map ratio to measured cached-vs-fresh drift);
  * ``priority``        — admission/preemption class.

The controller owns the SELECTION rule; the engine owns the policy bank
(compiled device plans) and execution.  ``bind`` hands the controller the
bank's realized per-class skip ratios plus the service-clock constants, so
feasibility estimates and the scheduler's admission estimates agree.

Selection (``decide``) is a pure function of (request, queue-wait
estimate) — deterministic under a seeded trace by construction:

  1. quality-feasible classes: bank entries whose skip ratio fits the
     request's quality budget.  None fit -> shed ``unsatisfiable``.
  2. best quality that still makes the deadline: walk feasible classes
     from lowest skip ratio up, estimating
     ``queue_wait + prefill + max_new * step_cost(ratio)``; the first
     class inside ``slo_latency_s`` wins.  Under light load every class
     estimate includes ~zero wait, so requests get the best quality their
     budget allows; as load grows the estimate pushes latency-bound
     requests onto the high-skip plans.
  3. nothing makes the deadline: if even the FASTEST feasible class blows
     the deadline on an idle pool the SLO is unsatisfiable -> shed at
     admission (the request never queues); otherwise the queue is the
     problem -> shed ``overload`` (load shedding), or, with
     ``shed_on_overload=False``, serve it anyway on the fastest class and
     let goodput record the miss.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

from repro.serving import metrics as metrics_lib

SHED_UNSATISFIABLE = "unsatisfiable"
SHED_OVERLOAD = "overload"


class AdmissionDecision(NamedTuple):
    admitted: bool
    policy_class: str      # assigned bank class ("" when shed)
    reason: str            # "" | "unsatisfiable" | "overload"
    est_service_s: float   # prefill + decode estimate under the class
    quality_ok: bool       # assigned class fits the quality budget


class AdmissionController:
    """Per-request policy selection + load shedding for the serving engine.

    Construct with knobs only; the engine calls ``bind`` with the policy
    bank's realized ratios (it compiled the plans, so it knows them).
    ``slack`` multiplies the deadline during feasibility checks: the
    estimate cannot see co-runner interference (above all the SERIAL
    prefill stalls of requests admitted while this one decodes), so the
    default keeps ~30% headroom — tight-deadline traffic shifts onto the
    high-skip classes a notch earlier than the naive estimate would,
    which is what makes its realized attainment hold up under load."""

    def __init__(self, *, shed_on_overload: bool = True,
                 slack: float = 0.7):
        self.shed_on_overload = shed_on_overload
        self.slack = slack
        self.class_ratios: Dict[str, float] = {}
        self.n_slots = 1
        self.step_overhead = metrics_lib.STEP_OVERHEAD
        self.module_cost = metrics_lib.MODULE_COST
        self._by_ratio: Tuple[Tuple[float, str], ...] = ()

    # ------------------------------------------------------------ binding
    def bind(self, class_ratios: Dict[str, float], n_slots: int, *,
             step_overhead: float = metrics_lib.STEP_OVERHEAD,
             module_cost: float = metrics_lib.MODULE_COST) -> None:
        """Attach the engine's policy bank: {class name: realized plan skip
        ratio} plus the service-clock constants the estimates price with."""
        if not class_ratios:
            raise ValueError("policy bank is empty")
        self.class_ratios = dict(class_ratios)
        self.n_slots = n_slots
        self.step_overhead = step_overhead
        self.module_cost = module_cost
        # lowest skip ratio (best quality) first; name breaks ties so the
        # walk order — and therefore selection — is deterministic
        self._by_ratio = tuple(sorted(
            (r, name) for name, r in self.class_ratios.items()))

    # ------------------------------------------------------------ estimates
    def est_service_s(self, prompt_len: int, max_new: int,
                      ratio: float) -> float:
        """Prefill + decode virtual seconds under a class ratio, priced
        CONSERVATIVELY: this request skips at ``ratio`` while every other
        slot runs diligent, so one decode step costs
        ``overhead + module_cost * ((1-ratio) + (n_slots-1)) / n_slots``
        and advances this request one token.  (Same-ratio co-runners only
        make steps cheaper, so realized latency beats the estimate when
        the mix skews lazy.)"""
        prefill = metrics_lib.prefill_cost(
            prompt_len, self.n_slots, step_overhead=self.step_overhead,
            module_cost=self.module_cost)
        step = self.step_overhead + self.module_cost * (
            (1.0 - ratio) + (self.n_slots - 1)) / self.n_slots
        return prefill + max_new * step

    # ------------------------------------------------------------ decision
    def decide(self, req, *, queue_wait_s: float = 0.0
               ) -> AdmissionDecision:
        """Select a policy class for ``req`` or shed it (see module doc).
        ``queue_wait_s`` is the engine's estimate of virtual seconds the
        request waits before its slot (scheduler.pending_work / n_slots) —
        deliberately optimistic, so shedding errs toward serving."""
        if not self._by_ratio:
            raise RuntimeError("AdmissionController.decide before bind()")
        max_skip = float(getattr(req, "max_skip_ratio", 1.0))
        slo = float(getattr(req, "slo_latency_s", float("inf")))
        prompt_len = len(req.prompt)

        feasible = [(r, name) for r, name in self._by_ratio
                    if r <= max_skip + 1e-9]
        if not feasible:
            return AdmissionDecision(False, "", SHED_UNSATISFIABLE, 0.0,
                                     False)
        deadline = slo * self.slack
        for r, name in feasible:                     # best quality first
            est = self.est_service_s(prompt_len, req.max_new, r)
            if queue_wait_s + est <= deadline:
                return AdmissionDecision(True, name, "", est, True)
        r_fast, fast = feasible[-1]                  # highest-skip feasible
        est = self.est_service_s(prompt_len, req.max_new, r_fast)
        if est > deadline:
            # infeasible even on an idle pool: shed NOW, never queue
            return AdmissionDecision(False, "", SHED_UNSATISFIABLE, est,
                                     False)
        if self.shed_on_overload:
            return AdmissionDecision(False, "", SHED_OVERLOAD, est, False)
        return AdmissionDecision(True, fast, "", est, True)

    # ------------------------------------------------------------ reporting
    def describe(self) -> Dict:
        return {"class_ratios": dict(self.class_ratios),
                "shed_on_overload": self.shed_on_overload,
                "slack": self.slack}


def default_policy_bank(*, lazy_ratio: float = 0.5, seed: int = 0,
                        calibration=None,
                        quality: Optional[str] = None) -> Dict[str, object]:
    """The stock three-class bank (launch/serve.py --listen, bench_serving
    overload sweep, docs/policies.md):

      * ``quality``  — `none` (diligent; every quality budget fits), or
        `smoothcache` when a calibration artifact is supplied;
      * ``balanced`` — `static_router` at half the latency tier's ratio;
      * ``latency``  — `static_router` at ``lazy_ratio`` (the high-skip
        plan latency-SLO traffic lands on under load).

    Returns {class name: CachePolicy}; the engine compiles the plans and
    reports realized ratios to the controller via ``bind``."""
    from repro import cache as cache_lib
    if quality is None:
        q = (cache_lib.get_policy("smoothcache", calibration=calibration)
             if calibration is not None else cache_lib.get_policy("none"))
    else:
        q = cache_lib.get_policy(quality)
    return {
        "quality": q,
        "balanced": cache_lib.get_policy("static_router",
                                         ratio=lazy_ratio / 2, seed=seed),
        "latency": cache_lib.get_policy("static_router", ratio=lazy_ratio,
                                        seed=seed),
    }


def quality_budget_ok(class_ratios: Dict[str, float], policy_class: str,
                      max_skip_ratio: float) -> bool:
    """Did the assigned class fit the request's quality budget?  (Metrics
    goodput counts a request only when this held — a fixed-policy engine
    forcing one class onto every request fails it for strict requests.)"""
    return class_ratios.get(policy_class, 0.0) <= max_skip_ratio + 1e-9


def trace_slo_stats(requests: Sequence) -> Dict[str, int]:
    """Per-class request counts of a trace (bench/report labeling)."""
    out: Dict[str, int] = {}
    for r in requests:
        cls = getattr(r, "slo_class", "") or "unclassified"
        out[cls] = out.get(cls, 0) + 1
    return out
