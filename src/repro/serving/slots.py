"""Fixed-capacity slot pool over shared stacked KV / lazy caches.

A slot is one decode lane of the continuous-batching engine.  Device state
is a pair of slot-stacked cache trees (every leaf is (n_slots, *single)),
built from batch-1 caches with lazy.stack_for_slots; requests join by
scattering their freshly prefilled batch-1 cache into a free slot index and
leave by simply marking the slot free (the next occupant's scatter
overwrites everything, including the ring-buffer ``pos`` vectors, so stale
keys can never leak across requests).

Host state is per-slot bookkeeping: the request, its absolute position
counter, decode-step counter (plan row index), and freshness flag.  The
position counters are per-slot — the whole point of the mixed-position
decode step (models/transformer.decode_step_mixed).

Under an active ``dist.ctx`` mesh every slot-stacked tree (KV cache,
lazy cache, traced policy state via ``place``) shards its slot axis over
the data axes — one decode lane per data shard
(dist/sharding.slot_stack_shardings), with admission scatters/evictions
operating on the sharded arrays unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import RequestSpec
from repro.dist import ctx as dist_ctx
from repro.dist import sharding as sharding_lib
from repro.models import transformer as tf


@dataclass
class Slot:
    req: Optional[RequestSpec] = None
    index: int = 0          # absolute position of the NEXT decode write
    produced: int = 0       # decode outputs emitted so far
    t: int = 0              # decode-step counter (selects the plan row)
    fresh: bool = False     # admitted this step: lazy cache must not serve
    last_token: int = 0     # input token for the next decode step
    tokens: List[int] = field(default_factory=list)   # decode outputs

    @property
    def active(self) -> bool:
        return self.req is not None


class SlotPool:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int, *,
                 lazy: bool = False, window_override: Optional[int] = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.window_override = window_override
        single = tf.init_decode_cache(cfg, 1, max_len,
                                      window_override=window_override)
        self.cache = self.place(lazy_lib.stack_for_slots(single, n_slots))
        self.lazy_cache = None
        if lazy:
            self.lazy_cache = self.place(lazy_lib.stack_for_slots(
                tf.init_lazy_decode_cache(cfg, 1,
                                          window_override=window_override),
                n_slots))
        self.slots = [Slot() for _ in range(n_slots)]

    def place(self, stacked):
        """Pin a slot-stacked tree's placement: slot axis over the data
        axes when a dist.ctx mesh is active (identity otherwise), so the
        jitted mixed-position decode runs SPMD over decode lanes."""
        mesh = dist_ctx.current_mesh()
        if mesh is None:
            return stacked
        return jax.device_put(
            stacked,
            sharding_lib.slot_stack_shardings(stacked, mesh, self.n_slots))

    # ------------------------------------------------------------ inventory
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.active]

    def any_active(self) -> bool:
        return any(s.active for s in self.slots)

    # ------------------------------------------------------------ lifecycle
    def admit(self, i: int, req: RequestSpec, prefilled_cache,
              first_token: int) -> None:
        """Join ``req`` on free slot ``i`` with its prefilled batch-1 cache;
        ``first_token`` is the prefill's greedy argmax (the first decode
        input, matching the static Engine's semantics)."""
        assert not self.slots[i].active, f"slot {i} is occupied"
        self.cache = lazy_lib.slot_cache_scatter(self.cache, i, prefilled_cache)
        if self.lazy_cache is not None:
            self.lazy_cache = lazy_lib.slot_cache_reset(self.lazy_cache, i)
        self.slots[i] = Slot(req=req, index=len(req.prompt), fresh=True,
                             last_token=int(first_token))

    def evict(self, i: int) -> None:
        self.slots[i] = Slot()

    # ------------------------------------------------- preemption save/restore
    def snapshot(self, i: int):
        """Gather slot ``i``'s device state for preemption: its batch-1 KV
        cache tree (and lazy cache when present) — the exact values the
        slot holds, so a later ``restore`` continues bit-identically."""
        kv = lazy_lib.slot_cache_gather(self.cache, i)
        lz = (lazy_lib.slot_cache_gather(self.lazy_cache, i)
              if self.lazy_cache is not None else None)
        return kv, lz

    def restore(self, i: int, req: RequestSpec, kv_single, lazy_single, *,
                index: int, produced: int, t: int, fresh: bool,
                last_token: int, tokens: List[int]) -> None:
        """Re-seat a preempted request on free slot ``i`` from a
        ``snapshot``: scatter its saved caches back and rebuild the host
        bookkeeping exactly as it was (gather-then-scatter of the same
        values is the identity, so the continuation tokens match the
        uninterrupted run — tests/test_admission.py pins this)."""
        assert not self.slots[i].active, f"slot {i} is occupied"
        self.cache = lazy_lib.slot_cache_scatter(self.cache, i, kv_single)
        if self.lazy_cache is not None and lazy_single is not None:
            self.lazy_cache = lazy_lib.slot_cache_scatter(
                self.lazy_cache, i, lazy_single)
        self.slots[i] = Slot(req=req, index=index, produced=produced, t=t,
                             fresh=fresh, last_token=last_token,
                             tokens=list(tokens))

    def advance(self, i: int, token: int) -> None:
        s = self.slots[i]
        s.tokens.append(int(token))
        s.last_token = int(token)
        s.index += 1
        s.produced += 1
        s.t += 1
        s.fresh = False

    def should_evict(self, i: int) -> bool:
        """EOS handling lives in the engine; this covers budget/capacity."""
        s = self.slots[i]
        return s.produced >= s.req.max_new or s.index >= self.max_len

    # ------------------------------------------------- decode-step vectors
    def token_vector(self) -> jnp.ndarray:
        return jnp.asarray([s.last_token for s in self.slots], jnp.int32)

    def index_vector(self) -> jnp.ndarray:
        # inactive slots hold a harmless in-range position; their writes are
        # garbage by construction and fully overwritten at next admission
        return jnp.asarray([min(s.index, self.max_len - 1)
                            for s in self.slots], jnp.int32)

    def fresh_vector(self) -> jnp.ndarray:
        return jnp.asarray([s.fresh for s in self.slots], bool)

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots], bool)

    def step_vector(self) -> np.ndarray:
        return np.array([s.t for s in self.slots], np.int64)
