"""Serving metrics and the service-clock cost model.

The container has no accelerator, so wall-clock on this host says nothing
about served throughput: masked-mode selects and inactive slots still burn
host FLOPs that a compiled plan (decode_step_unrolled, bench_compute) or a
paged production runtime would never issue.  The *service clock* projects
those measured HLO savings onto the request level instead: a decode step is
charged

    step_time = STEP_OVERHEAD + MODULE_COST * executed / (n_slots * M)

where ``executed`` counts gated module calls actually run for active slots
(skipped and idle-slot calls are free, i.e. a compacted/paged execution)
and ``M`` is the per-slot gated-module-call count.  A full step of a full
pool costs exactly 1.0 virtual second.  Prefilling a P-token prompt costs
``STEP_OVERHEAD + MODULE_COST * P / n_slots``.  The same constants drive
the scheduler's lazy-aware admission estimate (scheduler.py), so metrics
and scheduling decisions agree.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig

# one full-pool, no-skip decode step == STEP_OVERHEAD + MODULE_COST == 1.0
STEP_OVERHEAD = 0.2     # dispatch / collectives / sampling floor
MODULE_COST = 0.8       # the gated-module compute the lazy plan can remove

# goodput SLO: a completed request only counts toward goodput if its
# end-to-end latency stayed within this many virtual seconds
DEFAULT_SLO_LATENCY_S = 10.0


def attn_like_mask(cfg: ModelConfig, *,
                   window_override: Optional[int] = None) -> np.ndarray:
    """(n_layers,) bool — layers of the attn family, which carry TWO gated
    modules (attn + ffn) and consume both plan columns; SSM/xLSTM layers
    carry one and consume only column 1.  The single source of truth for
    plan-skip and step-cost accounting."""
    from repro.models.transformer import build_layer_specs
    specs = build_layer_specs(cfg, window_override=window_override)
    return np.array([s.kind in ("attn_ffn", "attn_moe", "parallel")
                     for s in specs], bool)


def gated_module_calls(cfg: ModelConfig, *,
                       window_override: Optional[int] = None) -> int:
    """Gated module calls per slot per decode step."""
    mask = attn_like_mask(cfg, window_override=window_override)
    return int(mask.sum()) + mask.size


def step_cost(executed_calls: float, n_slots: int, modules_per_slot: int,
              *, step_overhead: float = STEP_OVERHEAD,
              module_cost: float = MODULE_COST) -> float:
    """Virtual seconds for one mixed-position decode step."""
    return step_overhead + module_cost * executed_calls / (
        n_slots * modules_per_slot)


def prefill_cost(prompt_len: int, n_slots: int, *,
                 step_overhead: float = STEP_OVERHEAD,
                 module_cost: float = MODULE_COST) -> float:
    """Virtual seconds to prefill one P-token prompt into a free slot."""
    return step_overhead + module_cost * prompt_len / n_slots


class ServingMetrics:
    """Per-step and per-request accounting for a serving run."""

    def __init__(self, n_slots: int, modules_per_slot: int):
        self.n_slots = n_slots
        self.modules_per_slot = modules_per_slot
        self.steps: List[Dict] = []
        self.requests: Dict[int, Dict] = {}
        self.shed: Dict[int, Dict] = {}
        self._executed = 0.0
        self._skipped = 0.0
        self._tokens_out = 0
        self._t_end = 0.0
        self._drift_rel: List[float] = []
        self._drift_cos: List[float] = []
        self._n_preemptions = 0

    # ------------------------------------------------------------ recording
    def record_admit(self, rid: int, arrival: float, now: float,
                     prompt_len: int, *, prefill_s: float = 0.0,
                     slo_latency_s: Optional[float] = None,
                     quality_ok: bool = True,
                     policy_class: str = "",
                     priority: int = 0) -> None:
        """``now`` is the admit time AFTER prefill (the engine's
        convention); ``prefill_s`` is how much of it the prefill took, so
        the request's latency decomposes exactly into

            queue   = (admit - prefill_s) - arrival
            prefill = prefill_s
            decode  = done - admit

        and queue + prefill + decode == done - arrival per request.
        (With preemption the decode phase also absorbs preempted wait —
        the request left and re-entered the pool between admit and done.)

        ``slo_latency_s`` is the request's OWN deadline (None: judged
        against the summary-wide default); ``quality_ok`` records whether
        the policy it was assigned satisfies its quality budget — goodput
        counts a request only when latency AND quality held.
        ``policy_class`` labels which admission class / bank policy served
        it (per-class breakdowns, class_summary)."""
        self.requests[rid] = {"arrival": arrival, "admit": now,
                              "prompt_len": prompt_len,
                              "prefill_s": float(prefill_s),
                              "first_token": None, "done": None, "n_out": 0,
                              "slo_latency_s": slo_latency_s,
                              "quality_ok": bool(quality_ok),
                              "policy_class": policy_class,
                              "priority": int(priority),
                              "n_preempted": 0}
        self._t_end = max(self._t_end, now)

    def record_shed(self, rid: int, now: float, reason: str, *,
                    policy_class: str = "") -> None:
        """A request refused AT ADMISSION (serving/admission.py): it never
        queued, never held a slot, and never appears in ``requests``.
        ``reason``: 'unsatisfiable' (infeasible even on an idle pool) or
        'overload' (the queue-wait estimate blows its deadline)."""
        if rid in self.requests:
            raise KeyError(
                f"record_shed: request {rid} was already admitted — "
                "shedding happens at admission, not after")
        self.shed[rid] = {"t": now, "reason": reason,
                          "policy_class": policy_class}
        self._t_end = max(self._t_end, now)

    def record_preemption(self, rid: int, now: float) -> None:
        """An active request vacated its slot for a higher-priority one;
        it re-enters the queue and resumes later (engine save/restore)."""
        if rid not in self.requests:
            raise KeyError(
                f"record_preemption: request {rid} was never admitted")
        self.requests[rid]["n_preempted"] += 1
        self._n_preemptions += 1
        self._t_end = max(self._t_end, now)

    def record_step(self, now: float, n_active: int, queue_depth: int,
                    executed_calls: float, skipped_calls: float,
                    tokens_out: int,
                    drift_rel: Optional[float] = None,
                    drift_cos: Optional[float] = None) -> None:
        """``drift_rel``/``drift_cos`` are the step's mean cached-vs-fresh
        lazy-cache drift over established active slots (repro.obs
        slot_cache_drift), recorded only when the engine runs with
        telemetry on AND the step had any established slot."""
        self.steps.append({"t": now, "n_active": n_active,
                           "queue_depth": queue_depth,
                           "executed": executed_calls,
                           "skipped": skipped_calls,
                           "tokens": tokens_out})
        self._executed += executed_calls
        self._skipped += skipped_calls
        self._tokens_out += tokens_out
        self._t_end = max(self._t_end, now)
        if drift_rel is not None:
            self._drift_rel.append(float(drift_rel))
        if drift_cos is not None:
            self._drift_cos.append(float(drift_cos))

    def record_first_token(self, rid: int, now: float) -> None:
        if rid not in self.requests:
            raise KeyError(
                f"record_first_token: request {rid} was never admitted "
                "(record_admit must precede first-token recording)")
        if self.requests[rid]["first_token"] is None:
            self.requests[rid]["first_token"] = now

    def record_completion(self, rid: int, now: float, n_out: int) -> None:
        if rid not in self.requests:
            raise KeyError(
                f"record_completion: request {rid} was never admitted "
                "(record_admit must precede completion recording)")
        self.requests[rid]["done"] = now
        self.requests[rid]["n_out"] = n_out
        self._t_end = max(self._t_end, now)

    # ------------------------------------------------------------ summaries
    def realized_lazy_ratio(self) -> float:
        total = self._executed + self._skipped
        return float(self._skipped / total) if total else 0.0

    @staticmethod
    def _good(r: Dict, default_slo: float) -> bool:
        """Does a completed request count toward goodput?  Its latency must
        stay within its OWN slo (falling back to the summary default) AND
        its assigned policy must have satisfied its quality budget."""
        slo = r.get("slo_latency_s")
        slo = default_slo if slo is None else slo
        return (r["done"] - r["arrival"] <= slo) and r.get("quality_ok", True)

    def summary(self, *,
                slo_latency_s: float = DEFAULT_SLO_LATENCY_S
                ) -> Dict[str, float]:
        """Empty distributions report NaN, never a fabricated 0.0: a run
        with zero completed requests has no latency/TTFT percentiles, and a
        0.0 placeholder reads as an impossibly perfect run downstream
        (regression gates compare it as real data).  NaN is the honest
        missing value — json.dump emits it, and check_regression treats a
        NaN on either side as "metric absent", not a regression."""
        done = [r for r in self.requests.values() if r["done"] is not None]
        t0 = min((r["arrival"] for r in self.requests.values()), default=0.0)
        span = max(self._t_end - t0, 1e-9)
        lat = np.array([r["done"] - r["arrival"] for r in done])
        ttft = np.array([r["first_token"] - r["arrival"] for r in done
                         if r["first_token"] is not None])
        # phase decomposition (see record_admit): per request the three
        # phases sum exactly to end-to-end latency
        queue = np.array([r["admit"] - r.get("prefill_s", 0.0) - r["arrival"]
                          for r in done])
        prefill = np.array([r.get("prefill_s", 0.0) for r in done])
        decode = np.array([r["done"] - r["admit"] for r in done])
        qd = np.array([s["queue_depth"] for s in self.steps])
        act = np.array([s["n_active"] for s in self.steps])

        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else float("nan")

        def mean(a):
            return float(a.mean()) if len(a) else float("nan")

        within_slo = sum(1 for r in done if self._good(r, slo_latency_s))
        return {
            "n_requests": float(len(done)),
            "n_steps": float(len(self.steps)),
            "virtual_time_s": float(span),
            "requests_per_s": float(len(done) / span),
            "goodput_per_s": float(within_slo / span),
            "slo_attainment": (float(within_slo / (len(done) + len(self.shed)))
                               if done or self.shed else float("nan")),
            "n_shed": float(len(self.shed)),
            "n_preemptions": float(self._n_preemptions),
            "slo_latency_s": float(slo_latency_s),
            "tokens_per_s": float(self._tokens_out / span),
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            "queue_p50_s": pct(queue, 50),
            "queue_p95_s": pct(queue, 95),
            "prefill_p50_s": pct(prefill, 50),
            "prefill_p95_s": pct(prefill, 95),
            "decode_p50_s": pct(decode, 50),
            "decode_p95_s": pct(decode, 95),
            "realized_lazy_ratio": self.realized_lazy_ratio(),
            "mean_queue_depth": mean(qd),
            "mean_active_slots": mean(act),
            "drift_rel_l2_mean": mean(np.array(self._drift_rel)),
            "drift_cos_mean": mean(np.array(self._drift_cos)),
        }

    def class_summary(self, *,
                      slo_latency_s: float = DEFAULT_SLO_LATENCY_S
                      ) -> Dict[str, Dict[str, float]]:
        """Goodput-under-SLO broken down by admission policy class: for
        each class seen (admitted OR shed), completed/shed counts, goodput
        over the run span, SLO attainment (good / offered), and latency
        p50/p95.  Unclassified requests (no admission controller) land
        under ''."""
        t0 = min((r["arrival"] for r in self.requests.values()), default=0.0)
        span = max(self._t_end - t0, 1e-9)
        classes = ({r.get("policy_class", "") for r in self.requests.values()}
                   | {s.get("policy_class", "") for s in self.shed.values()})
        out: Dict[str, Dict[str, float]] = {}
        for cls in sorted(classes):
            rs = [r for r in self.requests.values()
                  if r.get("policy_class", "") == cls]
            done = [r for r in rs if r["done"] is not None]
            shed = [s for s in self.shed.values()
                    if s.get("policy_class", "") == cls]
            good = sum(1 for r in done if self._good(r, slo_latency_s))
            lat = np.array([r["done"] - r["arrival"] for r in done])
            offered = len(done) + len(shed)
            out[cls] = {
                "n_done": float(len(done)),
                "n_shed": float(len(shed)),
                "n_preemptions": float(sum(r["n_preempted"] for r in rs)),
                "goodput_per_s": float(good / span),
                "slo_attainment": (float(good / offered) if offered
                                   else float("nan")),
                "latency_p50_s": (float(np.percentile(lat, 50))
                                  if lat.size else float("nan")),
                "latency_p95_s": (float(np.percentile(lat, 95))
                                  if lat.size else float("nan")),
            }
        return out
