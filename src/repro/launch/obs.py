"""Laziness observability report: skip heatmaps, drift curves, traces.

Runs telemetry-instrumented sampling for a set of cache policies (one
fused-trajectory run each, counters riding the scan carry — repro.obs),
optionally a short telemetry-on serving trace, and writes the assembled
report plus the run's structured trace:

    artifacts/OBS_report.json   repro.obs.report/v1 — per-policy skip
                                heatmaps, drift-by-step curves, gate-score
                                means, compile-event timeline, service-
                                clock percentiles
    artifacts/OBS_trace.json    Chrome trace-event JSON (Perfetto /
                                chrome://tracing)
    artifacts/OBS_events.jsonl  the same events, one JSON object per line

  # default: 4 policies on reduced dit_xl2_256, no serving leg
  PYTHONPATH=src python -m repro.launch.obs

  # CI obs-smoke: tiny run + short serving trace
  PYTHONPATH=src python -m repro.launch.obs --steps 6 --batch 2 \
      --serve --serve-requests 8 --n-slots 2

The CLI FAILS (nonzero exit) if any policy's drift telemetry is
non-finite or the trace breaks the Chrome schema — the observability
artifacts are validated where they are produced, not in the viewer.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.registry import get_config
from repro.core import lazy as lazy_lib
from repro.data.synthetic import slo_request_trace
from repro.dist import hlo as hlo_lib
from repro.kernels import backend as kernel_backend
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.obs import profile as profile_lib
from repro.obs import report as report_lib
from repro.obs import trace as trace_lib
from repro.sampling import ddim, trajectory
from repro.serving import admission as admission_lib
from repro.serving import metrics as serving_metrics
from repro.serving.admission import trace_slo_stats
from repro.serving.engine import ContinuousBatchingEngine

# same directory benchmarks/common.ARTIFACTS resolves to, without making
# the launcher depend on the benchmarks package being importable
ARTIFACTS = os.path.join(os.path.dirname(__file__),
                         "..", "..", "..", "artifacts")

DEFAULT_POLICIES = ("none", "smoothcache", "static_router", "learned")

#: policies that need a calibration profile to be built here
CALIBRATED = ("smoothcache", "static_router", "delta", "learned")


def build_obs_policy(name: str, cfg, n_steps: int, calibration=None, *,
                     lazy_ratio: float = 0.4, seed: int = 0):
    """A ready-to-run policy for one report leg.  ``learned`` has no
    trained artifact in a fresh checkout, so it is synthesized from the
    calibration profile: low consecutive-step error -> high laziness
    score, distilled at ``lazy_ratio`` — the same evidence a trained
    router converges to, in deployable ScheduleArtifact form."""
    if name == "none":
        return cache_lib.get_policy("none")
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "lazy_gate":
        return cache_lib.get_policy("lazy_gate",
                                    threshold=cfg.lazy.threshold)
    if name == "plan":
        return cache_lib.get_policy(
            "plan", plan=lazy_lib.uniform_plan(n_steps, cfg.n_layers, 2,
                                               lazy_ratio, seed=seed).skip)
    if name in CALIBRATED and calibration is None:
        raise ValueError(f"policy {name!r} needs a calibration profile")
    if name == "smoothcache":
        return cache_lib.get_policy(
            "smoothcache", calibration=calibration,
            error_threshold=calibration.quantile_threshold(lazy_ratio))
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=lazy_ratio,
                                    calibration=calibration, seed=seed)
    if name == "delta":
        return cache_lib.get_policy("delta", ratio=lazy_ratio,
                                    calibration=calibration)
    if name == "learned":
        rel = np.asarray(calibration.resampled(n_steps), np.float64)
        scores = np.where(np.isfinite(rel), 1.0 / (1.0 + rel), 0.0)
        art = cache_lib.distill_scores("router", cfg.name, scores,
                                       target_ratio=lazy_ratio,
                                       per_layer=True,
                                       meta={"source": "obs-calibration"})
        return cache_lib.get_policy("learned", artifact=art)
    return cache_lib.get_policy(name)


def collect_sampling(cfg, params, sched, policy_names, *, n_steps: int,
                     batch: int, seed: int, lazy_ratio: float,
                     tracer: trace_lib.Tracer,
                     cfg_scale: float = 1.5) -> Dict[str, Dict]:
    """One telemetry-on fused-trajectory run per policy -> report legs."""
    labels = jnp.arange(batch) % cfg.dit_n_classes
    key = jax.random.PRNGKey(seed)
    calibration = None
    if any(n in CALIBRATED for n in policy_names):
        with tracer.span("calibrate_dit", cat="obs"):
            calibration = calibrate_lib.calibrate_dit(
                params, cfg, sched, key=jax.random.PRNGKey(seed + 1),
                labels=labels[:2], n_steps=n_steps)
    legs: Dict[str, Dict] = {}
    for name in policy_names:
        pol = build_obs_policy(name, cfg, n_steps, calibration,
                               lazy_ratio=lazy_ratio, seed=seed)
        with tracer.span(f"sample:{name}", cat="obs",
                         args={"policy": name, "n_steps": n_steps}):
            x, aux = trajectory.sample_trajectory(
                params, cfg, sched, key=key, labels=labels,
                n_steps=n_steps, cfg_scale=cfg_scale, policy=pol,
                telemetry=True)
            jax.block_until_ready(x)
        legs[name] = {"telemetry": aux["telemetry"],
                      "policy": pol.describe(),
                      "realized_skip_ratio": aux["realized_skip_ratio"]}
    return legs


def collect_serving(cfg, params, *, n_requests: int, n_slots: int,
                    seed: int, lazy_ratio: float, slo: float,
                    tracer: trace_lib.Tracer) -> Dict:
    """A short telemetry-on SLO-aware serving trace -> service-clock
    summary (latency/TTFT percentiles, goodput-under-SLO, drift means)
    plus per-policy-class breakdown.  Runs the full front-door path —
    policy bank + admission control + priority preemption — so shed,
    policy_assigned, and preempted events land in OBS_trace.json."""
    trace = slo_request_trace(n_requests, cfg.vocab_size, seed=seed,
                              mean_interarrival=0.3,
                              short_prompt=(4, 4), long_prompt=(10, 10),
                              short_output=(3, 6), long_output=(8, 14))
    max_len = max(len(r.prompt) + r.max_new for r in trace) + 4
    bank = admission_lib.default_policy_bank(lazy_ratio=lazy_ratio,
                                             seed=seed)
    ctrl = admission_lib.AdmissionController()
    with tracer.span("serve_trace", cat="obs",
                     args={"n_requests": n_requests, "n_slots": n_slots,
                           "classes": trace_slo_stats(trace)}):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=n_slots,
                                       max_len=max_len,
                                       policy_bank=bank, admission=ctrl,
                                       telemetry=True, tracer=tracer)
        res = eng.run(trace)
    out = res.metrics.summary(slo_latency_s=slo)
    out["by_class"] = res.metrics.class_summary()
    out["admission"] = ctrl.describe()
    return out


def collect_perf(cfg, params, sched, policy_names, *, n_steps: int,
                 batch: int, seed: int, lazy_ratio: float,
                 tracer: trace_lib.Tracer, iters: int = 3,
                 cfg_scale: float = 1.5) -> Dict:
    """The realized-vs-modeled join: per policy, AOT lower/compile timed
    apart from first execution, steady-state wall as median + MAD
    (repro.obs.profile.measure), the dist/hlo modeled FLOPs/bytes of the
    SAME compiled executable, and the achieved roofline fractions their
    ratio implies.  The first execution runs inside a jax.profiler
    device-trace capture merged onto the tracer's PID_DEVICE track."""
    labels = jnp.arange(batch) % cfg.dit_n_classes
    key = jax.random.PRNGKey(seed)
    calibration = None
    if any(n in CALIBRATED for n in policy_names):
        with tracer.span("perf:calibrate_dit", cat="perf"):
            calibration = calibrate_lib.calibrate_dit(
                params, cfg, sched, key=jax.random.PRNGKey(seed + 1),
                labels=labels[:2], n_steps=n_steps)
    legs: Dict[str, Dict] = {}
    for name in policy_names:
        pol = build_obs_policy(name, cfg, n_steps, calibration,
                               lazy_ratio=lazy_ratio, seed=seed)
        fn = trajectory.build_sampler(cfg, pol, n_steps, cfg_scale,
                                      batch=batch)
        sample_args = trajectory.prepare_inputs(
            cfg, sched, pol, key=key, labels=labels, n_steps=n_steps)
        with tracer.span(f"perf:aot:{name}", cat="perf"):
            compiled, aot = profile_lib.aot_compile(fn, params,
                                                    *sample_args)
        mod = hlo_lib.sharded_totals(compiled.as_text())
        try:
            mem = compiled.memory_analysis()
            mem_row = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception:
            mem_row = None
        with profile_lib.device_trace(tracer, label=f"device:{name}"):
            t0 = time.perf_counter()
            x, aux = compiled(params, *sample_args)
            jax.block_until_ready(x)
            first_exec_s = time.perf_counter() - t0
        with tracer.span(f"perf:steady:{name}", cat="perf"):
            m = profile_lib.measure(
                lambda: compiled(params, *sample_args)[0],
                iters=iters, warmup=0)
        gated = max(n_steps * cfg.n_layers * trajectory.N_MODULES, 1)
        wall_s = m.median_s
        legs[name] = {
            "wall_ms_median": wall_s * 1e3,
            "wall_ms_mad": m.mad_s * 1e3,
            "iters": m.iters,
            "rejected": m.rejected,
            "lower_s": aot["lower_s"],
            "compile_s": aot["compile_s"],
            "first_execute_ms": first_exec_s * 1e3,
            "realized_skip_ratio": float(aux["n_skipped"]) / gated,
            "modeled": {
                "flops_per_device": float(mod["flops"]),
                "bytes_per_device": float(mod["bytes"]),
                "flops_global": float(mod["flops_global"]),
                "bytes_global": float(mod["bytes_global"]),
                "partitions": mod["partitions"],
            },
            "memory_analysis": mem_row,
            "achieved": {
                "flops_per_s": float(mod["flops_global"]) / max(wall_s,
                                                                1e-12),
                "bytes_per_s": float(mod["bytes_global"]) / max(wall_s,
                                                                1e-12),
                # fractions of the reference accelerator roofline
                # (launch/mesh constants) — honest context for a CPU
                # container, a real utilization number on hardware
                "flops_fraction_of_peak": float(mod["flops_global"])
                / max(wall_s, 1e-12) / PEAK_FLOPS_BF16,
                "bytes_fraction_of_hbm": float(mod["bytes_global"])
                / max(wall_s, 1e-12) / HBM_BW,
            },
        }
    none_leg = legs.get("none")
    for name, leg in legs.items():
        if none_leg is None or name == "none":
            continue
        leg["measured_speedup_vs_none"] = (
            none_leg["wall_ms_median"] / max(leg["wall_ms_median"], 1e-9))
        leg["modeled_flop_saving_vs_none"] = 1.0 - (
            leg["modeled"]["flops_global"]
            / max(none_leg["modeled"]["flops_global"], 1.0))
    return {
        "policies": legs,
        "memory_watermarks": profile_lib.memory_watermarks(),
        "roofline_peaks": {"peak_flops_bf16": PEAK_FLOPS_BF16,
                           "hbm_bytes_per_s": HBM_BW},
        "harness": {"iters": iters,
                    "method": "repro.obs.profile.measure "
                              "(median + MAD, outlier-rejected)"},
        "arch": cfg.name, "n_steps": n_steps, "batch": batch,
    }


def verify_report(report: Dict) -> None:
    """Raise if the report misses its core metrics or any policy's drift
    telemetry came back non-finite — run-time validation of the artifact
    this CLI exists to produce."""
    metrics = report.get("metrics", {})
    for required in ("skip_heatmap", "drift_by_step"):
        if required not in metrics:
            raise ValueError(f"report is missing metric {required!r}")
    for pol, leg in metrics["drift_by_step"].items():
        for key in ("rel_l2", "cosine"):
            vals = leg[key]
            if not all(math.isfinite(v) for v in vals):
                raise ValueError(
                    f"non-finite drift in policy {pol!r} ({key}): {vals}")
    if "perf" in metrics:
        for pol, leg in metrics["perf"].get("policies", {}).items():
            for key in ("wall_ms_median", "compile_s", "first_execute_ms"):
                v = leg.get(key)
                if v is None or not math.isfinite(v) or v < 0:
                    raise ValueError(
                        f"perf leg {pol!r} has invalid {key}: {v!r}")


def _jsonify(obj):
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def write_artifacts(report: Dict, tracer: trace_lib.Tracer,
                    out_dir: str) -> Dict[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = {"report": os.path.join(out_dir, "OBS_report.json"),
             "trace": os.path.join(out_dir, "OBS_trace.json"),
             "events": os.path.join(out_dir, "OBS_events.jsonl")}
    with open(paths["report"], "w") as f:
        json.dump(report, f, indent=1, default=_jsonify)
    trace_lib.validate_chrome_trace(tracer.sorted_events())
    tracer.to_chrome(paths["trace"])
    tracer.to_jsonl(paths["events"])
    return paths


def run_report(*, arch: str = "dit_xl2_256",
               policies=DEFAULT_POLICIES,
               n_steps: int = 8, batch: int = 2, seed: int = 0,
               lazy_ratio: float = 0.4,
               serve: bool = False, serve_arch: str = "llama3_2_1b",
               serve_requests: int = 8, n_slots: int = 2,
               slo: float = serving_metrics.DEFAULT_SLO_LATENCY_S,
               out_dir: str = ARTIFACTS,
               cfg=None, params=None,
               serve_cfg=None, serve_params=None,
               perf: bool = False,
               perf_policies=("none", "static_router"),
               perf_iters: int = 3,
               write: bool = True):
    """The whole instrumented run: sampling legs (+ optional serving leg)
    under one tracer with jax.monitoring compile capture, assembled into
    a validated repro.obs.report/v1.  Tests inject tiny ``cfg``/``params``
    (and ``serve_cfg``/``serve_params``) to skip the registry models.

    Returns (report, tracer, paths) — ``paths`` empty if ``write=False``.
    """
    if cfg is None:
        cfg = get_config(arch).reduced()
    if cfg.family != "dit":
        raise ValueError(f"--arch must be a DiT config, got {cfg.name!r} "
                         f"(family {cfg.family!r})")
    tracer = trace_lib.Tracer()
    with tracer.capture_compile_events():
        if params is None:
            with tracer.span("init_dit", cat="obs"):
                params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
        sched = ddim.linear_schedule(1000)
        legs = collect_sampling(cfg, params, sched, tuple(policies),
                                n_steps=n_steps, batch=batch, seed=seed,
                                lazy_ratio=lazy_ratio, tracer=tracer)
        serving = None
        if serve:
            if serve_cfg is None:
                serve_cfg = get_config(serve_arch).reduced()
            if serve_params is None:
                with tracer.span("init_lm", cat="obs"):
                    serve_params = tf.init_lm(jax.random.PRNGKey(0),
                                              serve_cfg)
            serving = collect_serving(serve_cfg, serve_params,
                                      n_requests=serve_requests,
                                      n_slots=n_slots, seed=seed,
                                      lazy_ratio=lazy_ratio, slo=slo,
                                      tracer=tracer)
        perf_section = None
        if perf:
            perf_section = collect_perf(cfg, params, sched,
                                        tuple(perf_policies),
                                        n_steps=n_steps, batch=batch,
                                        seed=seed, lazy_ratio=lazy_ratio,
                                        tracer=tracer, iters=perf_iters)

    ctx = {"config": {"arch": cfg.name, "policies": list(policies),
                      "n_steps": n_steps, "batch": batch, "seed": seed,
                      "lazy_ratio": lazy_ratio, "serve": bool(serve),
                      "n_slots": n_slots if serve else None},
           "sampling": legs, "serving": serving, "perf": perf_section,
           "tracer": tracer}
    report = report_lib.build_report(ctx)
    verify_report(report)
    paths = write_artifacts(report, tracer, out_dir) if write else {}
    return report, tracer, paths


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="dit_xl2_256",
                    help="DiT config for the sampling legs (reduced)")
    ap.add_argument("--policies",
                    default=",".join(DEFAULT_POLICIES),
                    help="comma-separated cache policies to instrument")
    ap.add_argument("--steps", type=int, default=8,
                    help="DDIM sampling steps per policy leg")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lazy-ratio", type=float, default=0.4,
                    help="target ratio for ratio-driven policies and the "
                         "smoothcache threshold quantile")
    ap.add_argument("--serve", action="store_true",
                    help="append a telemetry-on continuous-batching leg")
    ap.add_argument("--serve-arch", default="llama3_2_1b",
                    help="LM config for the serving leg (reduced)")
    ap.add_argument("--serve-requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--slo", type=float,
                    default=serving_metrics.DEFAULT_SLO_LATENCY_S,
                    help="goodput latency SLO (virtual seconds)")
    ap.add_argument("--perf", action="store_true",
                    help="add the realized-vs-modeled perf section: AOT "
                         "compile timing, steady-state wall median + MAD, "
                         "device memory watermarks, achieved-throughput "
                         "fractions vs the dist/hlo model")
    ap.add_argument("--perf-policies", default="none,static_router",
                    help="comma-separated policies for the --perf legs")
    ap.add_argument("--perf-iters", type=int, default=3,
                    help="steady-state samples per --perf leg")
    ap.add_argument("--kernels", default="", choices=["", "xla", "pallas"],
                    help="kernel backend for every leg "
                         "(repro.kernels.backend): 'pallas' routes skips "
                         "through the skip-aware kernels (DESIGN.md "
                         "§Kernels); default keeps the XLA baseline")
    ap.add_argument("--out-dir", default=ARTIFACTS)
    args = ap.parse_args(argv)
    if args.kernels:
        kernel_backend.set_backend(args.kernels)

    names = tuple(n.strip() for n in args.policies.split(",") if n.strip())
    perf_names = tuple(n.strip() for n in args.perf_policies.split(",")
                       if n.strip())
    unknown = [n for n in names + (perf_names if args.perf else ())
               if n not in cache_lib.available_policies()]
    if unknown:
        ap.error(f"unknown policies {unknown}; "
                 f"available: {sorted(cache_lib.available_policies())}")

    report, tracer, paths = run_report(
        arch=args.arch, policies=names, n_steps=args.steps,
        batch=args.batch, seed=args.seed, lazy_ratio=args.lazy_ratio,
        serve=args.serve, serve_arch=args.serve_arch,
        serve_requests=args.serve_requests, n_slots=args.n_slots,
        slo=args.slo, perf=args.perf, perf_policies=perf_names,
        perf_iters=args.perf_iters, out_dir=args.out_dir)

    drift = report["metrics"]["drift_by_step"]
    heat = report["metrics"]["skip_heatmap"]
    print(f"obs report: arch={report['config']['arch']} "
          f"steps={report['config']['n_steps']} "
          f"policies={','.join(names)}")
    for name in names:
        print(f"  {name:14s} skip={heat[name]['realized_skip_ratio']:6.1%} "
              f"drift_rel_l2={drift[name]['rel_l2_mean']:.5f} "
              f"drift_cos={drift[name]['cosine_mean']:.5f}")
    n_compile = len(tracer.compile_events())
    print(f"  compile events captured: {n_compile}")
    if report["metrics"].get("service_percentiles"):
        s = report["metrics"]["service_percentiles"]
        print(f"  serving: {s['requests_per_s']:.3f} req/s  "
              f"goodput {s['goodput_per_s']:.3f}/s (SLO {s['slo_latency_s']}s)"
              f"  drift_rel_l2={s['drift_rel_l2_mean']:.5f}")
        print(f"  phases (p50): queue {s['queue_p50_s']:.2f}s  "
              f"prefill {s['prefill_p50_s']:.2f}s  "
              f"decode {s['decode_p50_s']:.2f}s")
    if report["metrics"].get("perf"):
        p = report["metrics"]["perf"]
        mw = p["memory_watermarks"]
        print(f"  perf ({p['harness']['iters']} iters/leg, "
              f"{mw['total_bytes'] / 2**20:.1f} MiB live via "
              f"{mw['source']}):")
        for name, leg in p["policies"].items():
            extra = ""
            if "measured_speedup_vs_none" in leg:
                extra = (f"  speedup_vs_none="
                         f"{leg['measured_speedup_vs_none']:.2f}x "
                         f"(modeled flop saving "
                         f"{leg['modeled_flop_saving_vs_none']:.1%})")
            print(f"    {name:14s} wall={leg['wall_ms_median']:.1f}ms "
                  f"± {leg['wall_ms_mad']:.1f} MAD  "
                  f"compile={leg['compile_s']:.2f}s  "
                  f"first={leg['first_execute_ms']:.1f}ms{extra}")
    for kind, path in paths.items():
        print(f"  {kind:7s} -> {path}")


if __name__ == "__main__":
    main()
