"""Serving launcher: batched greedy decode with optional lazy modes.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --lazy masked
"""
import argparse

import jax
import numpy as np

from repro.checkpoint.io import restore_checkpoint
from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.models import transformer as tf
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--lazy", default="off", choices=["off", "masked"])
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.lazy != "off":
        cfg = cfg.replace(lazy=LazyConfig(enabled=True, mode=args.lazy))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = restore_checkpoint(args.ckpt, params)
    eng = Engine(cfg, params, max_len=args.prompt_len + args.n_new + 8,
                 lazy_mode=args.lazy)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    res = eng.generate(prompt, n_new=args.n_new)
    print(f"arch={cfg.name} lazy={args.lazy}")
    for row in res.tokens:
        print("  ", row.tolist())
    print(f"realized lazy ratio: {res.realized_lazy_ratio:.1%}")


if __name__ == "__main__":
    main()
