"""Serving launcher: static-batch greedy decode or a trace-driven
continuous-batching workload, with optional lazy modes.

  # static batch, masked lazy decode
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --lazy masked

  # static batch under a 50% uniform lazy plan
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --lazy plan --lazy-ratio 0.5

  # continuous batching over a synthetic Poisson trace with mixed lengths
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --workload --n-requests 16 --arrival-rate 2.0 --lazy plan
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.io import restore_checkpoint
from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.core import lazy as lazy_lib
from repro.data.synthetic import request_trace
from repro.models import transformer as tf
from repro.serving.engine import ContinuousBatchingEngine, Engine


def build_plan(args, cfg, n_steps: int) -> lazy_lib.LazyPlan:
    """--plan loads a saved (T, L, 2) bool skip array (.npy/.npz); otherwise
    a uniform random plan at --lazy-ratio (the ablation baseline)."""
    if args.plan:
        data = np.load(args.plan)
        skip = data[data.files[0]] if hasattr(data, "files") else data
        return lazy_lib.LazyPlan(np.asarray(skip, bool))
    return lazy_lib.uniform_plan(n_steps, cfg.n_layers, 2, args.lazy_ratio,
                                 seed=args.seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--lazy", default="off", choices=["off", "masked", "plan"])
    ap.add_argument("--lazy-ratio", type=float, default=0.5,
                    help="uniform-plan skip ratio for --lazy plan")
    ap.add_argument("--plan", default="",
                    help="path to a saved (T, L, 2) bool skip plan")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    # trace-driven continuous-batching workload
    ap.add_argument("--workload", action="store_true",
                    help="serve a synthetic Poisson request trace through "
                         "the continuous-batching engine")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean request arrivals per virtual second")
    ap.add_argument("--n-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.lazy != "off":
        cfg = cfg.replace(lazy=LazyConfig(enabled=True, mode=args.lazy))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = restore_checkpoint(args.ckpt, params)

    if args.workload:
        # two prompt-length buckets (like bench_serving) bound the jitted
        # prefill retrace count while keeping the length mixture
        trace = request_trace(args.n_requests, cfg.vocab_size, seed=args.seed,
                              mean_interarrival=1.0 / args.arrival_rate,
                              short_prompt=(4, 4), long_prompt=(12, 12))
        max_len = max(len(r.prompt) + r.max_new for r in trace) + 8
        plan = (build_plan(args, cfg, n_steps=16)
                if args.lazy == "plan" else None)
        eng = ContinuousBatchingEngine(cfg, params, n_slots=args.n_slots,
                                       max_len=max_len, lazy_mode=args.lazy,
                                       plan=plan)
        t0 = time.perf_counter()
        res = eng.run(trace)
        wall = time.perf_counter() - t0
        s = res.metrics.summary()
        n_tok = sum(len(res.outputs[r.rid]) - len(r.prompt) for r in trace)
        print(f"arch={cfg.name} lazy={args.lazy} policy=continuous "
              f"slots={args.n_slots} requests={len(trace)}")
        print(f"  service clock : {s['requests_per_s']:.3f} req/s, "
              f"{s['tokens_per_s']:.2f} tok/s over {s['virtual_time_s']:.2f}s")
        print(f"  latency       : p50={s['latency_p50_s']:.2f}s "
              f"p95={s['latency_p95_s']:.2f}s  "
              f"ttft p50={s['ttft_p50_s']:.2f}s p95={s['ttft_p95_s']:.2f}s")
        print(f"  realized lazy ratio: {s['realized_lazy_ratio']:.1%}  "
              f"mean active slots: {s['mean_active_slots']:.2f}  "
              f"mean queue depth: {s['mean_queue_depth']:.2f}")
        print(f"  host wall-clock: {wall:.2f}s "
              f"({n_tok / max(wall, 1e-9):.1f} tok/s)")
        return

    plan = build_plan(args, cfg, n_steps=args.n_new) \
        if args.lazy == "plan" else None
    eng = Engine(cfg, params, max_len=args.prompt_len + args.n_new + 8,
                 lazy_mode=args.lazy, plan=plan)
    prompt = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    res = eng.generate(prompt, n_new=args.n_new)
    wall = time.perf_counter() - t0
    print(f"arch={cfg.name} lazy={args.lazy}")
    for row in res.tokens:
        print("  ", row.tolist())
    print(f"tokens/sec: {args.batch * args.n_new / max(wall, 1e-9):.1f} "
          f"(wall {wall:.2f}s)  realized lazy ratio: "
          f"{res.realized_lazy_ratio:.1%}")


if __name__ == "__main__":
    main()
