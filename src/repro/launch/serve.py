"""Serving launcher: static-batch greedy decode or a trace-driven
continuous-batching workload, with a pluggable cache policy.

  # static batch, masked lazy decode (legacy alias for --policy lazy_gate)
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --lazy masked

  # static batch under a 50% uniform lazy plan
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --lazy plan --lazy-ratio 0.5

  # any registered cache policy (repro.cache); smoothcache/static_router
  # self-calibrate with a quick probe decode unless --calibration is given
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --policy smoothcache --error-threshold 0.15
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --policy static_router --lazy-ratio 0.5 --workload

  # continuous batching over a synthetic Poisson trace with mixed lengths
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --workload --n-requests 16 --arrival-rate 2.0 --lazy plan

  # data-parallel sampling over an 8-device mesh (DiT archs route through
  # the sharded fused trajectory executor; per-example outputs are
  # bit-exact vs --mesh data=1)
  PYTHONPATH=src python -m repro.launch.serve --arch dit_xl2_256 \
      --policy static_router --mesh data=8 --batch 8
"""
import hashlib
import os
import sys


def _force_mesh_devices() -> None:
    """--mesh data=N needs N devices BEFORE jax initializes its backend
    (the host-platform device count is locked at first init), so peek at
    argv pre-import — both the '--mesh data=N' and '--mesh=data=N' forms.
    Malformed specs are left for argparse to report; an explicit
    user-provided device-count flag wins."""
    spec = ""
    if "--mesh" in sys.argv[:-1]:
        spec = sys.argv[sys.argv.index("--mesh") + 1]
    else:
        spec = next((a[len("--mesh="):] for a in sys.argv
                     if a.startswith("--mesh=")), "")
    if not spec:
        return
    try:
        n = 1
        for part in spec.split(","):
            n *= int(part.partition("=")[2])
    except ValueError:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


_force_mesh_devices()

import argparse
import contextlib
import time

import jax
import numpy as np

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.checkpoint.io import restore_checkpoint
from repro.configs.base import LazyConfig
from repro.configs.registry import get_config
from repro.core import lazy as lazy_lib
from repro.data.synthetic import request_trace
from repro.dist import ctx as dist_ctx
from repro.kernels import backend as kernel_backend
from repro.models import transformer as tf
from repro.serving.engine import ContinuousBatchingEngine, Engine


def build_plan(args, cfg, n_steps: int) -> lazy_lib.LazyPlan:
    """--plan loads a saved (T, L, 2) bool skip array (.npy/.npz); otherwise
    a uniform random plan at --lazy-ratio (the ablation baseline)."""
    if args.plan:
        data = np.load(args.plan)
        skip = data[data.files[0]] if hasattr(data, "files") else data
        return lazy_lib.LazyPlan(np.asarray(skip, bool))
    return lazy_lib.uniform_plan(n_steps, cfg.n_layers, 2, args.lazy_ratio,
                                 seed=args.seed)


def _calibration(args, cfg, params, sched=None):
    """--calibration loads a saved artifact; otherwise a quick in-process
    probe self-calibrates on the spot (calibrate_lm for decoders,
    calibrate_dit over a DDIM probe trajectory for DiT archs)."""
    if args.calibration:
        art = calibrate_lib.CalibrationArtifact.load(args.calibration)
        print(f"calibration: {args.calibration} (kind={art.kind} "
              f"arch={art.arch} T={art.n_steps})")
        return art
    if cfg.family == "dit":
        import jax.numpy as jnp
        labels = jnp.arange(2) % cfg.dit_n_classes
        print(f"calibration: none given — probing a {args.calib_steps}-step "
              "DDIM trajectory in-process")
        art = calibrate_lib.calibrate_dit(
            params, cfg, sched, key=jax.random.PRNGKey(args.seed),
            labels=labels, n_steps=args.calib_steps)
    else:
        rng = np.random.default_rng(args.seed)
        prompt = rng.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32)
        print(f"calibration: none given — probing {args.calib_steps} decode "
              f"steps in-process")
        art = calibrate_lib.calibrate_lm(params, cfg, prompt, args.calib_steps)
    if args.save_calibration:
        print(f"calibration saved -> {art.save(args.save_calibration)}")
    return art


def build_policy(args, cfg, params, n_steps: int, sched=None):
    """--policy <name> -> a repro.cache policy; '' defers to the legacy
    --lazy flags (which the engines map onto policies internally)."""
    name = args.policy
    if not name:
        return None
    if name == "plan":
        return cache_lib.get_policy("plan", plan=build_plan(args, cfg,
                                                            n_steps).skip)
    if name == "stride":
        return cache_lib.get_policy("stride", stride=args.stride)
    if name == "lazy_gate":
        return cache_lib.get_policy("lazy_gate", threshold=cfg.lazy.threshold)
    if name == "smoothcache":
        art = _calibration(args, cfg, params, sched)
        thr = (args.error_threshold if args.error_threshold is not None
               else art.quantile_threshold(args.lazy_ratio))
        return cache_lib.get_policy("smoothcache", calibration=art,
                                    error_threshold=thr)
    if name == "static_router":
        art = (_calibration(args, cfg, params, sched)
               if args.calibration or args.calibrate else None)
        return cache_lib.get_policy("static_router", ratio=args.lazy_ratio,
                                    calibration=art, seed=args.seed)
    return cache_lib.get_policy(name)


def serve_dit(args, cfg, tracer=None):
    """DiT archs serve image sampling, not token decode: the whole DDIM
    trajectory runs through the fused single-compile executor
    (sampling/trajectory.py) — one XLA program per (config, policy,
    step-count, guidance, eta, mesh), policy plan rows scanned as traced
    selects.  Under ``--mesh data=N`` the batch shards along the data
    axis; the printed per-example sha256 digests are bit-identical across
    mesh sizes (the parity contract, tests/test_trajectory_sharded.py).

    Timing is AOT-separated (repro.obs.profile): ``.lower()`` /
    ``.compile()`` wall apart from the first execution — the old
    first-call number lumped trace + compile + run into one misleading
    "compile" figure — and steady state is the profile harness's
    outlier-rejected median ± MAD, not a single sample."""
    from repro.cache import policy as cache_policy_lib
    from repro.models import dit as dit_lib
    from repro.obs import profile as profile_lib
    from repro.sampling import ddim, trajectory

    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = restore_checkpoint(args.ckpt, params)
    sched = ddim.linear_schedule(1000)
    n_steps = args.n_new                   # sampling steps for DiT archs
    policy = build_policy(args, cfg, params, n_steps, sched)
    plan = (build_plan(args, cfg, n_steps).skip
            if policy is None and args.lazy == "plan" else None)
    labels = (np.random.default_rng(args.seed)
              .integers(0, cfg.dit_n_classes, (args.batch,)).astype(np.int32))
    labels = jax.numpy.asarray(labels)

    pol = cache_policy_lib.resolve(policy, lazy_mode=args.lazy, plan=plan,
                                   threshold=cfg.lazy.threshold)
    fn = trajectory.build_sampler(cfg, pol, n_steps, 1.5, float(args.eta),
                                  batch=int(labels.shape[0]))
    sample_args = trajectory.prepare_inputs(
        cfg, sched, pol, key=jax.random.PRNGKey(args.seed), labels=labels,
        n_steps=n_steps, eta=args.eta)
    span = (tracer.span if tracer is not None
            else (lambda *a, **k: contextlib.nullcontext()))
    with span("sample:aot_compile", cat="serve"):
        compiled, aot = profile_lib.aot_compile(fn, params, *sample_args)
    t0 = time.perf_counter()
    with span("sample:first_execute", cat="serve"):
        x, aux = compiled(params, *sample_args)
        jax.block_until_ready(x)
    first_exec = time.perf_counter() - t0
    with span("sample:steady", cat="serve"):
        m = profile_lib.measure(
            lambda: compiled(params, *sample_args)[0], iters=3, warmup=0)
    ratio = float(aux["n_skipped"]) / max(
        n_steps * cfg.n_layers * trajectory.N_MODULES, 1)
    policy_label = args.policy or f"lazy:{args.lazy}"
    mesh = dist_ctx.current_mesh()
    mesh_label = ("x".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
                  if mesh is not None else "single")
    print(f"arch={cfg.name} policy={policy_label} sampler=fused-trajectory "
          f"steps={n_steps} batch={args.batch} eta={args.eta} "
          f"mesh={mesh_label} shape={tuple(x.shape)}")
    print(f"  AOT: lower {aot['lower_s']:.2f}s, compile "
          f"{aot['compile_s']:.2f}s; first execute {first_exec:.3f}s")
    print(f"  steady state: {m.median_s:.3f}s ± {m.mad_s * 1e3:.1f}ms MAD "
          f"over {m.iters} kept iters "
          f"({m.median_s / n_steps * 1e3:.1f} ms/step, one compiled scan)")
    mw = profile_lib.memory_watermarks()
    peak = mw.get("peak_bytes")
    print(f"  device memory: {mw['total_bytes'] / 2**20:.1f} MiB live"
          + (f", {peak / 2**20:.1f} MiB peak" if peak else "")
          + f" ({mw['source']})")
    print(f"  realized skip ratio: {ratio:.1%}")
    if mesh is not None:
        print(f"  latent sharding: {x.sharding.spec} over "
              f"{len(np.asarray(mesh.devices).flat)} devices")
    # per-example digests: diff these across --mesh runs to verify the
    # bit-exactness contract from the CLI (CI does exactly that)
    for i, row in enumerate(np.asarray(x)):
        print(f"  sample[{i}] sha256={hashlib.sha256(row.tobytes()).hexdigest()[:16]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--policy", default="",
                    choices=[""] + list(cache_lib.available_policies()),
                    help="cache policy (repro.cache); supersedes --lazy, "
                         "which stays as an alias")
    ap.add_argument("--lazy", default="off", choices=["off", "masked", "plan"],
                    help="legacy alias: off->none, masked->lazy_gate, "
                         "plan->plan policy")
    ap.add_argument("--lazy-ratio", type=float, default=0.5,
                    help="skip ratio: uniform plan for --lazy plan, target "
                         "ratio for --policy static_router, threshold "
                         "quantile fallback for --policy smoothcache")
    ap.add_argument("--plan", default="",
                    help="path to a saved (T, L, 2) bool skip plan")
    ap.add_argument("--calibration", default="",
                    help="saved calibration artifact JSON "
                         "(repro.cache.calibrate)")
    ap.add_argument("--calibrate", action="store_true",
                    help="force an in-process probe calibration even for "
                         "policies that can run without one")
    ap.add_argument("--save-calibration", default="",
                    help="write the in-process probe calibration here")
    ap.add_argument("--calib-steps", type=int, default=16,
                    help="probe decode steps for in-process calibration")
    ap.add_argument("--error-threshold", type=float, default=None,
                    help="smoothcache relative-error threshold (default: "
                         "the --lazy-ratio quantile of calibrated errors)")
    ap.add_argument("--stride", type=int, default=2,
                    help="refresh period for --policy stride")
    ap.add_argument("--mesh", default="",
                    help="device mesh spec, e.g. 'data=8' or "
                         "'data=4,model=2': DiT sampling shards the batch "
                         "over the data axis (per-example outputs bit-exact "
                         "vs data=1); serving engines shard their slot "
                         "pools.  CPU runs force the host device count "
                         "automatically")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="DDIM stochasticity (eta > 0 draws per-step "
                         "per-example noise from the reserved keys; "
                         "DiT archs only)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    # trace-driven continuous-batching workload
    ap.add_argument("--workload", action="store_true",
                    help="serve a synthetic Poisson request trace through "
                         "the continuous-batching engine")
    # asyncio streaming front door (serving/server.py)
    ap.add_argument("--listen", action="store_true",
                    help="start the asyncio streaming front door: NDJSON "
                         "over TCP, per-request SLO-aware policy selection "
                         "from a policy bank (quality|balanced|latency), "
                         "priority preemption and load shedding "
                         "(serving/server.py, serving/admission.py)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --listen")
    ap.add_argument("--port", type=int, default=8422,
                    help="bind port for --listen (0 = ephemeral; the CI "
                         "smoke uses 0)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="serving context budget (prompt + decode) for "
                         "--listen")
    ap.add_argument("--smoke-client", action="store_true",
                    help="with --listen: stream one request end-to-end "
                         "over localhost from a client thread, assert the "
                         "first-chunk latency was recorded, then exit "
                         "(the CI tripwire for the asyncio path)")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean request arrivals per virtual second")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of this run "
                         "(repro.obs: compile events, serving decisions "
                         "on the service clock) to this path")
    ap.add_argument("--kernels", default="", choices=["", "xla", "pallas"],
                    help="kernel backend (repro.kernels.backend): 'xla' "
                         "(default) keeps the where-select bit-exactness "
                         "baseline; 'pallas' routes skips through the "
                         "skip-aware kernels — cond-hoisted plan skips, "
                         "fused gate+select, fused DDIM update, and the "
                         "plan-aware flash kernel on compiled-Pallas "
                         "targets (DESIGN.md §Kernels)")
    args = ap.parse_args()
    if args.kernels:
        kernel_backend.set_backend(args.kernels)

    with contextlib.ExitStack() as stack:
        tracer = None
        if args.trace:
            from repro.obs import trace as obs_trace
            tracer = obs_trace.Tracer()
            stack.enter_context(tracer.capture_compile_events())
            # callback (not a trailing call) so every early return of the
            # serve body still writes + validates the trace on exit
            stack.callback(_write_trace, tracer, args.trace)
        _serve(args, tracer)


def _write_trace(tracer, path: str) -> None:
    from repro.obs import trace as obs_trace
    obs_trace.validate_chrome_trace(tracer.sorted_events())
    print(f"trace -> {tracer.to_chrome(path)}")


def _serve(args, tracer=None):
    cfg = get_config(args.arch).reduced()
    if args.mesh:
        # the --mesh parity contract (per-example outputs bit-exact across
        # mesh sizes) needs the strict matmul path: at default precision
        # XLA CPU picks its GEMM backend by shape, so per-shard and
        # full-batch matmuls round differently
        jax.config.update("jax_default_matmul_precision", "highest")
    mesh_cm = (dist_ctx.mesh(**dist_ctx.parse_mesh_spec(args.mesh))
               if args.mesh else contextlib.nullcontext())
    if cfg.family == "dit":
        if args.listen:
            raise SystemExit(
                "--listen streams token decode; DiT archs serve whole "
                "sampling trajectories (use the default fused path)")
        # DiT archs sample images: route through the fused single-compile
        # trajectory executor instead of the token-decode engines
        with mesh_cm:
            serve_dit(args, cfg, tracer)
        return
    needs_gates = (args.policy == "lazy_gate"
                   or (not args.policy and args.lazy != "off"))
    if needs_gates:
        mode = args.lazy if args.lazy != "off" else "masked"
        cfg = cfg.replace(lazy=LazyConfig(enabled=True, mode=mode))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        params = restore_checkpoint(args.ckpt, params)
    policy_label = args.policy or f"lazy:{args.lazy}"

    if args.listen:
        with mesh_cm:
            _listen(args, cfg, params, tracer)
        return

    if args.workload:
        # two prompt-length buckets (like bench_serving) bound the jitted
        # prefill retrace count while keeping the length mixture
        trace = request_trace(args.n_requests, cfg.vocab_size, seed=args.seed,
                              mean_interarrival=1.0 / args.arrival_rate,
                              short_prompt=(4, 4), long_prompt=(12, 12))
        max_len = max(len(r.prompt) + r.max_new for r in trace) + 8
        policy = build_policy(args, cfg, params, n_steps=16)
        plan = (build_plan(args, cfg, n_steps=16)
                if policy is None and args.lazy == "plan" else None)
        with mesh_cm:
            eng = ContinuousBatchingEngine(cfg, params, n_slots=args.n_slots,
                                           max_len=max_len,
                                           lazy_mode=args.lazy,
                                           plan=plan, policy=policy,
                                           tracer=tracer)
            t0 = time.perf_counter()
            res = eng.run(trace)
            wall = time.perf_counter() - t0
            # engines are re-entrant (pool/scheduler rebuilt per call), so
            # the steady-state number comes from the shared harness, not
            # the compile-polluted first run
            from repro.obs import profile as profile_lib
            m = profile_lib.measure(lambda: eng.run(trace), iters=2,
                                    warmup=0)
        s = res.metrics.summary()
        n_tok = sum(len(res.outputs[r.rid]) - len(r.prompt) for r in trace)
        print(f"arch={cfg.name} policy={policy_label} batching=continuous "
              f"slots={args.n_slots} requests={len(trace)}")
        print(f"  service clock : {s['requests_per_s']:.3f} req/s, "
              f"{s['tokens_per_s']:.2f} tok/s over {s['virtual_time_s']:.2f}s")
        print(f"  latency       : p50={s['latency_p50_s']:.2f}s "
              f"p95={s['latency_p95_s']:.2f}s  "
              f"ttft p50={s['ttft_p50_s']:.2f}s p95={s['ttft_p95_s']:.2f}s")
        print(f"  phases (p50/p95): queue {s['queue_p50_s']:.2f}/"
              f"{s['queue_p95_s']:.2f}s  prefill {s['prefill_p50_s']:.2f}/"
              f"{s['prefill_p95_s']:.2f}s  decode {s['decode_p50_s']:.2f}/"
              f"{s['decode_p95_s']:.2f}s")
        print(f"  realized lazy ratio: {s['realized_lazy_ratio']:.1%}  "
              f"mean active slots: {s['mean_active_slots']:.2f}  "
              f"mean queue depth: {s['mean_queue_depth']:.2f}")
        print(f"  host wall-clock: first run {wall:.2f}s (incl. compile); "
              f"steady {m.median_s:.2f}s ± {m.mad_s:.2f}s MAD "
              f"({n_tok / max(m.median_s, 1e-9):.1f} tok/s)")
        return

    policy = build_policy(args, cfg, params, n_steps=args.n_new)
    plan = build_plan(args, cfg, n_steps=args.n_new) \
        if policy is None and args.lazy == "plan" else None
    _static_batch(args, cfg, params, policy, plan, policy_label, mesh_cm)


def _listen(args, cfg, params, tracer=None) -> None:
    """--listen: run the asyncio streaming front door around an SLO-aware
    engine (policy bank + admission controller).  --smoke-client streams
    one request from a client thread and asserts the wall-clock
    first-chunk latency landed in the server stats, then exits — the CI
    tripwire for the whole asyncio path."""
    import asyncio

    from repro.serving import server as server_lib
    from repro.serving.admission import (AdmissionController,
                                         default_policy_bank)

    calib = (calibrate_lib.CalibrationArtifact.load(args.calibration)
             if args.calibration else None)
    bank = default_policy_bank(lazy_ratio=args.lazy_ratio, seed=args.seed,
                               calibration=calib)
    eng = ContinuousBatchingEngine(
        cfg, params, n_slots=args.n_slots, max_len=args.max_len,
        policy_bank=bank, admission=AdmissionController(), tracer=tracer)
    srv = server_lib.StreamingServer(eng, host=args.host, port=args.port)

    async def _amain():
        await srv.start()
        ratios = {k: round(v, 3) for k, v in eng.bank_ratios.items()}
        print(f"listening on {srv.host}:{srv.port} arch={cfg.name} "
              f"slots={args.n_slots} bank={ratios}", flush=True)
        if not args.smoke_client:
            await srv.serve_until_shutdown()
            return
        loop = asyncio.get_running_loop()

        def client():
            prompt = np.random.default_rng(args.seed).integers(
                0, cfg.vocab_size, args.prompt_len)
            evs = server_lib.request_once(
                srv.host, srv.port, prompt, max_new=args.n_new,
                slo_latency_s=1e4, max_skip_ratio=0.9, priority=1)
            return evs, server_lib.fetch_stats(srv.host, srv.port)

        events, stats = await loop.run_in_executor(None, client)
        kinds = [e["event"] for e in events]
        fc = stats["first_chunk_latency_s"]
        print(f"smoke: events={kinds}")
        print(f"smoke: first-chunk latency n={fc['n']} p50={fc['p50']}")
        assert kinds and kinds[-1] == "done", \
            f"smoke request did not complete: {kinds}"
        n_tok = sum(1 for k in kinds if k == "token")
        assert n_tok == args.n_new, \
            f"expected {args.n_new} streamed tokens, got {n_tok}"
        assert fc["n"] >= 1 and fc["p50"] is not None and fc["p50"] > 0, \
            "first-chunk latency was not recorded"
        await srv.stop()
        print("smoke: OK")

    asyncio.run(_amain())


def _static_batch(args, cfg, params, policy, plan, policy_label,
                  mesh_cm) -> None:
    prompt = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    with mesh_cm:
        eng = Engine(cfg, params, max_len=args.prompt_len + args.n_new + 8,
                     lazy_mode=args.lazy, plan=plan, policy=policy)
        t0 = time.perf_counter()
        res = eng.generate(prompt, n_new=args.n_new)
        wall = time.perf_counter() - t0
        from repro.obs import profile as profile_lib
        m = profile_lib.measure(
            lambda: eng.generate(prompt, n_new=args.n_new), iters=2,
            warmup=0)
    print(f"arch={cfg.name} policy={policy_label}")
    for row in res.tokens:
        print("  ", row.tolist())
    print(f"tokens/sec: {args.batch * args.n_new / max(m.median_s, 1e-9):.1f} "
          f"steady (first run incl. compile {wall:.2f}s; steady "
          f"{m.median_s:.2f}s ± {m.mad_s:.2f}s MAD)  realized lazy ratio: "
          f"{res.realized_lazy_ratio:.1%}")


if __name__ == "__main__":
    main()
