"""Production mesh factory.

A FUNCTION (not a module constant) so importing never touches jax device
state.  TPU v5e targets: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

# hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
