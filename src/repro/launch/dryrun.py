import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / collective traffic for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  # fused DDIM trajectory (DiT archs): ONE compile for n sampling steps,
  # whole-trajectory FLOPs/bytes via the loop-aware dist/hlo analyzer
  PYTHONPATH=src python -m repro.launch.dryrun --arch dit_xl2_256 \
      --shape sample_8 --policy static_router
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import contextlib
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.base import LazyConfig, ModelConfig, InputShape
from repro.configs.registry import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                                    long_context_policy)
from repro.dist import ctx
from repro.dist import hlo as hlo_lib
from repro.dist import sharding as sh
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import transformer as tf
from repro.train import optim, trainer

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract inputs for one (arch, shape): weak-type-correct, shardable,
    zero allocation."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.frontend_stub:
            n_frames = 256
            out["embeds"] = jax.ShapeDtypeStruct((B, n_frames, cfg.frontend_dim),
                                                 jnp.float32)
            out["tokens"] = jax.ShapeDtypeStruct((B, S - n_frames + 1), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
    elif shape.kind == "prefill":
        if cfg.frontend_stub:
            n_frames = 256
            out["embeds"] = jax.ShapeDtypeStruct((B, n_frames, cfg.frontend_dim),
                                                 jnp.float32)
            out["tokens"] = jax.ShapeDtypeStruct((B, S - n_frames), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode: ONE new token against a seq_len cache
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        out["index"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# cache-policy plan rows (decode dry-runs)
# ---------------------------------------------------------------------------


def build_cli_policy(opts: dict):
    """--policy <name> (+ --calibration / thresholds) -> a repro.cache
    policy instance.  Shared by the decode plan-row path and the fused
    sample_<n> trajectory path."""
    name = opts["policy"]
    kw = {}
    if name == "stride":
        kw["stride"] = int(opts.get("stride") or 2)
    if name in ("smoothcache", "static_router") and opts.get("calibration"):
        kw["calibration"] = calibrate_lib.CalibrationArtifact.load(
            opts["calibration"])
    if name == "smoothcache":
        if "calibration" not in kw:
            raise ValueError("--policy smoothcache needs --calibration "
                             "<artifact.json> (repro.cache.calibrate)")
        thr = opts.get("error_threshold")
        kw["error_threshold"] = (
            thr if thr is not None
            else kw["calibration"].quantile_threshold(
                opts.get("policy_ratio", 0.5)))
    if name == "static_router":
        kw["ratio"] = opts.get("policy_ratio", 0.5)
    return cache_lib.get_policy(name, **kw)


def policy_plan_step(cfg: ModelConfig, opts: dict) -> np.ndarray:
    """--policy <name> -> one (n_layers, 2) static plan row for the decode
    dry-run (the compiled HLO drops the skipped modules; dist/hlo then
    quantifies the saving).  Row ``--policy-step`` of the policy's compiled
    schedule is used — an odd mid-trajectory default, since first/last
    steps are always fresh and even steps are stride refresh (all-fresh)
    rows."""
    name = opts["policy"]
    if name == "none":
        return cache_lib.noop_plan_row(cfg.n_layers)    # no-skip baseline
    pol = build_cli_policy(opts)
    steps = max(int(opts.get("policy_steps") or 8), 3)
    plan = pol.compile_plan(steps, cfg.n_layers, 2)
    if plan is None:
        raise ValueError(f"policy {name!r} compiles no static plan; the "
                         "dry-run needs compile-time rows (use "
                         "stride/smoothcache/static_router, or 'none' for "
                         "the no-skip baseline)")
    t = int(opts.get("policy_step", 3)) % steps
    return np.asarray(plan.skip[t], bool)


# ---------------------------------------------------------------------------
# fused-sampler trajectory dry-runs (--shape sample_<n>, DiT archs)
# ---------------------------------------------------------------------------


SAMPLE_BATCH = 2          # conditional rows; CFG doubles them in-program
SAMPLE_CFG_SCALE = 1.5


def run_sample(arch: str, shape_name: str, *, tag: str = "",
               opts: Optional[dict] = None) -> dict:
    """--shape sample_<n>: lower + compile the FUSED DDIM trajectory
    executor (sampling/trajectory.py) ONCE and account the whole
    trajectory through the loop-aware dist/hlo analyzer — the sampling
    scan body is multiplied by its trip count (n sampling steps), so the
    reported FLOPs/bytes cover all n denoiser evaluations in a single
    compiled program.  Any --policy works: plan-mode rows ride the scan as
    traced selects (compute stays in the HLO — the traced-vs-static
    tradeoff documented in DESIGN.md §Trajectory), dynamic policies decide
    in-trace, 'none' is the no-skip baseline.

    ``--mesh data=N`` lowers the SHARDED executor instead: the batch
    (lifted to the data-axis size when the default is smaller) shards
    along ``data``, and the report carries per-device vs global FLOPs
    plus the collective traffic of the partitioned scan body
    (dist/hlo.sharded_totals)."""
    opts = opts or {}
    n_steps = int(shape_name.split("_", 1)[1])
    if n_steps < 1:
        raise ValueError(f"sample shape needs >= 1 step, got {shape_name!r}")
    cfg = get_config(arch)
    if cfg.family != "dit":
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "why": "sample_<n> trajectory shapes are DiT-only"}

    from repro.models import dit as dit_lib
    from repro.sampling import ddim as ddim_lib
    from repro.sampling import trajectory

    name = opts.get("policy") or "none"
    if name == "none":
        # baseline trajectory measures the un-gated model (run_one's rule)
        cfg = cfg.replace(lazy=LazyConfig(enabled=False))
        pol = cache_lib.get_policy("none")
    else:
        pol = build_cli_policy(dict(opts, policy=name))

    mesh_axes = ctx.parse_mesh_spec(opts.get("mesh") or "")
    # lift the tiny default batch to one example per data shard so the
    # sharded lowering actually partitions something
    batch = (max(SAMPLE_BATCH, mesh_axes["data"]) if opts.get("mesh")
             else SAMPLE_BATCH)
    mesh_label = ("-".join(f"{a}{n}" for a, n in mesh_axes.items())
                  if opts.get("mesh") else "single")

    plan = (pol.device_plan(n_steps, cfg.n_layers, 2)
            if pol.exec_mode == "plan" else None)
    state0 = pol.init_traced_state(n_steps=n_steps, n_layers=cfg.n_layers,
                                   n_modules=2)

    params_abs = jax.eval_shape(lambda k: dit_lib.init_dit(k, cfg),
                                jax.random.PRNGKey(0))
    sched_abs = jax.eval_shape(lambda: ddim_lib.linear_schedule(1000))
    ts, ts_prev = trajectory.timestep_arrays(1000, n_steps)
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    labels_abs = jax.ShapeDtypeStruct((batch,), jnp.int32)
    z0_abs = jax.ShapeDtypeStruct(
        (batch, cfg.dit_input_size, cfg.dit_input_size,
         cfg.dit_in_channels), jnp.float32)

    mesh_cm = (ctx.mesh(**mesh_axes) if opts.get("mesh")
               else contextlib.nullcontext())
    t0 = time.time()
    with mesh_cm:
        fn = trajectory.build_sampler(cfg, pol, n_steps, SAMPLE_CFG_SCALE,
                                      batch=batch)
        lowered = fn.lower(params_abs, sched_abs, ts, ts_prev, z0_abs,
                           key_abs, labels_abs, plan, state0)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mod = hlo_lib.sharded_totals(compiled.as_text())
    flops, bytes_acc = float(mod["flops"]), float(mod["bytes"])
    mem = compiled.memory_analysis()
    n_params = count_params_abs(params_abs)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    coll_s = hlo_lib.collective_seconds(mod["collective"],
                                        max(mesh_axes["data"], 1), ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    plan_ratio = (float(np.asarray(plan).mean()) if plan is not None else 0.0)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_label,
        "kind": "sample", "n_steps": n_steps, "batch": batch,
        "cfg_scale": SAMPLE_CFG_SCALE, "tag": tag,
        "policy": name, "exec_mode": pol.exec_mode,
        "plan_skip_ratio": plan_ratio,
        "n_params": n_params,
        "partitions": mod["partitions"],
        "compiles": 1,          # the whole trajectory is one executable
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc,
                 "flops_global": float(mod["flops_global"]),
                 "bytes_global": float(mod["bytes_global"]),
                 "flops_per_step": flops / n_steps,
                 "bytes_per_step": bytes_acc / n_steps},
        "collectives": mod["collective"],
        "roofline": {**terms,
                     "dominant": max(terms, key=terms.get),
                     "model_flops_global": None,
                     "model_flops_per_device": None,
                     "useful_compute_ratio": None},
    }


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def _abstract_params(cfg: ModelConfig, window_override):
    return jax.eval_shape(
        lambda k: tf.init_lm(k, cfg, window_override=window_override),
        jax.random.PRNGKey(0))


def build_step(cfg: ModelConfig, shape: InputShape, mesh, *,
               window_override: Optional[int], seq_parallel: bool = True,
               remat: bool = True, opts: Optional[dict] = None):
    """Returns (jitted_fn, kwargs_of_ShapeDtypeStructs).

    opts (§Perf hillclimb knobs): param_mode ('fsdp'|'tp_only'),
    shard_cache_heads (bool), lazy_plan (float skip ratio, decode only)."""
    opts = opts or {}
    ins = input_specs(cfg, shape)
    params_abs = _abstract_params(cfg, window_override)
    p_sh = sh.param_shardings(params_abs, mesh,
                              mode=opts.get("param_mode", "fsdp"))
    B = shape.global_batch
    carry_spec = sh.seq_parallel_spec(mesh) if seq_parallel else None
    csh = NamedSharding(mesh, carry_spec) if carry_spec is not None else None

    if shape.kind == "train":
        opt_abs = jax.eval_shape(optim.adamw_init, params_abs)
        o_sh = jax.tree.map(
            lambda l, s: NamedSharding(mesh, s.spec) if hasattr(l, "shape")
            and l.ndim > 0 else NamedSharding(mesh, P()),
            opt_abs,
            optim.AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                             p_sh, p_sh))

        def train_step(params, opt_state, tokens, embeds=None):
            def loss_fn(p):
                return trainer.lm_loss(p, cfg, tokens, embeds=embeds,
                                       remat=remat, carry_sharding=csh)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
            params, opt_state = optim.adamw_update(
                opt_state, grads, params, lr=1e-4, weight_decay=0.01)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        tok_sh = sh.batch_sharding(mesh, B, 2)
        args = {"params": params_abs, "opt_state": opt_abs,
                "tokens": ins["tokens"]}
        in_sh = {"params": p_sh, "opt_state": o_sh, "tokens": tok_sh}
        if "embeds" in ins:
            args["embeds"] = ins["embeds"]
            in_sh["embeds"] = sh.batch_sharding(mesh, B, 3)
        fn = jax.jit(train_step,
                     in_shardings=tuple(in_sh[k] for k in args),
                     out_shardings=(p_sh, o_sh, None))
        return fn, tuple(args[k] for k in args)

    if shape.kind == "prefill":
        def prefill_step(params, cache, tokens, embeds=None):
            logits, cache, _, _ = tf.decode_step(
                params, cfg, tokens, jnp.int32(0), cache, embeds=embeds,
                window_override=window_override, last_logit_only=True)
            return logits, cache

        cache_abs = jax.eval_shape(
            lambda: tf.init_decode_cache(cfg, B, shape.seq_len,
                                         window_override=window_override))
        c_sh = sh.cache_shardings(cache_abs, mesh, B)
        tok_sh = sh.batch_sharding(mesh, B, 2)
        args = {"params": params_abs, "cache": cache_abs,
                "tokens": ins["tokens"]}
        in_sh = {"params": p_sh, "cache": c_sh, "tokens": tok_sh}
        if "embeds" in ins:
            args["embeds"] = ins["embeds"]
            in_sh["embeds"] = sh.batch_sharding(mesh, B, 3)
        fn = jax.jit(prefill_step,
                     in_shardings=tuple(in_sh[k] for k in args),
                     out_shardings=(None, c_sh))
        return fn, tuple(args[k] for k in args)

    # decode
    cache_abs = jax.eval_shape(
        lambda: tf.init_decode_cache(cfg, B, shape.seq_len,
                                     window_override=window_override))
    c_sh = sh.cache_shardings(cache_abs, mesh, B,
                              mode=opts.get("cache_mode"),
                              shard_heads=opts.get("shard_cache_heads", False))

    lazy_ratio = opts.get("lazy_plan")
    if lazy_ratio is not None or opts.get("policy"):
        # §Perf: static lazy plan, layers unrolled -> skipped modules absent
        # from the compiled HLO (the paper's technique as deployed on TPU).
        # --policy routes the row through the cache-policy subsystem;
        # --lazy-plan <ratio> stays as the random-row alias.
        if opts.get("policy"):
            plan_step = policy_plan_step(cfg, opts)
        else:
            rng = np.random.default_rng(0)
            plan_step = rng.random((cfg.n_layers, 2)) < lazy_ratio
        lazy_abs = jax.eval_shape(
            lambda: tf.init_lazy_decode_cache(cfg, B,
                                              window_override=window_override))
        lz_sh = sh.cache_shardings(lazy_abs, mesh, B)

        def serve_step(params, cache, lazy_cache, tokens, index):
            logits, cache, lazy_cache = tf.decode_step_unrolled(
                params, cfg, tokens, index, cache, lazy_cache,
                plan_step=plan_step, window_override=window_override)
            return logits, cache, lazy_cache

        args = {"params": params_abs, "cache": cache_abs,
                "lazy_cache": lazy_abs, "tokens": ins["tokens"],
                "index": ins["index"]}
        in_sh = {"params": p_sh, "cache": c_sh, "lazy_cache": lz_sh,
                 "tokens": sh.batch_sharding(mesh, B, 2),
                 "index": NamedSharding(mesh, P())}
        fn = jax.jit(serve_step,
                     in_shardings=tuple(in_sh[k] for k in args),
                     out_shardings=(None, c_sh, lz_sh))
        return fn, tuple(args[k] for k in args)

    def serve_step(params, cache, tokens, index):
        logits, cache, _, _ = tf.decode_step(
            params, cfg, tokens, index, cache,
            window_override=window_override)
        return logits, cache

    args = {"params": params_abs, "cache": cache_abs, "tokens": ins["tokens"],
            "index": ins["index"]}
    in_sh = {"params": p_sh, "cache": c_sh,
             "tokens": sh.batch_sharding(mesh, B, 2),
             "index": NamedSharding(mesh, P())}
    fn = jax.jit(serve_step,
                 in_shardings=tuple(in_sh[k] for k in args),
                 out_shardings=(None, c_sh))
    return fn, tuple(args[k] for k in args)


# ---------------------------------------------------------------------------
# model-flops (6ND) for the roofline "useful compute" ratio
# ---------------------------------------------------------------------------


def count_params_abs(params_abs) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_abs))


def active_param_fraction(cfg: ModelConfig) -> float:
    """MoE: fraction of expert params active per token (top_k / n_experts),
    non-expert params always active.  Approximated from layer composition."""
    if cfg.moe is None:
        return 1.0
    mo = cfg.moe
    dff = mo.d_ff_expert or cfg.d_ff
    expert_p = 3 * cfg.d_model * dff * mo.n_experts
    shared_p = 3 * cfg.d_model * dff * mo.n_shared_experts
    attn_p = 4 * cfg.d_model * cfg.d_model  # rough
    per_layer = expert_p + shared_p + attn_p
    active = expert_p * (mo.top_k / mo.n_experts) + shared_p + attn_p
    return active / per_layer


def model_flops(cfg: ModelConfig, shape: InputShape, n_params: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = B tokens;
    train counts fwd+bwd (6ND); prefill/decode fwd only (2ND)."""
    frac = active_param_fraction(cfg)
    n_act = n_params * frac
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_act * tokens


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            seq_parallel: bool = True, remat: bool = True,
            tag: str = "", opts: Optional[dict] = None) -> dict:
    opts = opts or {}
    if shape_name.startswith("sample_"):
        return run_sample(arch, shape_name, tag=tag, opts=opts)
    cfg = get_config(arch)
    if opts.get("lazy_plan") is None and not opts.get("policy"):
        # baseline dry-runs measure the un-gated model; lazy variants keep
        # their probes (the paper's added layer must be in the program).
        cfg = cfg.replace(lazy=LazyConfig(enabled=False))
    if opts.get("mlstm_chunk") and cfg.xlstm is not None:
        import dataclasses as _dc
        cfg = cfg.replace(xlstm=_dc.replace(cfg.xlstm,
                                            chunk=opts["mlstm_chunk"]))
    shape = INPUT_SHAPES[shape_name]

    window_override = None
    if shape_name == "long_500k":
        pol = long_context_policy(get_config(arch))
        if not pol["runnable"]:
            return {"arch": arch, "shape": shape_name, "skipped": True,
                    "why": pol["why"]}
        window_override = pol["window_override"]

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    ctx_opts = {k: v for k, v in opts.items()
                if k in ("mlstm_shard", "moe_token_dp", "moe_shard_map")}
    with mesh, ctx.activation_sharding(mesh, **ctx_opts):
        fn, args = build_step(cfg, shape, mesh,
                              window_override=window_override,
                              seq_parallel=seq_parallel, remat=remat,
                              opts=opts)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x wraps it in a list
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    # loop-aware static analysis: cost_analysis() counts while (scan) bodies
    # ONCE — analyze_module scales by trip count (see dist/hlo.py)
    mod = hlo_lib.analyze_module(hlo_text)
    coll = mod["collective"]

    flops = float(mod["flops"])               # per-device (SPMD-partitioned)
    bytes_acc = float(mod["bytes"])
    params_abs = _abstract_params(cfg, window_override)
    n_params = count_params_abs(params_abs)
    mf = model_flops(cfg, shape, n_params)

    tp_model = mesh.shape.get("model", 1)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    coll_s = hlo_lib.collective_seconds(coll, tp_model, ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "window_override": window_override,
        "seq_parallel": seq_parallel, "remat": remat,
        "tag": tag,
        # identity checks, not ==: 0 and 0.0 are legitimate flag values
        # (e.g. --error-threshold 0.0) and must not match False
        "opts": {k: v for k, v in opts.items()
                 if v is not None and v is not False
                 and v not in ("", "fsdp", "hd")
                 and not (k.startswith("policy_") and not opts.get("policy"))
                 and not (k == "stride" and opts.get("policy") != "stride")},
        "n_params": n_params,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc,
                 "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
                 "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_chips,
            "useful_compute_ratio": (mf / n_chips) / flops if flops else None,
        },
    }
    return result


def save(result: dict):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result.get('mesh', 'skip')}"
    # policy runs get their own artifact: a --policy dry-run must never
    # silently overwrite the no-policy baseline for the same cell
    pol = result.get("policy") or (result.get("opts") or {}).get("policy")
    if pol:
        name += f"__pol-{pol}"
    if result.get("tag"):
        name += f"__{result['tag']}"
    path = os.path.join(ARTIFACT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    help="an INPUT_SHAPES name, or sample_<n> (DiT archs: "
                         "fused n-step DDIM trajectory, one compile)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tag", default="")
    # §Perf hillclimb knobs
    ap.add_argument("--param-mode", default="fsdp", choices=["fsdp", "tp_only"])
    ap.add_argument("--shard-cache-heads", action="store_true")
    ap.add_argument("--cache-mode", default=None, choices=["heads", "seq"])
    ap.add_argument("--lazy-plan", type=float, default=None)
    # cache-policy plan rows (repro.cache; supersedes --lazy-plan, which
    # stays as the random-row alias)
    ap.add_argument("--policy", default=None,
                    choices=["none", "stride", "smoothcache",
                             "static_router"],
                    help="plan-compiling cache policy (repro.cache); "
                         "dynamic policies (lazy_gate) have no static row "
                         "to compile")
    ap.add_argument("--policy-ratio", type=float, default=0.5,
                    help="target ratio (static_router) / threshold "
                         "quantile fallback (smoothcache)")
    ap.add_argument("--policy-step", type=int, default=3,
                    help="which schedule row the decode step compiles "
                         "(odd default: even steps are stride refresh "
                         "rows)")
    ap.add_argument("--policy-steps", type=int, default=8,
                    help="schedule horizon the policy compiles")
    ap.add_argument("--calibration", default="",
                    help="calibration artifact JSON for smoothcache / "
                         "static_router")
    ap.add_argument("--error-threshold", type=float, default=None)
    ap.add_argument("--stride", type=int, default=2)
    ap.add_argument("--mesh", default="",
                    help="sample_<n> shapes only: lower the SHARDED fused "
                         "trajectory executor on this mesh (e.g. "
                         "'data=8') and report per-device vs global FLOPs "
                         "+ collective traffic")
    ap.add_argument("--moe-token-dp", action="store_true")
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--mlstm-shard", default="hd", choices=["hd", "none"])
    ap.add_argument("--mlstm-chunk", type=int, default=None)
    args = ap.parse_args()
    opts = {"param_mode": args.param_mode,
            "shard_cache_heads": args.shard_cache_heads,
            "cache_mode": args.cache_mode,
            "lazy_plan": args.lazy_plan,
            "policy": args.policy,
            "policy_ratio": args.policy_ratio,
            "policy_step": args.policy_step,
            "policy_steps": args.policy_steps,
            "calibration": args.calibration,
            "error_threshold": args.error_threshold,
            "stride": args.stride,
            "mesh": args.mesh,
            "moe_token_dp": args.moe_token_dp,
            "moe_shard_map": args.moe_shard_map,
            "mlstm_shard": args.mlstm_shard,
            "mlstm_chunk": args.mlstm_chunk}

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_one(arch, shape, multi_pod=mp,
                                seq_parallel=not args.no_seq_parallel,
                                remat=not args.no_remat, tag=args.tag,
                                opts=opts)
                    p = save(r)
                    if r.get("skipped"):
                        print(f"[SKIP] {label}: {r['why']}")
                    else:
                        rl = r["roofline"]
                        print(f"[OK]   {label}: compile={r['compile_s']}s "
                              f"dominant={rl['dominant']} "
                              f"compute={rl['compute_s']:.4f}s "
                              f"mem={rl['memory_s']:.4f}s "
                              f"coll={rl['collective_s']:.4f}s -> {p}")
                except Exception as e:  # noqa: BLE001 - sweep must continue
                    failures.append((label, str(e)))
                    print(f"[FAIL] {label}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
