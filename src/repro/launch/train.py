"""Training launcher.

On this CPU container it runs reduced configs end-to-end; on a real pod the
same driver shards over the production mesh (the dry-run proves every
(arch × shape × mesh) lowers — repro.launch.dryrun).

DiT training is phased like the paper (DESIGN.md §Train):

  --phase pretrain   standard diffusion pretraining (full params)
  --phase lazy       the paper's lazy recipe: frozen base, probe-only AdamW
                     (train/learned.train_lazy_gates) — checkpointable
                     mid-run (--ckpt + --ckpt-every) and resumable
                     (--resume) with gate params AND optimizer state
  --phase router     learned per-layer router (train/learned.train_router)

``--distill out.json`` distills the trained schedule to a
cache/schedule.ScheduleArtifact the ``learned`` cache policy — and with
it the fused trajectory executor, serving engines and dry-run — consumes
unchanged.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dit_xl2_256 --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dit_xl2_256 \
      --phase lazy --steps 50 --distill artifacts/lazy_gate.json
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_checkpoint
from repro.configs.registry import get_config
from repro.data.synthetic import LatentImageDataset, MarkovTokenDataset
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.sampling import ddim
from repro.train import learned, optim, trainer


def run_lazy_phase(params, cfg, sched, args):
    """The lazy recipe + the train-smoke health gate CI leans on."""
    opt_state, start = None, 0
    if args.resume:
        params, opt_state, start = learned.restore_train_state(
            args.resume, params)
        print(f"resumed {args.resume} at step {start}")
    params, opt, history = learned.train_lazy_gates(
        params, cfg, sched, steps=args.steps, batch=args.batch, lr=args.lr,
        n_sample_steps=args.sample_steps, seed=0, opt_state=opt_state,
        start_step=start, ckpt_path=args.ckpt,
        ckpt_every=args.ckpt_every or (args.steps if args.ckpt else 0),
        log_every=10)
    if not history:
        print(f"recipe already complete at step {start} — nothing to do")
        return params
    # health gate (CI train-smoke): the recipe must end on a finite loss
    # with live gate gradients — a silently-frozen probe (the masking bug
    # this PR fixes) or a NaN'd trunk both fail here, loudly
    last = history[-1]
    assert all(map(lambda v: jnp.isfinite(jnp.asarray(v)), last.values())), \
        f"non-finite training stats: {last}"
    assert last["gnorm"] > 0.0, "gate gradient norm is zero — probes frozen"
    if args.distill:
        art = learned.distill_gate_schedule(
            params, cfg, sched, key=jax.random.PRNGKey(1),
            labels=jnp.arange(min(4, cfg.dit_n_classes)),
            n_steps=args.sample_steps,
            target_ratio=args.target_ratio)
        art.save(args.distill)
        print(f"schedule (ratio {art.lazy_ratio:.3f}) -> {args.distill}")
    return params


def run_router_phase(params, cfg, sched, args):
    theta, history = learned.train_router(
        params, cfg, sched, n_steps=args.sample_steps,
        target_ratio=args.target_ratio or 0.5, steps=args.steps,
        batch=min(args.batch, 2), lr=args.lr, log_every=10)
    last = history[-1]
    assert all(map(lambda v: jnp.isfinite(jnp.asarray(v)), last.values())), \
        f"non-finite router stats: {last}"
    if args.distill:
        art = learned.distill_router_schedule(
            theta, cfg, target_ratio=args.target_ratio or 0.5)
        art.save(args.distill)
        print(f"schedule (ratio {art.lazy_ratio:.3f}) -> {args.distill}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--phase", default="",
                    choices=["", "pretrain", "lazy", "router"],
                    help="DiT training phase (default: pretrain)")
    ap.add_argument("--lazy", action="store_true",
                    help="alias for --phase lazy (legacy flag)")
    ap.add_argument("--sample-steps", type=int, default=10,
                    help="sampling horizon the lazy/router phases train for")
    ap.add_argument("--target-ratio", type=float, default=None,
                    help="skip ratio for --distill (None: threshold rule)")
    ap.add_argument("--distill", default="",
                    help="write the trained ScheduleArtifact JSON here")
    ap.add_argument("--full-scale", action="store_true",
                    help="use the full config (needs a real pod)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the lazy phase every N steps")
    ap.add_argument("--resume", default="",
                    help="resume the lazy phase from this checkpoint")
    args = ap.parse_args()
    phase = args.phase or ("lazy" if args.lazy else "pretrain")

    cfg = get_config(args.arch)
    if not args.full_scale:
        cfg = cfg.reduced() if cfg.family != "dit" else \
            cfg.reduced(dit_input_size=16, dit_n_classes=16)
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    if cfg.family == "dit":
        params = dit_lib.init_dit(key, cfg)
        sched = ddim.linear_schedule(200)
        if phase == "lazy":
            params = run_lazy_phase(params, cfg, sched, args)
        elif phase == "router":
            params = run_router_phase(params, cfg, sched, args)
        else:
            data = LatentImageDataset(cfg, seed=0)
            it = data.batches(args.batch, seed=1)
            opt = optim.adamw_init(params)
            for i in range(args.steps):
                x0, y = next(it)
                key, k = jax.random.split(key)
                params, opt, aux = trainer.diffusion_train_step(
                    params, opt, cfg, sched, jnp.asarray(x0),
                    jnp.asarray(y), k, lr=args.lr)
                if i % 10 == 0 or i == args.steps - 1:
                    print(f"step {i:4d} loss {float(aux['loss']):.4f}")
    else:
        params = tf.init_lm(key, cfg)
        data = MarkovTokenDataset(cfg.vocab_size, seed=0)
        it = data.batches(args.batch, args.seq, seed=1)
        opt = optim.adamw_init(params)
        for i in range(args.steps):
            toks = jnp.asarray(next(it))
            key, k = jax.random.split(key)
            params, opt, aux = trainer.lm_train_step(params, opt, cfg, toks,
                                                     k, lr=args.lr)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(aux['loss']):.4f}")

    print(f"trained {args.steps} steps in {time.time() - t0:.1f}s "
          f"({tf.count_params(params) / 1e6:.1f}M params)")
    if args.ckpt and (cfg.family != "dit" or phase == "pretrain"):
        save_checkpoint(args.ckpt, params)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
