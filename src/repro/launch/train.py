"""Training launcher.

On this CPU container it runs reduced configs end-to-end; on a real pod the
same driver shards over the production mesh (the dry-run proves every
(arch × shape × mesh) lowers — repro.launch.dryrun).

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dit_xl2_256 --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dit_xl2_256 --lazy --steps 50
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_checkpoint
from repro.configs.registry import get_config
from repro.data.synthetic import LatentImageDataset, MarkovTokenDataset
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.sampling import ddim
from repro.train import optim, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lazy", action="store_true",
                    help="lazy-learning phase (DiT archs): frozen base + probes")
    ap.add_argument("--full-scale", action="store_true",
                    help="use the full config (needs a real pod)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_scale:
        cfg = cfg.reduced() if cfg.family != "dit" else \
            cfg.reduced(dit_input_size=16, dit_n_classes=16)
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    if cfg.family == "dit":
        params = dit_lib.init_dit(key, cfg)
        sched = ddim.linear_schedule(200)
        data = LatentImageDataset(cfg, seed=0)
        it = data.batches(args.batch, seed=1)
        opt = optim.adamw_init(params)
        step_fn = trainer.lazy_train_step if args.lazy \
            else trainer.diffusion_train_step
        for i in range(args.steps):
            x0, y = next(it)
            key, k = jax.random.split(key)
            params, opt, aux = step_fn(params, opt, cfg, sched,
                                       jnp.asarray(x0), jnp.asarray(y), k,
                                       lr=args.lr)
            if i % 10 == 0 or i == args.steps - 1:
                extra = (f" s_attn={float(aux.get('s_attn', 0)):.3f}"
                         if args.lazy else "")
                print(f"step {i:4d} loss {float(aux['loss']):.4f}{extra}")
    else:
        params = tf.init_lm(key, cfg)
        data = MarkovTokenDataset(cfg.vocab_size, seed=0)
        it = data.batches(args.batch, args.seq, seed=1)
        opt = optim.adamw_init(params)
        for i in range(args.steps):
            toks = jnp.asarray(next(it))
            key, k = jax.random.split(key)
            params, opt, aux = trainer.lm_train_step(params, opt, cfg, toks,
                                                     k, lr=args.lr)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(aux['loss']):.4f}")

    print(f"trained {args.steps} steps in {time.time() - t0:.1f}s "
          f"({tf.count_params(params) / 1e6:.1f}M params)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
