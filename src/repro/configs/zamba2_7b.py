"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

81L d_model=3584 (Mamba2 blocks, ssm_state=64) with ONE shared-weight
attention block (32H MHA, kv=32) applied every 6 layers; d_ff=14336 inside
the shared block's ffn is folded into the attention block here (we apply
attn-only shared blocks; deviation noted in DESIGN.md); vocab=32000.
[arXiv:2411.15242]
"""
from repro.configs.base import LazyConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    block_pattern=("mamba2",),
    shared_attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    # long_500k: Mamba2 state is O(1)/step, but the shared attention blocks
    # take the documented SWA fallback (DESIGN.md §long_500k policy)
    attn_window_fallback=4096,
    lazy=LazyConfig(enabled=True),
)
