"""gemma2-9b [dense] — alternating local/global attention + logit softcaps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 head_dim=256;
local window 4096 on even layers, global on odd; attn softcap 50, final
logit softcap 30; tied embeddings; GeGLU.
At long_500k the *global* layers also take the 4096 fallback window
(full 500k global attention is not sub-quadratic; DESIGN.md).
[arXiv:2408.00118]
"""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    attn_window_pattern=(4096, 0),    # local, global alternating
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    act="gelu", tie_embeddings=True,
    attn_window_fallback=4096,        # long_500k: cap the global layers
    lazy=LazyConfig(enabled=True),
)
