"""DiT-XL/2 512x512 — same trunk as XL/2-256 on 64x64x4 latents."""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="dit-xl2-512",
    family="dit",
    source="arXiv:2212.09748",
    n_layers=28, d_model=1152, n_heads=16, n_kv_heads=16,
    d_ff=4608, vocab_size=0,
    rope_type="none",
    dit_patch=2, dit_input_size=64, dit_in_channels=4, dit_n_classes=1000,
    lazy=LazyConfig(enabled=True, rho_attn=1e-4, rho_ffn=1e-4),
)
