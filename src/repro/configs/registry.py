"""Config registry: ``get_config(name)`` for every assigned architecture,
the paper's own DiT family, and the input-shape table."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

ASSIGNED_ARCHS = [
    "command_r_plus_104b",
    "llama3_2_1b",
    "qwen2_vl_7b",
    "zamba2_7b",
    "mixtral_8x22b",
    "xlstm_1_3b",
    "musicgen_large",
    "gemma2_9b",
    "deepseek_coder_33b",
    "deepseek_v2_lite_16b",
]

DIT_ARCHS = ["dit_xl2_256", "dit_xl2_512", "large_dit_3b", "large_dit_7b"]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ASSIGNED_ARCHS + DIT_ARCHS}


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def long_context_policy(cfg: ModelConfig) -> dict:
    """How an arch runs the long_500k shape (DESIGN.md §long_500k policy).

    Returns {"runnable": bool, "window_override": Optional[int], "why": str}.
    """
    kinds = set(cfg.layer_kinds())
    attn_free = kinds <= {"mamba2", "mlstm", "slstm"} and not cfg.shared_attn_every
    if attn_free:
        return {"runnable": True, "window_override": None,
                "why": "attention-free: O(1) state per step"}
    windows = cfg.layer_windows()
    if all(w > 0 for w in windows):
        return {"runnable": True, "window_override": None,
                "why": "native sliding-window attention"}
    if cfg.attn_window_fallback:
        return {"runnable": True, "window_override": cfg.attn_window_fallback,
                "why": f"SWA fallback window={cfg.attn_window_fallback} "
                       "(documented beyond-paper variant)"}
    return {"runnable": False, "window_override": None,
            "why": "full attention, no fallback configured"}
