"""qwen2-vl-7b [vlm] — Qwen2-VL language decoder backbone.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064; M-RoPE with
(temporal, height, width) sections (16, 24, 24) over head_dim/2 = 64;
qkv biases.  The ViT vision encoder is a STUB per assignment —
``input_specs()`` supplies precomputed patch embeddings (frontend_dim=1280,
the ViT output width) consumed through a linear projector.
[arXiv:2409.12191]
"""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    rope_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    use_bias=True,
    frontend_stub="vision", frontend_dim=1280,
    attn_window_fallback=4096,        # long_500k only
    lazy=LazyConfig(enabled=True),
)
