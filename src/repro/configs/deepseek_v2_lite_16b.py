"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE.

27L d_model=2048 16H, MLA (kv_lora_rank=512, qk_rope=64, qk_nope=128,
v=128), MoE: 64 routed experts top-6 + 2 shared experts, d_ff_expert=1408,
vocab=102400.  (The assignment line lists both "64e top-6" and "160
routed" — 64 routed matches V2-*Lite* [arXiv:2405.04434 §2]; we use 64.)
All 27 layers are MoE here; the real model's dense first layer is folded
into the shared experts (deviation noted in DESIGN.md §6).
"""
from repro.configs.base import LazyConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    block_pattern=("attn_moe",),
    mla=MLAConfig(kv_lora_rank=512, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2,
                  d_ff_expert=1408, capacity_factor=1.25),
    attn_window_fallback=4096,        # long_500k only
    lazy=LazyConfig(enabled=True),
)
