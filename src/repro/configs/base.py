"""Config system for the repro framework.

Every architecture (the paper's DiT family and the 10 assigned archs) is
described by a single frozen dataclass tree.  Configs are pure data: they never
touch jax device state, so importing a config module is always safe.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts feedforward."""

    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0          # deepseek-v2 style always-on experts
    d_ff_expert: int = 0               # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25      # dispatch capacity per expert
    router_aux_weight: float = 0.01    # load-balance loss weight


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 -> full-rank q projection
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    state_dim: int = 64
    head_dim: int = 64                 # per-SSM-head channel dim
    expand: int = 2                    # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256                   # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block parameters (mLSTM matrix memory / sLSTM scalar memory)."""

    slstm_every: int = 8               # every k-th block is sLSTM (7:1 ratio)
    proj_factor: float = 2.0           # mLSTM up-projection factor
    conv_width: int = 4
    chunk: int = 256                   # mLSTM chunkwise-scan block length


@dataclass(frozen=True)
class LazyConfig:
    """LazyDiT gating configuration (the paper's contribution)."""

    enabled: bool = False
    gate_attn: bool = True
    gate_ffn: bool = True
    # execution mode: 'soft' (training mixture), 'masked' (per-sample select),
    # 'plan' (static trace-time skip; real FLOP removal)
    mode: str = "soft"
    rho_attn: float = 1e-4             # lazy-loss penalty (paper: 1e-7..1e-2)
    rho_ffn: float = 1e-4
    threshold: float = 0.5


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"              # dense|moe|ssm|hybrid|vlm|audio|dit
    source: str = ""                   # citation (hf card / arXiv)

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                  # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # block layout -------------------------------------------------------
    # 'attn_ffn'    : standard pre-norm transformer block
    # 'parallel'    : cohere-style parallel attn+ffn from one norm
    # 'mamba2'      : Mamba2 SSD block
    # 'mlstm'/'slstm': xLSTM blocks
    # The stack is `block_pattern` repeated/cycled to n_layers.
    block_pattern: Tuple[str, ...] = ("attn_ffn",)

    # hybrid (zamba2): a single *shared-weight* attention block applied
    # every `shared_attn_every` layers (0 = disabled).
    shared_attn_every: int = 0

    # attention ------------------------------------------------------------
    rope_theta: float = 10000.0
    rope_type: str = "rope"            # rope|mrope|none
    mrope_sections: Tuple[int, ...] = ()
    # sliding-window pattern, cycled over layers; 0 = global attention.
    attn_window_pattern: Tuple[int, ...] = (0,)
    # fallback window used only for the long_500k shape on full-attn archs
    # (documented beyond-paper variant; see DESIGN.md §long_500k policy).
    attn_window_fallback: int = 0
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qk_norm: bool = False

    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    lazy: LazyConfig = field(default_factory=LazyConfig)

    # modality frontend stub: if set, the model consumes precomputed
    # embeddings of shape (B, S, frontend_dim) instead of token ids for a
    # prefix of the sequence (vlm: vision patches; audio: codec frames).
    frontend_stub: str = ""            # ''|vision|audio
    frontend_dim: int = 0

    # dit-only -------------------------------------------------------------
    dit_patch: int = 2
    dit_input_size: int = 32           # latent spatial size
    dit_in_channels: int = 4
    dit_n_classes: int = 1000

    dtype: str = "bfloat16"

    # ---------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind per layer (pattern cycled to n_layers)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def layer_windows(self) -> Tuple[int, ...]:
        p = self.attn_window_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=256, <=4 experts."""
        kw = dict(
            n_layers=max(2, len(self.block_pattern)) if self.shared_attn_every == 0
            else max(2, self.shared_attn_every),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, min(self.n_heads, 4)),
            head_dim=64 if self.resolved_head_dim >= 64 else self.resolved_head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 256, 256),
            )
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, qk_rope_head_dim=16,
                qk_nope_head_dim=32, v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=32, chunk=32)
        if self.frontend_dim:
            kw["frontend_dim"] = min(self.frontend_dim, 256)
        if self.mrope_sections:
            # rescale sections to the reduced head_dim/2 budget
            hd = kw.get("head_dim") or self.resolved_head_dim
            total = hd // 2
            base = [max(1, s * total // sum(self.mrope_sections))
                    for s in self.mrope_sections]
            base[-1] += total - sum(base)
            kw["mrope_sections"] = tuple(base)
        kw.update(overrides)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train|prefill|decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
