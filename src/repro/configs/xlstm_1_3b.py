"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1), attention-free.

48L d_model=2048 4H d_ff=0 (the xLSTM block contains its own up/down
projection, proj_factor=2) vocab=50304.
[arXiv:2405.04517]
"""
from repro.configs.base import LazyConfig, ModelConfig, XLSTMConfig

_PATTERN = ("mlstm",) * 7 + ("slstm",)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=_PATTERN,
    rope_type="none",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0),
    lazy=LazyConfig(enabled=True, gate_attn=False),  # block-level gates only
)
