"""Large-DiT-3B (Zhang et al. 2023, LLaMA-Adapter repo) 256x256."""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="large-dit-3b",
    family="dit",
    source="arXiv:2303.16199",
    n_layers=32, d_model=2304, n_heads=32, n_kv_heads=32,
    d_ff=9216, vocab_size=0,
    rope_type="none",
    dit_patch=2, dit_input_size=32, dit_in_channels=4, dit_n_classes=1000,
    lazy=LazyConfig(enabled=True, rho_attn=1e-4, rho_ffn=1e-4),
)
