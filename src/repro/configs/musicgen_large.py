"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048 (codec codebook).
The mel/EnCodec conv frontend is a STUB per assignment — ``input_specs()``
supplies precomputed frame embeddings (frontend_dim=128, the EnCodec latent
width).  RoPE replaces MusicGen's sinusoidal positions (TPU-idiomatic;
noted in DESIGN.md §6).
[arXiv:2306.05284]
"""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    use_bias=True,
    frontend_stub="audio", frontend_dim=128,
    attn_window_fallback=4096,        # long_500k only
    lazy=LazyConfig(enabled=True),
)
