"""command-r-plus-104b [dense] — Cohere Command R+.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000; GQA, no biases,
cohere parallel-block layout (attn ∥ ffn off one norm), tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    block_pattern=("parallel",),
    rope_theta=75_000_000.0,
    use_bias=False, tie_embeddings=True,
    attn_window_fallback=4096,        # long_500k only (DESIGN.md)
    lazy=LazyConfig(enabled=True),
)
