"""DiT-XL/2 256x256 (Peebles & Xie 2023) — the paper's primary model.

28L d_model=1152 16H patch=2 over 32x32x4 latents (256px / VAE-8), 1000
ImageNet classes, MLP ratio 4.
"""
from repro.configs.base import LazyConfig, ModelConfig

CONFIG = ModelConfig(
    name="dit-xl2-256",
    family="dit",
    source="arXiv:2212.09748",
    n_layers=28, d_model=1152, n_heads=16, n_kv_heads=16,
    d_ff=4608, vocab_size=0,
    rope_type="none",
    dit_patch=2, dit_input_size=32, dit_in_channels=4, dit_n_classes=1000,
    lazy=LazyConfig(enabled=True, rho_attn=1e-4, rho_ffn=1e-4),
)
