"""mixtral-8x22b [moe] — 8 experts, top-2 routing, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384(per expert) vocab=32768,
SWA window 4096 on all layers.
[arXiv:2401.04088]
"""
from repro.configs.base import LazyConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    block_pattern=("attn_moe",),
    attn_window_pattern=(4096,),      # native SWA -> long_500k runs natively
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
    lazy=LazyConfig(enabled=True),
)
