"""Synthetic data pipelines.

No dataset ships in this container (DESIGN.md §6): the DiT pipeline draws
structured latents from a label-conditioned Gaussian-mixture "latent
ImageNet", giving the denoiser a learnable signal; the LM pipeline draws
k-order Markov token streams so cross-entropy has a non-trivial floor.
Both are shard-aware: ``global_batch`` rows are produced host-side and
device_put with the train-step's input sharding by the launcher.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


class LatentImageDataset:
    """Label-conditioned Gaussian-mixture latents (B, H, W, C)."""

    def __init__(self, cfg: ModelConfig, n_classes: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.n_classes = n_classes or cfg.dit_n_classes
        rng = np.random.default_rng(seed)
        # per-class mean pattern: low-frequency spatial structure
        H, C = cfg.dit_input_size, cfg.dit_in_channels
        freq = rng.normal(size=(self.n_classes, 2, C)) * 2.0
        phase = rng.uniform(0, 2 * np.pi, size=(self.n_classes, C))
        gy, gx = np.meshgrid(np.linspace(0, 1, H), np.linspace(0, 1, H),
                             indexing="ij")
        self.means = np.stack([
            np.sin(2 * np.pi * (freq[k, 0, None, None, :] * gy[..., None]
                                + freq[k, 1, None, None, :] * gx[..., None])
                   + phase[k]) for k in range(self.n_classes)]).astype(np.float32)

    def batches(self, batch: int, seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            y = rng.integers(0, self.n_classes, size=batch)
            x = self.means[y] + rng.normal(size=self.means[y].shape).astype(np.float32) * 0.3
            yield x, y.astype(np.int32)


class MarkovTokenDataset:
    """Order-1 Markov chains with a sparse, peaked transition matrix —
    learnable next-token structure for the LM training examples."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        nxt = rng.integers(0, vocab, size=(vocab, branching))
        probs = rng.dirichlet(np.ones(branching) * 0.5, size=vocab)
        self.next_tokens = nxt
        self.next_probs = probs.astype(np.float64)

    def batches(self, batch: int, seq_len: int, seed: int = 0
                ) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(seed)
        while True:
            out = np.empty((batch, seq_len + 1), np.int32)
            out[:, 0] = rng.integers(0, self.vocab, size=batch)
            for t in range(seq_len):
                cur = out[:, t]
                choice = np.array([rng.choice(self.next_tokens[c],
                                              p=self.next_probs[c])
                                   for c in cur])
                out[:, t + 1] = choice
            yield out


@dataclass
class RequestSpec:
    """One serving request of a synthetic trace (serving/scheduler.py)."""

    rid: int
    arrival: float            # virtual-seconds arrival time (Poisson process)
    prompt: np.ndarray        # (P,) int32 token ids
    max_new: int              # decode-output budget


@dataclass
class SLORequestSpec(RequestSpec):
    """A request that declares its latency/quality budget (serving/admission).

    ``slo_latency_s`` is the end-to-end deadline on the virtual service
    clock; ``max_skip_ratio`` is the quality budget — the largest plan skip
    ratio the requester accepts (the serving-side quality proxy: the
    per-policy drift columns in BENCH_serving.json map ratio to measured
    cached-vs-fresh drift).  ``priority`` orders admission and preemption
    (higher preempts lower).  ``policy_class`` is FILLED IN by the
    admission controller — the per-request policy decision, kept on the
    request so it is observable end-to-end."""

    slo_latency_s: float = float("inf")
    max_skip_ratio: float = 1.0
    priority: int = 0
    slo_class: str = ""       # generator label: latency | quality | batch
    policy_class: str = ""    # assigned at admission (serving/admission.py)


def request_trace(n_requests: int, vocab: int, *, seed: int = 0,
                  mean_interarrival: float = 0.5,
                  short_prompt: Tuple[int, int] = (2, 6),
                  long_prompt: Tuple[int, int] = (8, 16),
                  short_output: Tuple[int, int] = (3, 6),
                  long_output: Tuple[int, int] = (8, 14),
                  long_frac: float = 0.35) -> List[RequestSpec]:
    """Deterministic synthetic request trace: seeded Poisson arrivals with a
    two-component (short/long) prompt/output length mixture.  Shared by the
    serving tests and benchmarks/bench_serving.py so both see the same
    workload for a given seed."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival, n_requests))
    reqs = []
    for i in range(n_requests):
        is_long = rng.random() < long_frac
        plo, phi = long_prompt if is_long else short_prompt
        olo, ohi = long_output if is_long else short_output
        prompt = rng.integers(0, vocab,
                              int(rng.integers(plo, phi + 1))).astype(np.int32)
        reqs.append(RequestSpec(rid=i, arrival=float(arrivals[i]),
                                prompt=prompt,
                                max_new=int(rng.integers(olo, ohi + 1))))
    return reqs


# SLO-class mixture for slo_request_trace: (label, probability, per-class
# knobs).  ``slo_scale`` multiplies the request's own decode budget into a
# deadline (a 12-token answer gets a tighter absolute deadline than a
# 4-token one), so overload degrades the classes differently instead of
# tripping one global cliff.
SLO_CLASS_MIX = (
    # tight deadline, loose quality budget, preempts everything below.
    # The deadline is generous enough for a diligent run on an IDLE pool
    # but not for one behind a queue, so under load admission must
    # actually choose: shift this class onto the high-skip plans (which
    # its loose quality budget allows) or shed it — a diligent
    # fixed-policy server starts missing these deadlines at ~1x load.
    ("latency", 0.45, dict(slo_scale=1.6, slo_floor=8.0,
                           max_skip_ratio=0.9, priority=2)),
    # loose deadline, near-zero quality budget (must run ~diligent)
    ("quality", 0.35, dict(slo_scale=3.0, slo_floor=12.0,
                           max_skip_ratio=0.05, priority=1)),
    # best-effort: loose on both axes, first to be shed or preempted
    ("batch", 0.20, dict(slo_scale=8.0, slo_floor=30.0,
                         max_skip_ratio=0.6, priority=0)),
)


def slo_request_trace(n_requests: int, vocab: int, *, seed: int = 0,
                      mean_interarrival: float = 0.5,
                      class_mix=SLO_CLASS_MIX,
                      **trace_kwargs) -> List[SLORequestSpec]:
    """``request_trace`` with a seeded SLO-class mixture layered on top:
    same arrivals/prompts/outputs for a given seed (the class draw uses an
    independent stream, so changing the mix never reshuffles the
    workload).  Shared by the admission tests and the bench_serving
    overload sweep."""
    base = request_trace(n_requests, vocab, seed=seed,
                         mean_interarrival=mean_interarrival, **trace_kwargs)
    rng = np.random.default_rng(seed + 104729)        # independent stream
    probs = np.array([p for _, p, _ in class_mix], np.float64)
    probs = probs / probs.sum()
    picks = rng.choice(len(class_mix), size=n_requests, p=probs)
    out = []
    for req, k in zip(base, picks):
        label, _, kw = class_mix[int(k)]
        out.append(SLORequestSpec(
            rid=req.rid, arrival=req.arrival, prompt=req.prompt,
            max_new=req.max_new, slo_class=label,
            slo_latency_s=max(kw["slo_floor"],
                              kw["slo_scale"] * req.max_new),
            max_skip_ratio=kw["max_skip_ratio"], priority=kw["priority"]))
    return out


def frontend_stub_embeddings(rng: np.random.Generator, batch: int, n_frames: int,
                             dim: int) -> np.ndarray:
    """Precomputed patch/frame embeddings for the vlm/audio frontend stubs
    (DESIGN.md: the one sanctioned stub)."""
    t = np.linspace(0, 1, n_frames)[None, :, None]
    base = np.sin(2 * np.pi * (rng.uniform(1, 4, (batch, 1, dim)) * t
                               + rng.uniform(0, 1, (batch, 1, dim))))
    return (base + 0.1 * rng.normal(size=(batch, n_frames, dim))).astype(np.float32)
