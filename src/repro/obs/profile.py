"""Realized-performance profiling: steady-state timing, memory
watermarks, AOT compile timing and device-trace capture (repro.obs).

Every wall-clock number this repo reports flows through ONE harness so
the methodology is uniform and auditable (DESIGN.md §Obs §Perf):

  * ``measure`` — steady-state wall time of a callable: warmup until two
    consecutive calls agree (never fewer than the requested warmup
    calls), then a timed sample set reduced to **median + MAD** (median
    absolute deviation).  The median ignores the slow tail entirely and
    the MAD is the dispersion estimate the regression gate scales its
    tolerance by (benchmarks/check_regression.py) — mean/stddev would
    let one GC pause or scheduler preemption poison the statistic.
    Samples beyond an explicit outlier cutoff are dropped and COUNTED
    (``Measurement.rejected``) — never silently.
  * ``aot_compile`` — ``fn.lower(*args).compile()`` with the lower and
    compile phases timed separately, so callers report compile cost
    apart from first execution instead of conflating trace + compile +
    run into one "first call" number (the launch/serve.py bug this
    module fixes).
  * ``memory_watermarks`` — per-device bytes in use.  Accelerator
    backends expose ``device.memory_stats()``; the CPU container returns
    None there, so the fallback sums ``jax.live_arrays()`` shard bytes
    per device (no peak watermark — recorded as None, not 0).
  * ``device_trace`` — a ``jax.profiler`` capture merged onto the
    host/service Chrome tracer (obs/trace.py) as the ``PID_DEVICE``
    track: one timeline for host phases, compile events, service-clock
    serving decisions AND on-device op execution, still
    ``validate_chrome_trace``-clean.

Profiling OFF is the default and costs nothing: ``measure`` only calls
the function it is given (no wrapping, no retracing — the zero-overhead
pins in tests/test_profile.py), and ``device_trace`` failures degrade to
an annotation on the tracer, never a failed run.
"""
from __future__ import annotations

import gzip
import json
import os
import shutil
import tempfile
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Callable, Dict, List, NamedTuple

import jax

from repro.obs import trace as trace_lib

# outlier cutoff for timed samples: median + max(OUTLIER_MADS * 1.4826 *
# MAD, OUTLIER_REL_FLOOR * median).  The 1.4826 factor makes the MAD
# comparable to a Gaussian sigma; the relative floor keeps the cutoff
# meaningful when the MAD degenerates to 0 at perf_counter resolution.
OUTLIER_MADS = 5.0
OUTLIER_REL_FLOOR = 1.0

# warmup-until-stable: consecutive warmup calls within this relative
# band mean the jit caches / allocator have settled
STABLE_REL = 0.25


class Measurement(NamedTuple):
    """Steady-state timing result (all times in µs per call)."""
    median_us: float
    mad_us: float           # raw median absolute deviation (unscaled)
    iters: int              # samples kept after outlier rejection
    n_samples: int          # timed samples taken
    warmup_iters: int       # warmup calls until the stability criterion
    rejected: int           # outlier samples dropped (counted, not hidden)

    @property
    def median_s(self) -> float:
        return self.median_us / 1e6

    @property
    def mad_s(self) -> float:
        return self.mad_us / 1e6


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def measure(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            max_warmup: int = 8, stable_rel: float = STABLE_REL,
            block: bool = True) -> Measurement:
    """Steady-state wall time of ``fn(*args)``: median + MAD over
    ``iters`` samples after warmup-until-stable.

    Warmup runs at least ``warmup`` calls and keeps going (up to
    ``max_warmup``) until two consecutive calls agree within
    ``stable_rel`` — so a cold jit cache or allocator ramp never leaks
    into the samples.  ``warmup=0`` skips warmup entirely (the caller
    already warmed the function, e.g. by timing its first execution).
    Samples past the outlier cutoff (see module docstring) are dropped
    and reported in ``Measurement.rejected``.
    """
    sync = jax.block_until_ready if block else (lambda x: x)

    n_warm = 0
    if warmup > 0:
        prev = None
        for _ in range(max(max_warmup, warmup)):
            t0 = time.perf_counter()
            sync(fn(*args))
            dt = time.perf_counter() - t0
            n_warm += 1
            if (n_warm >= warmup and prev is not None
                    and abs(dt - prev) <= stable_rel * max(prev, 1e-12)):
                break
            prev = dt

    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        sync(fn(*args))
        samples.append(time.perf_counter() - t0)

    med = _median(samples)
    mad = _median([abs(s - med) for s in samples])
    cutoff = med + max(OUTLIER_MADS * 1.4826 * mad,
                       OUTLIER_REL_FLOOR * med)
    kept = [s for s in samples if s <= cutoff]
    med = _median(kept)
    mad = _median([abs(s - med) for s in kept])
    return Measurement(median_us=med * 1e6, mad_us=mad * 1e6,
                       iters=len(kept), n_samples=len(samples),
                       warmup_iters=n_warm,
                       rejected=len(samples) - len(kept))


# ---------------------------------------------------------------------------
# AOT compile timing
# ---------------------------------------------------------------------------


def aot_compile(fn, *args):
    """``fn.lower(*args).compile()`` with lower / compile timed apart.

    Returns ``(compiled, {"lower_s", "compile_s"})``.  The compiled
    executable runs without retracing (``compiled(*args)``), so callers
    can time *first execution* as execution only — compile cost is no
    longer conflated with the first call the way a cold jit call
    conflates trace + compile + run.
    """
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


# ---------------------------------------------------------------------------
# memory watermarks
# ---------------------------------------------------------------------------


def memory_watermarks() -> Dict:
    """Per-device memory in use, with the honest source labelled.

    Accelerator backends report allocator stats via
    ``device.memory_stats()`` (including a peak watermark); the CPU
    backend returns None there, so the fallback sums the shard bytes of
    every live ``jax.Array`` per device.  The fallback has NO peak
    watermark — ``peak_bytes`` is None then, never a fabricated 0.
    """
    devices = jax.devices()
    per_device: Dict[str, Dict] = {}
    source = "device.memory_stats"
    for d in devices:
        try:
            stats = d.memory_stats()
        except Exception:
            stats = None
        if stats:
            in_use = int(stats.get("bytes_in_use", 0))
            per_device[str(d)] = {
                "bytes_in_use": in_use,
                "peak_bytes_in_use": int(
                    stats.get("peak_bytes_in_use", in_use)),
            }
    if len(per_device) != len(devices):
        source = "jax.live_arrays"
        per_device = {}
        for arr in jax.live_arrays():
            try:
                shards = [(str(s.device), int(s.data.nbytes))
                          for s in arr.addressable_shards]
            except Exception:
                shards = [(str(next(iter(arr.devices()))), int(arr.nbytes))]
            for dev, nbytes in shards:
                slot = per_device.setdefault(
                    dev, {"bytes_in_use": 0, "peak_bytes_in_use": None})
                slot["bytes_in_use"] += nbytes
    total = sum(v["bytes_in_use"] for v in per_device.values())
    peaks = [v["peak_bytes_in_use"] for v in per_device.values()]
    peak = (sum(peaks) if peaks and all(p is not None for p in peaks)
            else None)
    return {"source": source, "per_device": per_device,
            "total_bytes": int(total), "peak_bytes": peak}


# ---------------------------------------------------------------------------
# device-trace capture + merge (jax.profiler -> the Chrome tracer)
# ---------------------------------------------------------------------------


def _load_profiler_events(log_dir: str) -> List[Dict]:
    """traceEvents of the newest profiler session under ``log_dir``.

    jax.profiler.trace writes ``plugins/profile/<ts>/<host>.trace.json.gz``
    in Chrome trace-event format (µs timestamps)."""
    paths = sorted(Path(log_dir).glob("plugins/profile/*/*.trace.json.gz"))
    if not paths:
        return []
    with gzip.open(paths[-1], "rt") as f:
        return json.load(f).get("traceEvents", []) or []


def merge_device_trace(tracer: trace_lib.Tracer, log_dir: str, *,
                       offset_us: float = 0.0) -> int:
    """Merge one jax.profiler capture onto the tracer's device track.

    Keeps the complete ("X") spans with well-formed pid/tid/ts/dur —
    profiler output also carries metadata rows without tid/ts and a
    trailing phase-less event, which would break the Chrome schema the
    repo validates — remaps the profiler's (pid, tid) pairs onto small
    sequential tids under ``PID_DEVICE``, and rebases timestamps so the
    capture window starts at ``offset_us`` on the tracer's clock (pass
    ``tracer.now_us()`` from capture start).  Thread names from the
    profiler's metadata are preserved as ``thread_name`` metadata on the
    remapped tids.  Returns the number of spans merged.
    """
    raw = _load_profiler_events(log_dir)
    thread_names: Dict[tuple, str] = {}
    spans = []
    for ev in raw:
        pid, tid, ts = ev.get("pid"), ev.get("tid"), ev.get("ts")
        if ev.get("ph") == "M" and ev.get("name") == "thread_name" \
                and pid is not None and tid is not None:
            thread_names[(pid, tid)] = str(
                (ev.get("args") or {}).get("name", tid))
        if ev.get("ph") != "X" or pid is None or tid is None:
            continue
        dur = ev.get("dur")
        if not isinstance(ts, (int, float)) or \
                not isinstance(dur, (int, float)) or dur < 0:
            continue
        spans.append(ev)
    if not spans:
        return 0

    t_min = min(e["ts"] for e in spans)
    tracks = sorted({(e["pid"], e["tid"]) for e in spans})
    tid_map = {track: i for i, track in enumerate(tracks)}

    # one process_name for the device track (idempotent across captures)
    if not any(e.get("pid") == trace_lib.PID_DEVICE and e.get("ph") == "M"
               and e.get("name") == "process_name" for e in tracer.events):
        tracer.events.append(
            {"ph": "M", "name": "process_name",
             "pid": trace_lib.PID_DEVICE, "tid": 0, "ts": 0.0,
             "args": {"name": trace_lib.DEVICE_PROCESS_NAME}})
    for track, tid in tid_map.items():
        name = thread_names.get(track, f"pid{track[0]}.tid{track[1]}")
        ev = {"ph": "M", "name": "thread_name",
              "pid": trace_lib.PID_DEVICE, "tid": tid, "ts": 0.0,
              "args": {"name": name}}
        if ev not in tracer.events:
            tracer.events.append(ev)

    for e in spans:
        tracer.complete(
            str(e.get("name", "op")),
            max(offset_us + (e["ts"] - t_min), 0.0), float(e["dur"]),
            pid=trace_lib.PID_DEVICE, tid=tid_map[(e["pid"], e["tid"])],
            cat="device", args=dict(e.get("args") or {}))
    return len(spans)


@contextmanager
def device_trace(tracer: trace_lib.Tracer, *, label: str = "device_trace"):
    """Capture a ``jax.profiler`` device trace around a block and merge
    it onto ``tracer``'s ``PID_DEVICE`` track.

    Profiling must never fail the profiled run: if the profiler is
    unavailable or produces nothing, the block still executes and the
    tracer gets an instant event recording what happened
    (``<label>_merged`` with ``n_events``, or ``<label>_failed``).
    """
    tmp = tempfile.mkdtemp(prefix="repro-devtrace-")
    t_start = tracer.now_us()
    session = None
    try:
        session = jax.profiler.trace(tmp)
        session.__enter__()
    except Exception as e:
        session = None
        tracer.instant(f"{label}_failed", cat="profile",
                       args={"error": str(e)})
    try:
        yield tracer
    finally:
        n = 0
        if session is not None:
            try:
                session.__exit__(None, None, None)
                n = merge_device_trace(tracer, tmp, offset_us=t_start)
                tracer.instant(f"{label}_merged", cat="profile",
                               args={"n_events": n})
            except Exception as e:
                tracer.instant(f"{label}_failed", cat="profile",
                               args={"error": str(e)})
        shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# perf trend file
# ---------------------------------------------------------------------------


def append_trend(path: str, row: Dict) -> str:
    """Append one JSON row to a PERF_*.jsonl trend file (one object per
    line, stream-appendable — every bench run adds a row so wall-clock
    history survives artifact overwrites)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    return path


__all__ = ["Measurement", "measure", "aot_compile", "memory_watermarks",
           "merge_device_trace", "device_trace", "append_trend",
           "OUTLIER_MADS", "STABLE_REL"]
