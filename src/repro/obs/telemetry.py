"""On-device laziness telemetry — the counters that ride the fused scan.

The fused trajectory executor (sampling/trajectory.py) compiles the whole
DDIM loop into one ``lax.scan``; nothing about a step is observable from
the host until the trajectory returns.  This module defines the OPTIONAL
telemetry pytree that rides the scan carry when observability is on:

    executed      (T, L, M) f32  fraction of the batch that RAN module m
    skipped       (T, L, M) f32  fraction that served the lazy cache
    gate_scores   (T, L, M) f32  layer-mean probe scores (masked/soft modes)
    drift_cos     (T, L, M) f32  cosine(new cache, previous cache)
    drift_rel_l2  (T, L, M) f32  ||new - old||_F / ||old||_F

with M following the repo-wide plan-column convention (0 = attention,
1 = ffn).  Every step writes its row via ``.at[step].set`` inside the scan
body; the host drains the whole pytree in ONE device->host sync after the
trajectory (``drain``).

Drift semantics: the lazy cache holds each module's previous-step output,
and its next value is the SERVED output (fresh where executed, the cache
itself where skipped — core/lazy.lazy_execute).  Comparing consecutive
cache states therefore measures cached-vs-fresh drift exactly where it is
meaningful: an executed module's entry is "how far the cache had drifted
from the fresh output" (the error skipping WOULD have served — the
statistic SmoothCache thresholds), and a skipped module's entry is 0 / 1
by construction (it served the cache verbatim).  Step 0 primes the cache
and is pinned to rel = 0, cos = 1.

Bit-exactness: telemetry only ADDS reduction consumers of the scan-carry
cache buffers — it never feeds back into the latent math — and both cache
operands pass through an ``optimization_barrier`` before reduction, so XLA
cannot refuse/refuse-to-fuse the main path differently because of the new
consumers.  With telemetry off the carry entry is ``None`` (an empty
pytree): the traced jaxpr, the compiled HLO and the output bits are
identical to a build with no telemetry support at all
(tests/test_obs.py).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lazy as lazy_lib

Array = jax.Array

# plan-column names, index-aligned with the (L, M) telemetry columns
MODULE_KINDS = ("attn", "ffn")

COUNTER_KEYS = ("executed", "skipped", "gate_scores",
                "drift_cos", "drift_rel_l2")


def init_trajectory_telemetry(n_steps: int, n_layers: int,
                              n_modules: int = 2) -> Dict[str, Array]:
    """Zeroed telemetry pytree for an ``n_steps``-step trajectory."""
    def z():
        return jnp.zeros((n_steps, n_layers, n_modules), jnp.float32)
    return {k: z() for k in COUNTER_KEYS}


def trajectory_step_update(tele: Optional[Dict[str, Array]], step: Array, *,
                           first: Array, mode: str, threshold: float,
                           row: Optional[Array],
                           scores: Optional[Dict[str, Array]],
                           old_cache: Optional[dict],
                           new_cache: Optional[dict]) -> Optional[Dict]:
    """Write step ``step``'s telemetry row — a pure traced transform for
    the scan body.  ``row`` is the step's (L, M) bool plan row (plan mode);
    ``scores`` the per-module probe scores (masked/soft); ``old_cache`` /
    ``new_cache`` the lazy cache entering and leaving the step, each
    ``{"attn": (L, B', N, D), "ffn": ...}``.  Returns the advanced pytree,
    or None untouched (telemetry off)."""
    if tele is None:
        return None
    n_layers, n_modules = tele["executed"].shape[1:]
    zeros = jnp.zeros((n_layers, n_modules), jnp.float32)

    gate = zeros
    if scores and mode in ("masked", "soft"):
        # mirror the executor's ACTUAL select: lazy_execute thresholds per
        # sample, so the realized skip fraction is the batch mean of
        # per-sample threshold crossings (same rule as n_skipped)
        per_sample = jnp.stack([scores[k] for k in MODULE_KINDS],
                               axis=-1) > threshold            # (L, B', M)
        skipped = jnp.where(first, 0.0,
                            per_sample.astype(jnp.float32).mean(axis=1))
        gate = jnp.stack([scores[k].mean(-1) for k in MODULE_KINDS], axis=-1)
    elif row is not None:
        skipped = jnp.where(first, 0.0, row.astype(jnp.float32))
    else:
        skipped = zeros

    cos, rel = jnp.ones_like(zeros), zeros
    if old_cache is not None and new_cache is not None:
        # the barrier pins both operands as materialized values: the new
        # reduction consumers cannot change how XLA fuses the producers
        # feeding the main latent path (the bit-exactness contract)
        old_cache, new_cache = jax.lax.optimization_barrier(
            (old_cache, new_cache))
        per_kind = [lazy_lib.module_drift(new_cache[k], old_cache[k])
                    for k in MODULE_KINDS]                     # [(L,B'),...]
        cos = jnp.stack([c.mean(axis=-1) for c, _ in per_kind], axis=-1)
        rel = jnp.stack([r.mean(axis=-1) for _, r in per_kind], axis=-1)
        # step 0 primes a zero-initialized cache: no previous step exists
        cos = jnp.where(first, 1.0, cos)
        rel = jnp.where(first, 0.0, rel)

    return {
        "executed": tele["executed"].at[step].set(1.0 - skipped),
        "skipped": tele["skipped"].at[step].set(skipped),
        "gate_scores": tele["gate_scores"].at[step].set(gate),
        "drift_cos": tele["drift_cos"].at[step].set(cos),
        "drift_rel_l2": tele["drift_rel_l2"].at[step].set(rel),
    }


def drain(tele) -> Dict[str, np.ndarray]:
    """Device -> host in one sync: the single transfer the whole
    trajectory's telemetry costs."""
    if tele is None:
        return {}
    return {k: np.asarray(v) for k, v in jax.device_get(tele).items()}


def summarize(tele_np: Dict[str, np.ndarray]) -> Dict:
    """Host-side reductions of a drained telemetry pytree — the report
    rows launch/obs.py and bench_serving consume."""
    if not tele_np:
        return {}
    skipped = np.asarray(tele_np["skipped"], np.float64)
    gated = np.asarray(tele_np["executed"]) + skipped
    rel = np.asarray(tele_np["drift_rel_l2"], np.float64)
    cos = np.asarray(tele_np["drift_cos"], np.float64)
    return {
        "realized_skip_ratio": float(skipped.sum() / max(gated.sum(), 1e-9)),
        # (T, L): per-(step, layer) skipped module calls, 0..M
        "skip_heatmap": skipped.sum(axis=-1).tolist(),
        "drift_rel_l2_by_step": rel.mean(axis=(1, 2)).tolist(),
        "drift_cos_by_step": cos.mean(axis=(1, 2)).tolist(),
        "drift_rel_l2_mean": float(rel.mean()),
        "drift_cos_mean": float(cos.mean()),
        "gate_score_mean": float(np.asarray(tele_np["gate_scores"]).mean()),
    }


# ---------------------------------------------------------------------------
# Serving-side drift (slot-stacked LM lazy caches)
# ---------------------------------------------------------------------------


def slot_cache_drift(new_cache, old_cache, *, eps: float = 1e-12):
    """(cos, rel_l2) per SLOT across every leaf of a slot-stacked lazy
    cache (serving/slots.SlotPool): each leaf is (n_slots, ...); the
    reduction flattens a slot's entries across all leaves so one scalar
    pair summarizes how far the slot's cached module outputs moved this
    decode step.  Runs in-trace (the engine's jitted ``_step``); callers
    mask fresh / inactive slots host-side."""
    old_cache, new_cache = jax.lax.optimization_barrier(
        (old_cache, new_cache))

    def flat(tree):
        return [leaf.astype(jnp.float32).reshape(leaf.shape[0], -1)
                for leaf in jax.tree.leaves(tree)]

    news, olds = flat(new_cache), flat(old_cache)
    dot = sum(jnp.sum(n * o, axis=-1) for n, o in zip(news, olds))
    nn = sum(jnp.sum(n * n, axis=-1) for n in news)
    oo = sum(jnp.sum(o * o, axis=-1) for o in olds)
    dd = sum(jnp.sum((n - o) ** 2, axis=-1) for n, o in zip(news, olds))
    cos = dot / jnp.maximum(jnp.sqrt(nn * oo), eps)
    rel = jnp.sqrt(dd) / jnp.maximum(jnp.sqrt(oo), eps)
    return cos, rel
