"""Metrics registry + report assembly for ``launch/obs.py``.

A report metric is a named function over the observation context — the
dict ``launch/obs.py`` assembles from one instrumented run:

    ctx["config"]    run parameters (arch, steps, batch, policies)
    ctx["sampling"]  {policy_name: {"telemetry": drained pytree (numpy),
                                    "policy": CachePolicy.describe(),
                                    "realized_skip_ratio": float}}
    ctx["serving"]   ServingMetrics.summary() of the serving leg (optional)
    ctx["tracer"]    the run's obs.trace.Tracer (optional)

Registering a metric (``@register_metric``) is all it takes to grow the
report; ``build_report`` runs every registered metric and collects the
non-None results under ``report["metrics"]`` with the schema tag
``repro.obs.report/v1``.  Metrics must be pure reads of the context —
the registry is how the serving and sampling legs share one reporting
surface without importing each other.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.obs import telemetry as telemetry_lib

SCHEMA = "repro.obs.report/v1"

_METRICS: Dict[str, Callable[[Dict], Optional[Dict]]] = {}


def register_metric(name: str):
    def deco(fn):
        _METRICS[name] = fn
        return fn
    return deco


def available_metrics() -> Tuple[str, ...]:
    return tuple(sorted(_METRICS))


def build_report(ctx: Dict) -> Dict:
    report = {"schema": SCHEMA, "config": dict(ctx.get("config", {})),
              "metrics": {}}
    for name in sorted(_METRICS):
        value = _METRICS[name](ctx)
        if value is not None:
            report["metrics"][name] = value
    return report


def _sampling(ctx) -> Dict[str, Dict]:
    return ctx.get("sampling") or {}


@register_metric("skip_heatmap")
def _skip_heatmap(ctx) -> Optional[Dict]:
    """Per-policy (step, layer) skipped-module-call heatmap + realized
    ratio — the report's picture of WHERE each policy spends laziness."""
    out = {}
    for name, leg in _sampling(ctx).items():
        summ = telemetry_lib.summarize(leg["telemetry"])
        if not summ:
            continue
        out[name] = {"heatmap": summ["skip_heatmap"],
                     "realized_skip_ratio": summ["realized_skip_ratio"]}
    return out or None


@register_metric("drift_by_step")
def _drift_by_step(ctx) -> Optional[Dict]:
    """Per-policy cached-vs-fresh drift curves over sampling steps — the
    per-(step) mean of the (L, M) drift counters, both as relative L2 and
    cosine similarity (paper Eq. 3)."""
    out = {}
    for name, leg in _sampling(ctx).items():
        summ = telemetry_lib.summarize(leg["telemetry"])
        if not summ:
            continue
        out[name] = {"rel_l2": summ["drift_rel_l2_by_step"],
                     "cosine": summ["drift_cos_by_step"],
                     "rel_l2_mean": summ["drift_rel_l2_mean"],
                     "cosine_mean": summ["drift_cos_mean"]}
    return out or None


@register_metric("gate_scores")
def _gate_scores(ctx) -> Optional[Dict]:
    """Mean probe score per policy (nonzero only for masked/soft policies
    — the paper's learned gates)."""
    out = {}
    for name, leg in _sampling(ctx).items():
        tele = leg["telemetry"]
        if not tele:
            continue
        out[name] = float(np.asarray(tele["gate_scores"]).mean())
    return out or None


@register_metric("policies")
def _policies(ctx) -> Optional[Dict]:
    return {name: leg["policy"]
            for name, leg in _sampling(ctx).items()} or None


@register_metric("compile_timeline")
def _compile_timeline(ctx) -> Optional[list]:
    """jax.monitoring compile / trace-cache events captured during the
    run, as (name, ts_us, dur_us) rows — a silently recompiling fused
    sampler shows up here as extra backend_compile spans."""
    tracer = ctx.get("tracer")
    if tracer is None:
        return None
    return [{"name": e["name"], "ts_us": e["ts"], "dur_us": e["dur"]}
            for e in tracer.compile_events()] or None


@register_metric("service_percentiles")
def _service_percentiles(ctx) -> Optional[Dict]:
    """The serving leg's service-clock summary (requests/s, latency and
    TTFT percentiles with queue/prefill/decode phase breakdowns,
    goodput-under-SLO, per-policy drift means)."""
    return ctx.get("serving") or None


@register_metric("perf")
def _perf(ctx) -> Optional[Dict]:
    """The realized-vs-modeled performance join (launch/obs.py --perf):
    per-policy steady-state wall medians + MAD, AOT lower/compile times,
    first-execute latency, device memory watermarks, and the dist/hlo
    modeled FLOPs/bytes the measured numbers are divided by — achieved
    roofline fractions and measured-vs-modeled speedups per policy."""
    return ctx.get("perf") or None
