"""repro.obs — zero-dependency observability for the lazy stack.

Three layers (DESIGN.md §Obs):

  * ``obs.telemetry`` — on-device counters riding the fused scan carry:
    per-(step, layer, module) executed/skipped fractions, gate-score
    summaries and cached-vs-fresh drift (cosine / relative L2 against the
    lazy cache), drained in one device->host sync.  Off by default; off
    means bit-identical HLO.
  * ``obs.trace`` — structured tracer: spans/events as JSONL + Chrome
    trace-event JSON (Perfetto-viewable), jax.monitoring compile events,
    serving decisions on the virtual service clock.
  * ``obs.profile`` — realized-performance measurement: the steady-state
    median+MAD timing harness every benchmark/launcher timing loop uses,
    AOT lower/compile timing, device memory watermarks, and jax.profiler
    device-trace capture merged onto the tracer's device track.
  * ``obs.report`` — metrics registry + report assembly; the CLI lives in
    ``repro.launch.obs`` and writes ``artifacts/OBS_*.json``.
"""
from repro.obs.profile import (Measurement, aot_compile,  # noqa: F401
                               device_trace, measure, memory_watermarks)
from repro.obs.report import (available_metrics, build_report,  # noqa: F401
                              register_metric)
from repro.obs.telemetry import (drain, init_trajectory_telemetry,  # noqa: F401
                                 slot_cache_drift, summarize,
                                 trajectory_step_update)
from repro.obs.trace import Tracer, validate_chrome_trace  # noqa: F401
