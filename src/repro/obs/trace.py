"""Structured tracing: spans/events as JSONL + Chrome trace-event JSON.

Zero-dependency tracer for the whole stack — host-side phases (policy
builds, calibration probes, sampling calls), ``jax.monitoring`` compile /
trace-cache events, and serving-engine decisions on the VIRTUAL service
clock (serving/metrics.py).  Events accumulate in memory and export two
ways:

  * ``to_jsonl(path)``  — one event object per line (stream-appendable,
    grep-able);
  * ``to_chrome(path)`` — the Chrome trace-event JSON array format
    (``{"traceEvents": [...]}``), loadable in Perfetto / chrome://tracing.

Event model (the Chrome trace-event phases actually used):

  ph "X"  complete span   (ts + dur, both µs)
  ph "i"  instant event   (admission decisions, completions, ...)
  ph "C"  counter sample  (queue depth, active slots, ...)
  ph "M"  metadata        (process names for the fixed pids below)

Processes separate the clocks so Perfetto lays them out as tracks:
pid HOST (wall clock, µs since the tracer started), pid JAX (compile /
trace-cache events, wall clock), pid SERVICE (the virtual service clock,
1 virtual second = 1e6 "µs"), and — when ``obs/profile.device_trace``
merged a ``jax.profiler`` capture — pid DEVICE (on-device op spans,
rebased onto the host clock).  Exports sort events by (pid, tid, ts), so
timestamps are monotonically non-decreasing per track no matter the
append order — ``validate_chrome_trace`` checks exactly the invariants
the tests pin (required fields, known phases, per-track monotonic ts,
non-negative durations).
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional

# fixed process ids (Chrome trace pids are numeric; "M" metadata events
# name them for the viewer)
PID_HOST = 1
PID_JAX = 2
PID_SERVICE = 3
# device-side ops from a jax.profiler capture (obs/profile.py merges
# them in); its process_name metadata is emitted at merge time, so
# traces without a device capture carry exactly the three tracks above
PID_DEVICE = 4

_PROCESS_NAMES = {PID_HOST: "repro.host", PID_JAX: "repro.jax",
                  PID_SERVICE: "repro.service-clock"}
DEVICE_PROCESS_NAME = "repro.device (jax.profiler)"

KNOWN_PHASES = ("X", "i", "C", "M")

# the jax.monitoring event the compile-count probes already key on
# (benchmarks/bench_trajectory.compile_counter)
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
COMPILE_EVENT_PREFIXES = ("/jax/core/compile", "/jax/core/tracing")


class Tracer:
    """Append-only event collector with Chrome-trace + JSONL export."""

    def __init__(self):
        self.events: List[Dict] = []
        self._t0 = time.perf_counter()
        for pid, name in _PROCESS_NAMES.items():
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0, "ts": 0.0,
                                "args": {"name": name}})

    # ------------------------------------------------------------ clocks
    def now_us(self) -> float:
        """Wall-clock µs since the tracer started (pids HOST / JAX)."""
        return (time.perf_counter() - self._t0) * 1e6

    @staticmethod
    def service_us(now_s: float) -> float:
        """Virtual service clock -> trace µs (1 virtual second = 1e6)."""
        return float(now_s) * 1e6

    # ------------------------------------------------------------ emit
    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 pid: int = PID_HOST, tid: int = 0, cat: str = "host",
                 args: Optional[Dict] = None) -> None:
        self.events.append({"ph": "X", "name": name, "cat": cat,
                            "pid": pid, "tid": tid,
                            "ts": float(ts_us), "dur": max(float(dur_us), 0.0),
                            "args": dict(args or {})})

    def instant(self, name: str, *, ts_us: Optional[float] = None,
                pid: int = PID_HOST, tid: int = 0, cat: str = "host",
                args: Optional[Dict] = None) -> None:
        self.events.append({"ph": "i", "name": name, "cat": cat,
                            "pid": pid, "tid": tid, "s": "t",
                            "ts": float(self.now_us() if ts_us is None
                                        else ts_us),
                            "args": dict(args or {})})

    def counter(self, name: str, values: Dict[str, float], *,
                ts_us: Optional[float] = None, pid: int = PID_HOST,
                cat: str = "host") -> None:
        self.events.append({"ph": "C", "name": name, "cat": cat,
                            "pid": pid, "tid": 0,
                            "ts": float(self.now_us() if ts_us is None
                                        else ts_us),
                            "args": {k: float(v) for k, v in values.items()}})

    @contextmanager
    def span(self, name: str, *, cat: str = "host", tid: int = 0,
             args: Optional[Dict] = None):
        """Wall-clock complete span around a host-side block."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, self.now_us() - t0, pid=PID_HOST,
                          tid=tid, cat=cat, args=args)

    # ------------------------------------------------------------ jax events
    @contextmanager
    def capture_compile_events(self):
        """Record ``jax.monitoring`` duration events (XLA backend compiles,
        trace-cache misses) as spans on the JAX track.  The listener fires
        when an event ENDS, so the span is back-dated by its duration;
        export-time sorting restores per-track ts order."""
        from jax import monitoring as _pub
        from jax._src import monitoring as _mon

        def _listener(event, duration, **kw):
            if not event.startswith(COMPILE_EVENT_PREFIXES):
                return
            dur_us = float(duration) * 1e6
            self.complete(event, self.now_us() - dur_us, dur_us,
                          pid=PID_JAX, cat="compile",
                          args={k: str(v) for k, v in kw.items()})

        _pub.register_event_duration_secs_listener(_listener)
        try:
            yield self
        finally:
            _mon._unregister_event_duration_listener_by_callback(_listener)

    def compile_events(self) -> List[Dict]:
        return [e for e in self.events if e.get("cat") == "compile"]

    # ------------------------------------------------------------ export
    def sorted_events(self) -> List[Dict]:
        return sorted(self.events,
                      key=lambda e: (e["pid"], e.get("tid", 0), e["ts"]))

    def to_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.sorted_events(),
                       "displayTimeUnit": "ms"}, f)
        return path

    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.sorted_events():
                f.write(json.dumps(ev) + "\n")
        return path


def validate_chrome_trace(events: Iterable[Dict]) -> None:
    """Raise ValueError unless ``events`` is schema-valid Chrome trace
    data: required fields present, phases known, timestamps non-negative
    and monotonically non-decreasing per (pid, tid) track, durations
    non-negative.  Used by the tests AND by launch/obs.py before it
    writes the trace artifact — an invalid trace fails the run, not the
    viewer."""
    last_ts: Dict[tuple, float] = {}
    for i, ev in enumerate(events):
        for field in ("ph", "name", "pid", "tid", "ts"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if ev["ph"] not in KNOWN_PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} has invalid ts {ts!r}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} (X) has invalid dur {dur!r}")
        track = (ev["pid"], ev["tid"])
        if ts < last_ts.get(track, 0.0):
            raise ValueError(
                f"event {i} ({ev['name']!r}) goes backwards on track "
                f"{track}: ts {ts} < {last_ts[track]}")
        last_ts[track] = ts
