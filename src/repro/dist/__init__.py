"""Distribution layer.

Three orthogonal pieces (DESIGN.md §Dist):
  * ``ctx``      — thread-local activation-sharding context; layers call
                   ``ctx.constrain`` unconditionally and it is a no-op
                   outside an ``activation_sharding`` block.  Its
                   mesh-scoped entry point is re-exported here:
                   ``with dist.mesh(data=8): ...`` turns on data-parallel
                   execution for everything downstream (fused DDIM
                   trajectory executor, serving slot pools).
  * ``sharding`` — path-rule parameter / cache / batch PartitionSpecs.
  * ``hlo``      — loop-aware static analysis of compiled HLO text
                   (FLOPs, bytes, collective traffic, SPMD partitions)
                   for the roofline.
"""
from repro.dist import ctx, hlo, sharding  # noqa: F401
from repro.dist.ctx import (current_mesh, mesh,  # noqa: F401
                            mesh_cache_key, parse_mesh_spec)
