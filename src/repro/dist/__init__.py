"""Distribution layer.

Three orthogonal pieces (DESIGN.md §Dist):
  * ``ctx``      — thread-local activation-sharding context; layers call
                   ``ctx.constrain`` unconditionally and it is a no-op
                   outside an ``activation_sharding`` block.
  * ``sharding`` — path-rule parameter / cache / batch PartitionSpecs.
  * ``hlo``      — loop-aware static analysis of compiled HLO text
                   (FLOPs, bytes, collective traffic) for the roofline.
"""
from repro.dist import ctx, hlo, sharding  # noqa: F401
