"""Path-rule parameter / cache / batch shardings (DESIGN.md §Dist).

One rule table covers every assigned architecture because the layer library
(models/layers.py) uses a consistent naming convention:

  column-parallel (in-dim FSDP over data axes, out-dim over ``model``):
      wq wk wv  w_gate w_up  w_in w_x w_i w_f  w_dkv w_uk w_uv
      router lm_head frontend_proj
  row-parallel (in-dim over ``model``, out-dim FSDP over data axes):
      wo w_down w_out
  embed: vocab over ``model`` (logit all-gather at the head), d over data.

Everything else — norms, biases, conv filters, gate probes, recurrence
matrices — is replicated: each is O(d) or O(hd^2) and sharding them buys
nothing but collectives.  Stacked leaves (vmapped experts / scanned layer
periods) get ``None`` on every leading dim and the 2-D rule on the last
two.  A dim that does not divide its assigned axes falls back to None.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec
from jax.tree_util import DictKey, FlattenedIndexKey, GetAttrKey, SequenceKey

from repro.dist.ctx import MODEL_AXIS, data_axes

# rule tables keyed on the LAST path component
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_x", "w_i", "w_f",
    "w_dkv", "w_uk", "w_uv", "router", "lm_head", "frontend_proj",
})
_ROW_PARALLEL = frozenset({"wo", "w_down", "w_out"})
_EMBED = frozenset({"embed"})

# cache leaves with a (batch, seq, heads, head_dim)-like layout
_KV_LEAVES = frozenset({"k", "v", "k_rope", "c_kv"})


def _axes_size(mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[n] for n in axes) if axes else 1


def _data_entry(mesh, dim: int, use_data: bool):
    da = data_axes(mesh)
    if not use_data or not da or dim % _axes_size(mesh, da) != 0:
        return None
    return da


def _model_entry(mesh, dim: int):
    if MODEL_AXIS not in mesh.axis_names:
        return None
    if dim % mesh.shape[MODEL_AXIS] != 0:
        return None
    return MODEL_AXIS


def param_spec(path: str, shape: Tuple[int, ...], mesh, *,
               mode: str = "fsdp") -> PartitionSpec:
    """PartitionSpec for one parameter leaf.

    ``path``: '/'-joined tree path (e.g. "period/0/moe/experts/w_gate").
    ``mode``: 'fsdp' shards the non-TP dim over the data axes;
    'tp_only' keeps params replicated across data (weight-stationary TP).
    """
    name = path.rsplit("/", 1)[-1]
    ndim = len(shape)
    known = name in _COL_PARALLEL or name in _ROW_PARALLEL or name in _EMBED
    if ndim < 2 or not known:
        return PartitionSpec(*([None] * ndim))
    use_data = mode == "fsdp"
    d_in, d_out = shape[-2], shape[-1]
    if name in _ROW_PARALLEL or name in _EMBED:
        # embed shares the row-parallel layout: vocab over TP (logit
        # all-gather at the head), d over data
        tail = (_model_entry(mesh, d_in), _data_entry(mesh, d_out, use_data))
    else:  # column-parallel
        tail = (_data_entry(mesh, d_in, use_data), _model_entry(mesh, d_out))
    return PartitionSpec(*([None] * (ndim - 2)), *tail)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, FlattenedIndexKey):
            parts.append(str(k.key))
        else:  # pragma: no cover - future key kinds
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params, mesh, *, mode: str = "fsdp"):
    """Pytree of NamedShardings matching ``params`` (abstract or concrete)."""
    def one(path, leaf):
        spec = param_spec(_path_str(path), tuple(leaf.shape), mesh, mode=mode)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def batch_sharding(mesh, batch: int, ndim: int) -> NamedSharding:
    """Global-batch inputs: leading dim over the data axes, rest replicated."""
    first = _data_entry(mesh, batch, True)
    return NamedSharding(mesh, PartitionSpec(first, *([None] * (ndim - 1))))


def replicated(mesh) -> NamedSharding:
    """Fully-replicated placement on ``mesh``."""
    return NamedSharding(mesh, PartitionSpec())


def trajectory_shardings(mesh, batch: int, *, latent_ndim: int = 4,
                         per_example_keys: bool = False):
    """(in_shardings, out_shardings) for the fused DDIM trajectory executor
    (sampling/trajectory.py build_sampler).

    Argument order is the sampler's:
    ``(params, sched, ts, ts_prev, z0, keys, labels, plan, state0)`` ->
    ``(z, aux)``.  Latents and labels shard their batch dim over the data
    axes (falling back to replicated when the batch does not divide them —
    the repo-wide rule of least surprise); the (T, L, 2) plan array,
    schedule tables, timesteps and the policy's traced state are
    replicated, so every policy's schedule is visible whole on every
    shard and plan rows stay batch-invariant.  ``per_example_keys`` marks
    the eta > 0 carry layout, where ``keys`` is a (B, 2) per-example key
    array sharded like the batch (eta = 0 passes one replicated key)."""
    rep = replicated(mesh)
    z_sh = batch_sharding(mesh, batch, latent_ndim)
    key_sh = batch_sharding(mesh, batch, 2) if per_example_keys else rep
    in_shardings = (rep, rep, rep, rep, z_sh, key_sh,
                    batch_sharding(mesh, batch, 1), rep, rep)
    out_shardings = (z_sh, rep)
    return in_shardings, out_shardings


def slot_stack_shardings(tree, mesh, n_slots: int):
    """NamedShardings for a slot-stacked serving tree (serving/slots.py):
    every leaf's leading slot axis over the data axes (replicated when
    n_slots does not divide them), everything else replicated — one decode
    lane per data shard, the serving analogue of batch sharding."""
    first = _data_entry(mesh, n_slots, True)

    def one(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim < 1 or first is None:
            return replicated(mesh)
        return NamedSharding(mesh,
                             PartitionSpec(first, *([None] * (ndim - 1))))

    return jax.tree.map(one, tree)


def seq_parallel_spec(mesh) -> PartitionSpec:
    """Megatron-style sequence parallelism for (B, S, D) layer-boundary
    activations: B over data, S over ``model`` — remat storage is 1/TP of
    the replicated layout and GSPMD inserts the gather/scatter pair at each
    block's TP region."""
    da = data_axes(mesh) or None
    mdl = MODEL_AXIS if MODEL_AXIS in mesh.axis_names else None
    return PartitionSpec(da, mdl, None)


def cache_shardings(cache, mesh, batch: int, *,
                    mode: Optional[str] = None,
                    shard_heads: bool = False):
    """NamedShardings for a decode / lazy cache pytree.

    The batch dim — position 0, or 1 under the ``period`` subtree whose
    stacked leaves carry a leading n_repeats dim — is sharded over the
    data axes when it matches the global batch (position-based, so an
    n_repeats that happens to equal the batch is never mistaken for it).
    KV-like leaves can additionally shard heads (``shard_heads`` /
    ``mode='heads'``) or the window dim (``mode='seq'``) over ``model``.
    ``pos`` index vectors and scalar stats stay replicated.
    """
    heads = shard_heads or mode == "heads"

    def one(path, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        parts = _path_str(path).split("/")
        name = parts[-1]
        start = 1 if parts[0] == "period" else 0
        bi = start if (name != "pos" and len(shape) > start
                       and shape[start] == batch) else None
        if bi is not None:
            spec[bi] = _data_entry(mesh, shape[bi], True)
        if bi is not None and name in _KV_LEAVES:
            if heads and len(shape) > bi + 2:
                spec[bi + 2] = _model_entry(mesh, shape[bi + 2])
            elif mode == "seq" and len(shape) > bi + 1:
                spec[bi + 1] = _model_entry(mesh, shape[bi + 1])
        return NamedSharding(mesh, PartitionSpec(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)
