"""Loop-aware static analysis of compiled HLO text.

``Compiled.cost_analysis()`` counts each ``while`` (lax.scan) body ONCE, so
a 32-layer model scanned over its period reports ~1/32 of the executed
FLOPs — useless for a roofline.  ``analyze_module`` re-walks the HLO text
with the call graph intact and multiplies loop bodies by their trip count
(XLA records it in ``backend_config={"known_trip_count":{"n":N}}``; the
fallback reads the loop-condition's ``compare(counter, constant)``).

Cost model (intentionally simple, documented in DESIGN.md §Roofline):
  * dot          : 2 · |out| · prod(contracting dims)
  * convolution  : 2 · |out| · |kernel| / out_features  (approximate)
  * elementwise  : |out| (one flop per element, transcendentals included)
  * reduce       : |input|
  * fusion       : flops of the fused computation; BYTES of the fusion
                   instruction's own operands/output only (internals never
                   touch HBM — that is what fusion means)
  * while        : (body + cond) · trip_count
  * collectives  : tallied separately per op with payload bytes;
                   ``collective_seconds`` turns them into an ICI time term.

Pure text processing — no jax import, usable on saved HLO dumps.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# ops whose operands/output are not real memory traffic
_FREE_BYTES = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
})

# pointwise ops: one flop per output element
_ELEMENTWISE = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "abs", "negate", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "and", "or", "xor", "not", "select", "compare",
    "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "tanh", "sqrt", "rsqrt", "cbrt", "power", "sine",
    "cosine", "tan", "atan2", "logistic", "erf", "is-finite", "popcnt",
    "count-leading-zeros", "stochastic-convert",
})

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*?(\d+)")


class _Instr:
    __slots__ = ("name", "op", "shapes", "operands", "attrs", "const_int")

    def __init__(self, name, op, shapes, operands, attrs, const_int):
        self.name = name
        self.op = op
        self.shapes = shapes          # [(dtype, (dims...)), ...]
        self.operands = operands      # operand instruction names
        self.attrs = attrs
        self.const_int = const_int


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _elems(shapes) -> int:
    return sum(math.prod(s) for _, s in shapes)


def _nbytes(shapes) -> int:
    return sum(_DTYPE_BYTES.get(dt, 4) * math.prod(s) for dt, s in shapes)


def _split_instruction(line: str) -> Optional[Tuple[str, str, str, str, str]]:
    """-> (name, type_str, op, operand_str, attrs) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # type: balanced parens for tuple types, else up to the space before op
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    rest = rest.lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    op = om.group(1)
    depth, j = 0, om.end() - 1
    for j in range(om.end() - 1, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    operand_str = rest[om.end():j]
    attrs = rest[j + 1:]
    return name, type_str, op, operand_str, attrs


def _parse_computations(text: str) -> Tuple[Dict[str, List[_Instr]], str]:
    """-> ({computation_name: [instructions]}, entry_name)."""
    comps: Dict[str, List[_Instr]] = {}
    entry = ""
    cur: Optional[List[_Instr]] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            h = _HEADER_RE.match(line)
            if h and "=" not in line.split("(")[0]:
                cur = comps.setdefault(h.group(2), [])
                if h.group(1):
                    entry = h.group(2)
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        parsed = _split_instruction(line)
        if parsed is None:
            continue
        name, type_str, op, operand_str, attrs = parsed
        shapes = _parse_shapes(type_str)
        operands = _OPERAND_NAME_RE.findall(operand_str)
        const_int = None
        if op == "constant":
            cm = re.fullmatch(r"-?\d+", operand_str.strip())
            if cm:
                const_int = int(cm.group(0))
        cur.append(_Instr(name, op, shapes, operands, attrs, const_int))
    if not entry and comps:
        entry = next(iter(comps))
    return comps, entry


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _branch_computations(attrs: str) -> List[str]:
    m = re.search(r"branch_computations=\{([^}]*)\}", attrs)
    if not m:
        return []
    return [_OPERAND_NAME_RE.match(p.strip()).group(1)
            for p in m.group(1).split(",") if p.strip()]


def _trip_count(instr: _Instr, comps) -> int:
    m = _TRIP_RE.search(instr.attrs)
    if m:
        return max(1, int(m.group(1)))
    cond_name = _called(instr.attrs, "condition")
    cond = comps.get(cond_name, [])
    consts = {i.name: i.const_int for i in cond if i.const_int is not None}
    for i in cond:
        if i.op != "compare":
            continue
        dm = re.search(r"direction=(\w+)", i.attrs)
        direction = dm.group(1) if dm else "LT"
        for opnd in i.operands:
            if consts.get(opnd) is not None:
                n = consts[opnd]
                return max(1, n + 1 if direction == "LE" else n)
    return 1


def _base_collective(op: str) -> Optional[str]:
    for base in _COLLECTIVE_OPS:
        if op == base or op == base + "-start":
            return base
    return None


def _collective_payload(op: str, shapes) -> int:
    """Payload bytes of a collective.  An async ``-start`` op's shape is the
    (operands..., result) tuple — count the result only, so async and sync
    forms of the same program tally identically.  Sync variadic collectives
    tuple their RESULTS, so there the full sum is correct."""
    if op.endswith("-start") and len(shapes) > 1:
        return _nbytes(shapes[-1:])
    return _nbytes(shapes)


def _instr_flops(instr: _Instr, name_shapes) -> float:
    op = instr.op
    out = _elems(instr.shapes)
    if op == "dot":
        lhs = name_shapes.get(instr.operands[0]) if instr.operands else None
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
        contr = 1
        if lhs and cm and cm.group(1):
            dims = lhs[0][1]
            for d in cm.group(1).split(","):
                if int(d) < len(dims):
                    contr *= dims[int(d)]
        # a while-loop dot result is tupled with the counter: only the array
        # output participates, which _elems already sums correctly
        return 2.0 * out * contr
    if op == "convolution":
        rhs = name_shapes.get(instr.operands[1]) if len(instr.operands) > 1 else None
        if rhs:
            kdims = rhs[0][1]
            ofeat = kdims[-1] if kdims else 1
            return 2.0 * out * (math.prod(kdims) / max(ofeat, 1))
        return 2.0 * out
    if op in _ELEMENTWISE:
        return float(out)
    if op in ("reduce", "reduce-window"):
        in_shapes = name_shapes.get(instr.operands[0]) if instr.operands else None
        return float(_elems(in_shapes)) if in_shapes else float(out)
    return 0.0


def _instr_bytes(instr: _Instr, name_shapes) -> float:
    if instr.op in _FREE_BYTES:
        return 0.0
    total = _nbytes(instr.shapes)
    for opnd in instr.operands:
        sh = name_shapes.get(opnd)
        if sh:
            total += _nbytes(sh)
    return float(total)


def _merge_coll(dst: Dict, src: Dict, scale: int = 1) -> None:
    for k, v in src.items():
        d = dst.setdefault(k, {"bytes": 0, "count": 0})
        d["bytes"] += v["bytes"] * scale
        d["count"] += v["count"] * scale


def _comp_totals(name: str, comps, memo) -> Dict:
    if name in memo:
        return memo[name]
    memo[name] = {"flops": 0.0, "bytes": 0.0, "collective": {}}  # cycle guard
    instrs = comps.get(name, [])
    name_shapes = {i.name: i.shapes for i in instrs}
    flops = 0.0
    nbytes = 0.0
    coll: Dict[str, Dict[str, int]] = {}
    for instr in instrs:
        op = instr.op
        base = _base_collective(op)
        if base is not None:
            d = coll.setdefault(base, {"bytes": 0, "count": 0})
            d["bytes"] += _collective_payload(op, instr.shapes)
            d["count"] += 1
            continue
        if op.endswith("-done") or op == "copy-start":
            continue
        if op == "while":
            trip = _trip_count(instr, comps)
            for key in ("body", "condition"):
                sub_name = _called(instr.attrs, key)
                if sub_name:
                    sub = _comp_totals(sub_name, comps, memo)
                    flops += sub["flops"] * trip
                    nbytes += sub["bytes"] * trip
                    _merge_coll(coll, sub["collective"], trip)
            continue
        if op == "fusion":
            sub_name = _called(instr.attrs, "calls")
            if sub_name:
                sub = _comp_totals(sub_name, comps, memo)
                flops += sub["flops"]
                _merge_coll(coll, sub["collective"])
            nbytes += _instr_bytes(instr, name_shapes)
            continue
        if op in ("call", "async-start", "custom-call"):
            sub_name = (_called(instr.attrs, "calls")
                        or _called(instr.attrs, "to_apply"))
            if sub_name:
                sub = _comp_totals(sub_name, comps, memo)
                flops += sub["flops"]
                nbytes += sub["bytes"]
                _merge_coll(coll, sub["collective"])
            else:
                nbytes += _instr_bytes(instr, name_shapes)
            continue
        if op == "conditional":
            branches = _branch_computations(instr.attrs)
            subs = [_comp_totals(b, comps, memo) for b in branches]
            if subs:
                worst = max(subs, key=lambda s: s["flops"])
                flops += worst["flops"]
                nbytes += worst["bytes"]
                _merge_coll(coll, worst["collective"])
            continue
        flops += _instr_flops(instr, name_shapes)
        nbytes += _instr_bytes(instr, name_shapes)
    memo[name] = {"flops": flops, "bytes": nbytes, "collective": coll}
    return memo[name]


_PARTITIONS_RE = re.compile(r"num_partitions\s*=\s*(\d+)")


def module_partitions(hlo_text: str) -> int:
    """SPMD partition count from the ``HloModule`` header line (1 when the
    module was not partitioned).  The header records it as
    ``num_partitions=N``; only the header is consulted so an instruction
    attribute can never spoof it."""
    for line in hlo_text.splitlines():
        if line.startswith("HloModule"):
            m = _PARTITIONS_RE.search(line)
            return max(1, int(m.group(1))) if m else 1
        if line.strip():
            break
    return 1


def analyze_module(hlo_text: str) -> Dict:
    """Analyze one HLO module's text.

    Returns ``{"flops", "bytes", "collective", "partitions"}`` where
    flops/bytes are per-device (SPMD-partitioned modules are already
    per-shard — ``partitions`` carries the shard count from the module
    header so callers can recover global totals, see ``sharded_totals``)
    and ``collective`` maps op name -> {"bytes", "count"} with while-loop
    bodies scaled by trip count.
    """
    parts = module_partitions(hlo_text)
    comps, entry = _parse_computations(hlo_text)
    if not entry:
        return {"flops": 0.0, "bytes": 0.0, "collective": {},
                "partitions": parts}
    totals = _comp_totals(entry, comps, {})
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "collective": dict(totals["collective"]), "partitions": parts}


def sharded_totals(hlo_text: str) -> Dict:
    """Per-device AND global accounting for one (possibly SPMD-partitioned)
    module: ``analyze_module``'s per-device numbers plus
    ``flops_global`` / ``bytes_global`` scaled by the partition count.

    For the sharded fused-trajectory scan this is the modeled weak-scaling
    story in one dict: per-device FLOPs shrink ~1/N while global FLOPs
    (and the collective tally, already trip-count-scaled per device) show
    what the extra devices cost in communication."""
    mod = analyze_module(hlo_text)
    n = mod["partitions"]
    return {**mod, "flops_global": mod["flops"] * n,
            "bytes_global": mod["bytes"] * n}


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Flat (loop-unaware) collective tally over raw HLO text — works on
    snippets that are not a complete module.  Async ``-start``/``-done``
    pairs count once."""
    out: Dict[str, Dict[str, int]] = {}
    for line in hlo_text.splitlines():
        parsed = _split_instruction(line)
        if parsed is None:
            continue
        _, type_str, op, _, _ = parsed
        base = _base_collective(op)
        if base is None:
            continue
        d = out.setdefault(base, {"bytes": 0, "count": 0})
        d["bytes"] += _collective_payload(op, _parse_shapes(type_str))
        d["count"] += 1
    return out


def collective_seconds(coll: Dict[str, Dict[str, int]], n_shards: int,
                       link_bw: float) -> float:
    """Ring-algorithm ICI time estimate for a collective tally.

    A ring over the FULL (unsharded) buffer moves ``full·(n-1)/n`` per
    link.  The tallied bytes are each op's RESULT: the full buffer for
    all-gather / all-reduce / all-to-all, but the 1/n-size shard for
    reduce-scatter — so reduce-scatter scales by ``(n-1)`` to recover the
    full-buffer ring.  All-reduce is reduce-scatter + all-gather (2×);
    permutes and broadcasts move the payload once.
    """
    if link_bw <= 0:
        return 0.0
    frac = (n_shards - 1) / n_shards if n_shards > 1 else 0.0
    total = 0.0
    for op, d in coll.items():
        b = float(d["bytes"])
        if op == "all-reduce":
            total += 2.0 * b * frac / link_bw
        elif op == "reduce-scatter":
            total += b * (n_shards - 1) / link_bw
        elif op in ("all-gather", "all-to-all", "ragged-all-to-all"):
            total += b * frac / link_bw
        else:  # permute / broadcast
            total += b / link_bw
    return total
