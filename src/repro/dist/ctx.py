"""Thread-local activation-sharding context.

Model code (models/layers.py) calls ``ctx.constrain(x, *axes)`` at every
layer boundary it wants pinned.  Outside an ``activation_sharding`` block —
unit tests, single-device benchmarks — constrain is the identity, so the
same layer code runs sharded and unsharded without branches.

Axis vocabulary: ``"batch"`` maps to ALL data-parallel mesh axes (``data``,
plus ``pod`` on multi-pod meshes) as one PartitionSpec entry; ``"model"``
maps to the tensor-parallel axis; ``None`` leaves a dim replicated.  A dim
whose size does not divide the mapped axis product is silently left
unconstrained rather than erroring — rule-of-least-surprise for reduced
test configs on production meshes.

The state is thread-local so concurrent dry-runs (launch/dryrun.py sweeps
driven from a thread pool) cannot observe each other's mesh.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class _State(threading.local):
    """Dict-like thread-local view; layers read ``_STATE["mesh"]`` etc."""

    def __init__(self):
        self.mesh = None
        self.dp: Tuple[str, ...] = ()
        self.model: Optional[str] = None
        self.opts: dict = {}
        self.active: bool = False

    def __getitem__(self, key: str) -> Any:
        return getattr(self, key)

    def snapshot(self) -> dict:
        return {"mesh": self.mesh, "dp": self.dp, "model": self.model,
                "opts": self.opts, "active": self.active}

    def restore(self, snap: dict) -> None:
        for k, v in snap.items():
            setattr(self, k, v)


_STATE = _State()

MODEL_AXIS = "model"


def data_axes(mesh) -> Tuple[str, ...]:
    """Every mesh axis that is not the tensor-parallel one (pod, data)."""
    return tuple(n for n in mesh.axis_names if n != MODEL_AXIS)


@contextmanager
def activation_sharding(mesh, **opts):
    """Enable activation sharding constraints on ``mesh`` for this thread.

    ``opts`` are free-form hillclimb knobs read back via ``ctx.opt``
    (e.g. ``moe_token_dp``, ``moe_shard_map``, ``mlstm_shard``).
    """
    snap = _STATE.snapshot()
    _STATE.mesh = mesh
    _STATE.dp = data_axes(mesh)
    _STATE.model = MODEL_AXIS if MODEL_AXIS in mesh.axis_names else None
    _STATE.opts = dict(opts)
    _STATE.active = True
    try:
        yield mesh
    finally:
        _STATE.restore(snap)


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``'data=8'`` / ``'data=4,model=2'`` -> ``{'data': 4, 'model': 2}``.

    The CLI surface for ``mesh(...)`` (launch/serve.py ``--mesh``,
    launch/dryrun.py ``--mesh``).  Unknown axis names are rejected rather
    than silently replicated — a typo'd ``--mesh dat=8`` must not run the
    whole job single-device."""
    out = {"data": 1, "model": 1}
    if not spec:
        return out
    for part in spec.split(","):
        name, eq, val = part.partition("=")
        name = name.strip()
        if name not in out or not eq:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected axis=N pairs with axes "
                f"in {tuple(out)}, got {part!r}")
        try:
            size = int(val)
        except ValueError as e:
            raise ValueError(f"bad mesh spec {spec!r}: {val!r} is not an "
                             "integer") from e
        if size < 1:
            raise ValueError(f"bad mesh spec {spec!r}: axis sizes must be "
                             f">= 1, got {size}")
        out[name] = size
    return out


def build_mesh(data: int = 1, model: int = 1, *,
               devices: Optional[Sequence] = None) -> Mesh:
    """A (data, model) device mesh over the FIRST data*model devices.

    Deterministic device order (so two contexts with the same spec build
    equal meshes and hit the same compiled-executable caches); raises when
    the host has too few devices instead of silently shrinking — CPU CI
    legs must set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    BEFORE jax initializes."""
    n = data * model
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh data={data} model={model} needs {n} devices, have "
            f"{len(devs)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before jax initializes)")
    arr = np.asarray(devs[:n], dtype=object).reshape(data, model)
    return Mesh(arr, ("data", "model"))


@contextmanager
def mesh(data: int = 1, model: int = 1, *,
         devices: Optional[Sequence] = None, **opts):
    """Mesh-scoped context: ``with dist.mesh(data=8):``.

    Builds a (data, model) device mesh and activates the thread-local
    sharding context on it, so everything downstream — the fused DDIM
    trajectory executor (sampling/trajectory.py), the serving engines'
    slot pools, ``ctx.constrain`` in the layers — picks the mesh up
    without threading it through every call.  ``opts`` are forwarded to
    ``activation_sharding`` (perf hillclimb knobs)."""
    m = build_mesh(data, model, devices=devices)
    with m, activation_sharding(m, **opts):
        yield m


def current_mesh():
    """The active context's mesh, or None outside a mesh/activation-
    sharding block (single-device paths)."""
    return _STATE.mesh if active() else None


def mesh_cache_key(m=None) -> Optional[tuple]:
    """Hashable identity of a mesh for executable caches (axis sizes +
    device assignment); None when no mesh is active.  Two ``mesh(data=8)``
    contexts yield equal keys, so trace caches keyed on this survive
    context exit/re-entry."""
    m = m if m is not None else current_mesh()
    if m is None:
        return None
    return (tuple((a, int(m.shape[a])) for a in m.axis_names),
            tuple(int(d.id) for d in np.asarray(m.devices).flat))


@contextmanager
def disabled():
    """Temporarily suppress constraints (e.g. inside shard_map bodies,
    where activations are already device-local and a nested
    with_sharding_constraint would be wrong)."""
    prev = _STATE.active
    _STATE.active = False
    try:
        yield
    finally:
        _STATE.active = prev


def active() -> bool:
    return bool(_STATE.active and _STATE.mesh is not None)


def opt(name: str, default: Any = None) -> Any:
    """Read a context option; ``default`` when inactive or unset."""
    if not active():
        return default
    return _STATE.opts.get(name, default)


def _axis_entry(mesh, axis: Optional[str], dim: int):
    """Map one logical axis name to a PartitionSpec entry for a dim of
    size ``dim`` — or None when unmapped / not divisible."""
    if axis is None:
        return None
    if axis == "batch":
        names = _STATE.dp
    elif axis == MODEL_AXIS:
        names = (_STATE.model,) if _STATE.model else ()
    else:
        names = (axis,) if axis in mesh.axis_names else ()
    if not names:
        return None
    size = math.prod(mesh.shape[n] for n in names)
    if size <= 1 or dim % size != 0:
        return None
    return names if len(names) > 1 else names[0]


def constrain(x, *axes):
    """PartitionSpec-based ``with_sharding_constraint``; identity when the
    context is inactive.  ``axes``: one entry per leading dim of ``x``
    (trailing dims default to None)."""
    if not active():
        return x
    mesh = _STATE.mesh
    spec = [_axis_entry(mesh, a, x.shape[i]) for i, a in enumerate(axes)]
    spec += [None] * (x.ndim - len(spec))
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))
