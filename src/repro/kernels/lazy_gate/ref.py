"""Pure-jnp oracle for the fused lazy-gate probe."""
import jax.numpy as jnp


def lazy_gate_pooled_ref(x, scale, shift, w):
    """x: (B,N,D); scale/shift: (B,D); w: (D,1) -> (B,) f32 token-SUM of the
    modulated probe response."""
    z = (x.astype(jnp.float32) * (1.0 + scale.astype(jnp.float32))[:, None, :]
         + shift.astype(jnp.float32)[:, None, :])
    return jnp.sum(z @ w.astype(jnp.float32), axis=(1, 2))


def lazy_gate_score_ref(x, scale, shift, w, b):
    """Full probe: sigmoid(mean_n(probe) + b) — matches core.lazy.gate_score
    on modulated input."""
    import jax
    pooled = lazy_gate_pooled_ref(x, scale, shift, w) / x.shape[1]
    return jax.nn.sigmoid(pooled + b)
