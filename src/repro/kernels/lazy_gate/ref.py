"""Pure-jnp oracles for the fused lazy-gate kernels."""
import jax
import jax.numpy as jnp


def lazy_gate_pooled_ref(x, scale, shift, w):
    """x: (B,N,D); scale/shift: (B,D); w: (D,1) -> (B,) f32 token-SUM of the
    modulated probe response."""
    z = (x.astype(jnp.float32) * (1.0 + scale.astype(jnp.float32))[:, None, :]
         + shift.astype(jnp.float32)[:, None, :])
    return jnp.sum(z @ w.astype(jnp.float32), axis=(1, 2))


def lazy_gate_score_ref(x, scale, shift, w, b):
    """Full probe: sigmoid(mean_n(probe) + b) — matches core.lazy.gate_score
    on modulated input."""
    pooled = lazy_gate_pooled_ref(x, scale, shift, w) / x.shape[1]
    return jax.nn.sigmoid(pooled + b)


def lazy_gate_select_ref(z, w, b, y_new, cache_y, fresh=None, *,
                         threshold: float = 0.5):
    """Oracle for the fused gate+select kernel: op-for-op the math
    ``core.lazy`` masked mode emits (``gate_score`` then ``select_cached``)
    so the CPU dispatch of the pallas backend is bit-exact with the XLA
    baseline.  Returns (y (B,N,D), score (B,) f32)."""
    zp = z.astype(jnp.float32) @ w.astype(jnp.float32)         # (B, N, 1)
    pooled = jnp.mean(zp[..., 0], axis=-1) + b.astype(jnp.float32)[0]
    score = jax.nn.sigmoid(pooled)                             # (B,)
    skip = jnp.reshape(score > threshold, (-1,) + (1,) * (y_new.ndim - 1))
    if fresh is not None:
        not_fresh = jnp.logical_not(
            jnp.reshape(fresh, (-1,) + (1,) * (y_new.ndim - 1)))
        skip = jnp.logical_and(skip, not_fresh)
    return jnp.where(skip, cache_y, y_new), score
