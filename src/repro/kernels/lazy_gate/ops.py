"""Jit'd public wrapper for the fused lazy-gate probe.

On CPU (this container) the kernel body runs under interpret=True; on TPU
pass interpret=False for the compiled Mosaic kernel.  ``use_pallas=False``
falls back to the jnp oracle (used for HLO-level dry-runs where a Pallas
call would not lower on the host platform).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lazy_gate.kernel import lazy_gate_pooled
from repro.kernels.lazy_gate.ref import lazy_gate_pooled_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lazy_gate_score(x, scale, shift, w, b, *, use_pallas: bool = True,
                    interpret: bool = True):
    """Fused modulate+probe+pool+sigmoid: (B,N,D)->(B,) in (0,1)."""
    if use_pallas:
        pooled = lazy_gate_pooled(x, scale, shift, w, interpret=interpret)
    else:
        pooled = lazy_gate_pooled_ref(x, scale, shift, w)
    return jax.nn.sigmoid(pooled / x.shape[1] + b.astype(jnp.float32))
