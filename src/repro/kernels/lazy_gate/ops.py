"""Jit'd public wrappers for the fused lazy-gate kernels.

``lazy_gate_score`` is the probe alone (modulate + matvec + pool +
sigmoid).  ``lazy_gate_select`` is the masked-mode fusion (DESIGN.md
§Kernels): probe score + threshold + fresh-or-cached tile write in one
pass, so masked mode stops materializing both select branches in HBM.

Dispatch: compiled-Pallas targets (TPU) run the fused kernel; interpret
hosts (CPU) run the jnp reference — which is op-for-op the same math
``core.lazy`` masked mode emits today (``gate_score`` +
``select_cached``), so the CPU pallas backend stays bit-exact with the
XLA baseline on this path.  ``use_pallas=False`` forces the reference
(HLO-level dry-runs where a Pallas call would not lower).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.lazy_gate.kernel import lazy_gate_pooled
from repro.kernels.lazy_gate.kernel import lazy_gate_select as _select_kernel
from repro.kernels.lazy_gate.ref import (lazy_gate_pooled_ref,
                                         lazy_gate_select_ref)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lazy_gate_score(x, scale, shift, w, b, *, use_pallas: bool = True,
                    interpret=None):
    """Fused modulate+probe+pool+sigmoid: (B,N,D)->(B,) in (0,1)."""
    if use_pallas:
        pooled = lazy_gate_pooled(x, scale, shift, w, interpret=interpret)
    else:
        pooled = lazy_gate_pooled_ref(x, scale, shift, w)
    return jax.nn.sigmoid(pooled / x.shape[1] + b.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("threshold", "use_pallas",
                                             "interpret"))
def lazy_gate_select(z, w, b, y_new, cache_y, fresh=None, *,
                     threshold: float = 0.5, use_pallas: bool = True,
                     interpret=None):
    """Fused masked-mode gating: (y, score) — serve the cached tile where
    sigmoid(mean_n(z @ w) + b) > threshold (and the cache is not fresh),
    the fresh tile elsewhere.  See kernel.lazy_gate_select for shapes."""
    interp = resolve_interpret(interpret)
    if use_pallas and not interp:
        return _select_kernel(z, w, b, y_new, cache_y, fresh,
                              threshold=threshold, interpret=interpret)
    return lazy_gate_select_ref(z, w, b, y_new, cache_y, fresh,
                                threshold=threshold)
