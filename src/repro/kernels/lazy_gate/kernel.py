"""Fused lazy-gate probe kernel (the paper's added layer).

Computes, in ONE pass over the activation tile resident in VMEM:

    pooled[b] = mean_n( (x[b,n,:] * (1 + scale[b,:]) + shift[b,:]) @ w )

i.e. adaLN modulate + the D->1 probe matvec + token pooling fused, so the
probe's overhead is a single VMEM read of the activation instead of three
HBM round-trips (modulate out, matvec in, reduce in).  The sigmoid and bias
live in ops.py (scalar epilogue).

Grid: (B, N // BLOCK_N) — token-tiled, sequential accumulation into the
(B,) output (TPU grids iterate the trailing dim sequentially per core, so
read-modify-write on out_ref is safe).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

BLOCK_N = 128


def _lazy_gate_kernel(x_ref, scale_ref, shift_ref, w_ref, out_ref):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0].astype(jnp.float32)              # (BLOCK_N, D)
    sc = scale_ref[0].astype(jnp.float32)         # (D,)
    sh = shift_ref[0].astype(jnp.float32)         # (D,)
    w = w_ref[...].astype(jnp.float32)            # (D, 1)
    z = x * (1.0 + sc)[None, :] + sh[None, :]
    part = jnp.sum(z @ w)                         # scalar: sum over tile tokens
    out_ref[0, 0] += part


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def lazy_gate_pooled(x, scale, shift, w, *, interpret: Optional[bool] = None,
                     block_n: int = BLOCK_N):
    """x: (B, N, D); scale/shift: (B, D); w: (D, 1) -> pooled (B,) f32
    (pre-bias, pre-sigmoid; SUM over tokens — divide by N outside)."""
    interpret = resolve_interpret(interpret)
    B, N, D = x.shape
    pad = (-N) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        # padded tokens contribute shift@w each; subtracted in ops.py? no:
        # zero them by masking is costly — instead pad contributes
        # (0*(1+sc)+sh)@w = sh@w per padded token; ops.py corrects.
    nN = (N + pad) // block_n

    out = pl.pallas_call(
        _lazy_gate_kernel,
        grid=(B, nN),
        in_specs=[
            pl.BlockSpec((1, block_n, D), lambda b, n: (b, n, 0)),
            pl.BlockSpec((1, D), lambda b, n: (b, 0)),
            pl.BlockSpec((1, D), lambda b, n: (b, 0)),
            pl.BlockSpec((D, 1), lambda b, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, n: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(x, scale, shift, w)
    pooled = out[:, 0]
    if pad:
        # remove the padded tokens' (shift @ w) contribution
        corr = pad * (shift.astype(jnp.float32)
                      @ w.astype(jnp.float32))[:, 0]
        pooled = pooled - corr
    return pooled


def _gate_select_kernel(z_ref, w_ref, b_ref, y_ref, c_ref, f_ref,
                        o_ref, s_ref, acc_scr, *, threshold: float,
                        n_tok: int):
    """Fused probe + threshold + select (DESIGN.md §Kernels).

    Grid (B, 2, nN), two sequential phases per example: phase 0 sweeps the
    token tiles of the MODULATED probe input z accumulating sum_n(z @ w)
    into scratch; phase 1 re-sweeps the tiles and writes either the fresh
    or the cached output tile — the cached tile is copied through verbatim
    (bit-exact), the skip decision never leaves VMEM, and the (B, N, D)
    where-select intermediate the XLA path materializes is gone."""
    ph = pl.program_id(1)
    nj = pl.program_id(2)

    @pl.when((ph == 0) & (nj == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ph == 0)
    def _accum():
        z = z_ref[0].astype(jnp.float32)              # (BLOCK_N, D)
        w = w_ref[...].astype(jnp.float32)            # (D, 1)
        # zero-padded tokens contribute 0 @ w = 0 — no pad correction
        acc_scr[0, 0] += jnp.sum(z @ w)

    @pl.when(ph == 1)
    def _select():
        score = jax.nn.sigmoid(acc_scr[0, 0] / n_tok
                               + b_ref[0].astype(jnp.float32))
        skip = (score > threshold) & (f_ref[0, 0] == 0)
        o_ref[0] = jnp.where(skip, c_ref[0], y_ref[0])

    @pl.when((ph == 1) & (nj == 0))
    def _emit_score():
        s_ref[0, 0] = jax.nn.sigmoid(acc_scr[0, 0] / n_tok
                                     + b_ref[0].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("threshold", "interpret",
                                             "block_n"))
def lazy_gate_select(z, w, b, y_new, cache_y, fresh=None, *,
                     threshold: float = 0.5,
                     interpret: Optional[bool] = None,
                     block_n: int = BLOCK_N):
    """Fused masked-mode gating: probe score + threshold + fresh-or-cached
    tile write in ONE pass.

    z: (B, N, D) modulated probe input; w: (D, 1); b: (1,); y_new /
    cache_y: (B, N, D) fresh module output and previous-step cache;
    fresh: optional (B,)-broadcastable bool — set entries never serve
    their (just-reset) cache.  Returns (y (B, N, D), score (B,) f32),
    matching core.lazy masked-mode semantics (skip iff score > threshold)."""
    interpret = resolve_interpret(interpret)
    B, N, D = z.shape
    pad = (-N) % block_n
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        y_new = jnp.pad(y_new, ((0, 0), (0, pad), (0, 0)))
        cache_y = jnp.pad(cache_y, ((0, 0), (0, pad), (0, 0)))
    nN = (N + pad) // block_n
    if fresh is None:
        f = jnp.zeros((B, 1), jnp.int32)
    else:
        f = jnp.broadcast_to(jnp.reshape(fresh, (-1, 1)),
                             (B, 1)).astype(jnp.int32)

    kern = functools.partial(_gate_select_kernel, threshold=threshold,
                             n_tok=N)
    y, score = pl.pallas_call(
        kern,
        grid=(B, 2, nN),
        in_specs=[
            pl.BlockSpec((1, block_n, D), lambda bI, p, n: (bI, n, 0)),
            pl.BlockSpec((D, 1), lambda bI, p, n: (0, 0)),
            pl.BlockSpec((1,), lambda bI, p, n: (0,)),
            pl.BlockSpec((1, block_n, D), lambda bI, p, n: (bI, n, 0)),
            pl.BlockSpec((1, block_n, D), lambda bI, p, n: (bI, n, 0)),
            pl.BlockSpec((1, 1), lambda bI, p, n: (bI, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n, D), lambda bI, p, n: (bI, n, 0)),
            pl.BlockSpec((1, 1), lambda bI, p, n: (bI, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nN * block_n, D), y_new.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(z, w, b, y_new, cache_y, f)
    return y[:, :N], score[:, 0]
