"""Fused lazy-gate probe kernel (the paper's added layer).

Computes, in ONE pass over the activation tile resident in VMEM:

    pooled[b] = mean_n( (x[b,n,:] * (1 + scale[b,:]) + shift[b,:]) @ w )

i.e. adaLN modulate + the D->1 probe matvec + token pooling fused, so the
probe's overhead is a single VMEM read of the activation instead of three
HBM round-trips (modulate out, matvec in, reduce in).  The sigmoid and bias
live in ops.py (scalar epilogue).

Grid: (B, N // BLOCK_N) — token-tiled, sequential accumulation into the
(B,) output (TPU grids iterate the trailing dim sequentially per core, so
read-modify-write on out_ref is safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _lazy_gate_kernel(x_ref, scale_ref, shift_ref, w_ref, out_ref):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0].astype(jnp.float32)              # (BLOCK_N, D)
    sc = scale_ref[0].astype(jnp.float32)         # (D,)
    sh = shift_ref[0].astype(jnp.float32)         # (D,)
    w = w_ref[...].astype(jnp.float32)            # (D, 1)
    z = x * (1.0 + sc)[None, :] + sh[None, :]
    part = jnp.sum(z @ w)                         # scalar: sum over tile tokens
    out_ref[0, 0] += part


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def lazy_gate_pooled(x, scale, shift, w, *, interpret: bool = True,
                     block_n: int = BLOCK_N):
    """x: (B, N, D); scale/shift: (B, D); w: (D, 1) -> pooled (B,) f32
    (pre-bias, pre-sigmoid; SUM over tokens — divide by N outside)."""
    B, N, D = x.shape
    pad = (-N) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        # padded tokens contribute shift@w each; subtracted in ops.py? no:
        # zero them by masking is costly — instead pad contributes
        # (0*(1+sc)+sh)@w = sh@w per padded token; ops.py corrects.
    nN = (N + pad) // block_n

    out = pl.pallas_call(
        _lazy_gate_kernel,
        grid=(B, nN),
        in_specs=[
            pl.BlockSpec((1, block_n, D), lambda b, n: (b, n, 0)),
            pl.BlockSpec((1, D), lambda b, n: (b, 0)),
            pl.BlockSpec((1, D), lambda b, n: (b, 0)),
            pl.BlockSpec((D, 1), lambda b, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, n: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(x, scale, shift, w)
    pooled = out[:, 0]
    if pad:
        # remove the padded tokens' (shift @ w) contribution
        corr = pad * (shift.astype(jnp.float32)
                      @ w.astype(jnp.float32))[:, 0]
        pooled = pooled - corr
    return pooled
