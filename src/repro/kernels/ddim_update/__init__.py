# Fused DDIM update: epsilon -> x_{t-1} (+ eta-noise) in one
# read-modify-write over the latent, replacing the 6+ elementwise HLO ops
# sampling/ddim.ddim_step otherwise emits (DESIGN.md §Kernels).
