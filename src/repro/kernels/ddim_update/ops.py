"""Jit'd public wrapper for the fused DDIM update.

Compiled-Pallas targets (TPU) run the fused kernel; interpret hosts (CPU)
run the jnp reference — the identical expression tree ``ddim_step``'s XLA
path emits, so flipping the kernel backend on CPU does not move a bit on
this op.  ``use_pallas=False`` forces the reference (HLO dry-runs)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.backend import resolve_interpret
from repro.kernels.ddim_update.kernel import ddim_update as _ddim_kernel
from repro.kernels.ddim_update.ref import ddim_update_ref


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas",
                                             "interpret"))
def ddim_update(z_t, eps, a_t, a_p, noise=None, *, eta: float = 0.0,
                use_pallas: bool = True, interpret=None):
    """Fused x_{t-1} update (see kernel.ddim_update for shapes)."""
    interp = resolve_interpret(interpret)
    if use_pallas and not interp:
        return _ddim_kernel(z_t, eps, a_t, a_p, noise, eta=eta,
                            interpret=interpret)
    return ddim_update_ref(z_t, eps, a_t, a_p, noise, eta=eta)
