"""Fused DDIM-update kernel (TPU Pallas).

One grid step reads a latent tile, its epsilon tile (and, at eta > 0, a
noise tile) plus the two per-example alpha-bar scalars, and writes the
x_{t-1} tile — the whole Song et al. Eq. 16 update in a single
read-modify-write:

    x0    = (z - sqrt(1-a_t) eps) / sqrt(a_t)
    sigma = eta sqrt((1-a_p)/(1-a_t)) sqrt(1 - a_t/a_p)
    z'    = sqrt(a_p) x0 + sqrt(1-a_p - sigma^2) eps + sigma noise

The XLA path materializes each intermediate (x0, the scaled eps, the
sigma term) as its own HBM-bound elementwise op unless fusion wins; here
the tile never leaves VMEM between ops.  ``eta`` is STATIC, matching
``ddim_step``'s contract: at eta = 0 the deterministic update is emitted
with no dead noise ops.

Grid: (B, M // BLOCK_M) over the flattened latent.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

BLOCK_M = 512


def _ddim_update_kernel(z_ref, e_ref, at_ref, ap_ref, o_ref, *, eta: float):
    z = z_ref[0].astype(jnp.float32)                 # (block_m,)
    e = e_ref[0].astype(jnp.float32)
    a_t = at_ref[0, 0]
    a_p = ap_ref[0, 0]
    x0 = (z - jnp.sqrt(1.0 - a_t) * e) / jnp.sqrt(a_t)
    out = jnp.sqrt(a_p) * x0 + jnp.sqrt(1.0 - a_p) * e
    del eta
    o_ref[0] = out.astype(o_ref.dtype)


def _ddim_update_noise_kernel(z_ref, e_ref, at_ref, ap_ref, n_ref, o_ref, *,
                              eta: float):
    z = z_ref[0].astype(jnp.float32)
    e = e_ref[0].astype(jnp.float32)
    n = n_ref[0].astype(jnp.float32)
    a_t = at_ref[0, 0]
    a_p = ap_ref[0, 0]
    x0 = (z - jnp.sqrt(1.0 - a_t) * e) / jnp.sqrt(a_t)
    sigma = (eta * jnp.sqrt((1.0 - a_p) / (1.0 - a_t))
             * jnp.sqrt(1.0 - a_t / a_p))
    dir_eps = jnp.sqrt(jnp.maximum(1.0 - a_p - sigma ** 2, 0.0))
    out = jnp.sqrt(a_p) * x0 + dir_eps * e + sigma * n
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eta", "interpret", "block_m"))
def ddim_update(z, eps, a_t, a_p, noise=None, *, eta: float = 0.0,
                interpret: Optional[bool] = None, block_m: int = BLOCK_M):
    """z/eps[/noise]: (B, ...) latents; a_t/a_p: (B,) alpha-bars (a_p
    already 1.0 on the final step — gathered by the caller, see
    sampling/ddim.ddim_step).  Returns x_{t-1} with z's shape/dtype."""
    interpret = resolve_interpret(interpret)
    B = z.shape[0]
    orig_shape = z.shape
    M = 1
    for s in z.shape[1:]:
        M *= s
    zf = z.reshape(B, M)
    ef = eps.reshape(B, M)
    pad = (-M) % block_m
    if pad:
        zf = jnp.pad(zf, ((0, 0), (0, pad)))
        ef = jnp.pad(ef, ((0, 0), (0, pad)))
    nM = (M + pad) // block_m
    at2 = jnp.broadcast_to(a_t.astype(jnp.float32).reshape(-1, 1), (B, 1))
    ap2 = jnp.broadcast_to(a_p.astype(jnp.float32).reshape(-1, 1), (B, 1))

    tile = pl.BlockSpec((1, block_m), lambda bI, m: (bI, m))
    scal = pl.BlockSpec((1, 1), lambda bI, m: (bI, 0))
    use_noise = eta > 0.0 and noise is not None
    if use_noise:
        nf = noise.reshape(B, M)
        if pad:
            nf = jnp.pad(nf, ((0, 0), (0, pad)))
        kern = functools.partial(_ddim_update_noise_kernel, eta=eta)
        in_specs = [tile, tile, scal, scal, tile]
        operands = (zf, ef, at2, ap2, nf)
    else:
        kern = functools.partial(_ddim_update_kernel, eta=eta)
        in_specs = [tile, tile, scal, scal]
        operands = (zf, ef, at2, ap2)
    out = pl.pallas_call(
        kern,
        grid=(B, nM),
        in_specs=in_specs,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((B, nM * block_m), z.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :M].reshape(orig_shape)
