"""Pure-jnp oracle for the fused DDIM update.

Op-for-op the math ``sampling/ddim.ddim_step`` emits after gathering the
alpha-bars, so the CPU dispatch of the pallas backend stays bit-exact
with the XLA baseline on this path."""
from __future__ import annotations

import jax.numpy as jnp


def ddim_update_ref(z_t, eps, a_t, a_p, noise=None, *, eta: float = 0.0):
    """z_t/eps[/noise]: (B, ...); a_t/a_p: (B,).  Song et al. Eq. 16 with
    a_p pre-gathered (1.0 on the final step)."""
    shape = (-1,) + (1,) * (z_t.ndim - 1)
    a_t, a_p = a_t.reshape(shape), a_p.reshape(shape)
    x0 = (z_t - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    if eta == 0.0 or noise is None:
        return jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps
    sigma = (eta * jnp.sqrt((1 - a_p) / (1 - a_t))
             * jnp.sqrt(1 - a_t / a_p))
    dir_eps = jnp.sqrt(jnp.maximum(1 - a_p - sigma ** 2, 0.0))
    return jnp.sqrt(a_p) * x0 + dir_eps * eps + sigma * noise
