"""Oracle: the model's own sLSTM cell loop (models/layers._slstm_cell)."""
import jax
import jax.numpy as jnp

from repro.models.layers import _slstm_cell


def slstm_scan_ref(gx, r, f_bias, *, nh: int):
    """gx: (B, S, 4D); r: (nh, 4, hd, hd); f_bias: (D,) -> h (B, S, D)."""
    B, S, D4 = gx.shape
    D = D4 // 4
    params = {"r": r, "f_bias": f_bias, "w_x": None}
    state = {k: jnp.zeros((B, D), jnp.float32) for k in ("c", "n", "h", "m")}

    def step(st, gx_t):
        st2 = _slstm_cell(params, (nh, D // nh), gx_t, st)
        return st2, st2["h"]

    _, hs = jax.lax.scan(step, state, gx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)
