"""Public wrapper for the sLSTM VMEM scan with jnp fallback."""
from __future__ import annotations

import functools

import jax

from repro.kernels.slstm_scan.kernel import slstm_scan
from repro.kernels.slstm_scan.ref import slstm_scan_ref


@functools.partial(jax.jit, static_argnames=("nh", "chunk", "use_pallas",
                                             "interpret"))
def slstm_sequence(gx, r, f_bias, *, nh: int, chunk: int = 64,
                   use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return slstm_scan(gx, r, f_bias, nh=nh, chunk=chunk,
                          interpret=interpret)
    return slstm_scan_ref(gx, r, f_bias, nh=nh)
