"""sLSTM sequential scan kernel (TPU Pallas) — §Perf hillclimb C's fix.

The xLSTM sLSTM recurrence is inherently sequential; under XLA it lowers to
a 4096-iteration while loop whose (B, D) cell states round-trip HBM every
step (~25 GB/layer-pass measured) and whose sharded gate splits emit a TP
collective per step (1.4M collectives per train step on xlstm-1.3b).

This kernel keeps (c, n, h, m) in VMEM scratch and walks CHUNK timesteps
per grid step from a VMEM-resident slice of the pre-projected gates, so HBM
traffic collapses to: read gates once + write h once (~2.5 GB/layer-pass,
10x; see EXPERIMENTS.md §Perf C).  Block-diagonal recurrence weights
(h, 4, hd, hd) stay resident too.

Forward-only (inference/serving + the §Perf projection); training
integration needs a custom VJP — tracked in the backlog.

Grid: (B_blocks, n_chunks) — chunks sequential per batch block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64


def _slstm_kernel(gx_ref, r_ref, fb_ref, h_out_ref,
                  c_scr, n_scr, h_scr, m_scr, *, chunk: int, nh: int, hd: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        h_scr[...] = jnp.zeros_like(h_scr)
        # m init 0 matches models/layers.init_slstm_cache (the max(n,1)
        # output floor makes the stabilizer convention observable)
        m_scr[...] = jnp.zeros_like(m_scr)

    gx = gx_ref[0].astype(jnp.float32)            # (chunk, 4D)
    r = r_ref[...].astype(jnp.float32)            # (nh, 4, hd, hd)
    fb = fb_ref[...].astype(jnp.float32)          # (D,)
    D = nh * hd

    def step(t, carry):
        c, n, h, m = carry
        hp = h.reshape(1, nh, hd)
        rec = jnp.einsum("bhd,hgde->bghe", hp, r).reshape(4 * D)
        g = gx[t] + rec
        zi, ii, fi, oi = g[:D], g[D:2 * D], g[2 * D:3 * D] + fb, g[3 * D:]
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oi)
        logf = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(logf + m, ii)
        ia = jnp.exp(ii - m_new)
        fa = jnp.exp(logf + m - m_new)
        c_new = fa * c + ia * z
        n_new = fa * n + ia
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        h_out_ref[0, t] = h_new.astype(h_out_ref.dtype)
        return c_new, n_new, h_new, m_new

    c, n, h, m = lax.fori_loop(
        0, chunk, step,
        (c_scr[0], n_scr[0], h_scr[0], m_scr[0]))
    c_scr[0], n_scr[0], h_scr[0], m_scr[0] = c, n, h, m


@functools.partial(jax.jit, static_argnames=("nh", "chunk", "interpret"))
def slstm_scan(gx, r, f_bias, *, nh: int, chunk: int = CHUNK,
               interpret: bool = True):
    """gx: (B, S, 4D) pre-projected gates; r: (nh, 4, hd, hd) recurrence;
    f_bias: (D,).  Returns h: (B, S, D).  S padded to a chunk multiple by
    the caller (gx rows past S are ignored by slicing)."""
    B, S, D4 = gx.shape
    D = D4 // 4
    hd = D // nh
    pad = (-S) % chunk
    if pad:
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk

    kern = functools.partial(_slstm_kernel, chunk=chunk, nh=nh, hd=hd)
    h = pl.pallas_call(
        kern,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 4 * D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((nh, 4, hd, hd), lambda b, c: (0, 0, 0, 0)),
            pl.BlockSpec((D,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S + pad, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),      # c
            pltpu.VMEM((1, D), jnp.float32),      # n
            pltpu.VMEM((1, D), jnp.float32),      # h
            pltpu.VMEM((1, D), jnp.float32),      # m
        ],
        interpret=interpret,
    )(gx, r, f_bias)
    return h[:, :S]
