"""Oracle for the SSD chunk-scan kernel: the model's own chunked scan
(validated against the naive recurrence in tests)."""
import jax.numpy as jnp

from repro.models.layers import ssd_chunked


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int = 128):
    y, _ = ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk)
    return y


def ssd_naive_ref(x, dt, A, Bm, Cm):
    """Step-by-step recurrence (slow, ground truth)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                     # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
        state = state * dA[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    return jnp.stack(ys, axis=1)
