"""Chunked Mamba2/SSD state-scan kernel (TPU Pallas).

One grid step processes one (batch, chunk) tile entirely in VMEM: the
intra-chunk quadratic term, the carry-in state contribution, and the state
update — the recurrent state (H, P, N) persists in VMEM scratch across the
sequential chunk dimension, so the O(S) recurrence never round-trips HBM
(the TPU-native replacement for the paper-adjacent GPU selective-scan
kernels; DESIGN.md §3).

Grid: (B, n_chunks) — chunks iterate sequentially per batch row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    A = a_ref[...].astype(jnp.float32)        # (H,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    Q = x.shape[0]

    dA = dt * A[None, :]                      # (Q, H)
    dA_cs = jnp.cumsum(dA, axis=0)            # (Q, H)
    # intra-chunk decay L[h, l, s] = exp(cs[l] - cs[s]) for s <= l
    seg = dA_cs[:, None, :] - dA_cs[None, :, :]          # (l, s, H)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri[..., None], jnp.exp(seg), 0.0)     # (l, s, H)
    xdt = x * dt[..., None]                              # (Q, H, P)
    cb = Cm @ Bm.T                                       # (l, s)
    w = cb[..., None] * L                                # (l, s, H)
    y_diag = jnp.einsum("lsh,shp->lhp", w, xdt)
    # carry-in contribution
    state = state_scr[...]                               # (H, P, N)
    y_off = jnp.einsum("ln,hpn->lhp", Cm, state) * jnp.exp(dA_cs)[..., None]
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)
    # state update
    decay_states = jnp.exp(dA_cs[-1:, :] - dA_cs)        # (Q, H)
    upd = jnp.einsum("qn,qh,qhp->hpn", Bm, decay_states * dt, x)
    state_scr[...] = state * jnp.exp(dA_cs[-1])[:, None, None] + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N) -> y (B,S,H,P).

    S must be padded to a chunk multiple by the caller (dt=0 padding)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    kern = functools.partial(_ssd_kernel, nc=nc)
    y = pl.pallas_call(
        kern,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y
