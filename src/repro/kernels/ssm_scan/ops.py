"""Public wrapper for the SSD chunk-scan kernel with jnp fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_scan
from repro.kernels.ssm_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, use_pallas: bool = True,
        interpret: bool = True):
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))      # dt=0: no-op steps
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if use_pallas:
        y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    else:
        y = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    return y[:, :S]
