"""Public wrapper: GQA-aware flash attention with jnp fallback, plus the
plan-aware (skip-bit) entry point.

Dispatch policy for the lazy path (DESIGN.md §Kernels): on a compiled
Pallas target (TPU — ``resolve_interpret() == False``) the skip bit rides
the scalar-prefetch operand of ``flash_attention_lazy`` and gates whole
grid steps inside the kernel.  On hosts where Pallas only interprets (CPU)
the grid loop would pay full cost regardless of ``pl.when``, so the same
semantics are realized one level up: ``lax.cond`` on the all-skip
predicate short-circuits the entire attention computation at runtime —
the branch XLA takes when every plan bit says reuse touches nothing but
the cached tiles.  Both realizations serve the cache bit-exactly."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.flash_attention.kernel import (flash_attention,
                                                 flash_attention_lazy)
from repro.kernels.flash_attention.ref import attention_lazy_ref, attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "use_pallas", "interpret"))
def gqa_flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        use_pallas=True, interpret=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) — layout of models/layers.sdpa.
    Repeats kv heads to H, dispatches to the Pallas kernel or the oracle."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    if use_pallas:
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, interpret=interpret)
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window,
                            softcap=softcap)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "use_pallas", "interpret"))
def lazy_gqa_flash_attention(q, k, v, cached, skip, *, causal=False,
                             window=0, softcap=0.0, use_pallas=True,
                             interpret=None):
    """Plan-aware attention in the models/layers.sdpa layout.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); cached: (B, Sq, H, hd) — the
    previous step's attention output; skip: (B,) bool/int plan bits.
    Examples with skip set get their cached tile bit-exactly; the rest get
    fresh attention.  Compiled-Pallas targets run the skip-gated kernel;
    interpret-mode hosts hoist the skip to a runtime ``lax.cond`` so an
    all-skip step costs O(1) instead of O(Sq·Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    ct = cached.transpose(0, 2, 1, 3)
    skip = (skip != 0).reshape(B)

    interp = resolve_interpret(interpret)
    if use_pallas and not interp:
        out = flash_attention_lazy(qt, kt, vt, ct, skip, causal=causal,
                                   window=window, softcap=softcap,
                                   interpret=interpret)
    else:
        def _serve_all():
            return ct

        def _mixed():
            fresh = attention_ref(qt, kt, vt, causal=causal, window=window,
                                  softcap=softcap)
            return jnp.where(skip.reshape(-1, 1, 1, 1), ct, fresh)

        out = jax.lax.cond(jnp.all(skip), _serve_all, _mixed)
    return out.transpose(0, 2, 1, 3)


__all__ = ["gqa_flash_attention", "lazy_gqa_flash_attention",
           "flash_attention", "flash_attention_lazy", "attention_ref",
           "attention_lazy_ref"]
