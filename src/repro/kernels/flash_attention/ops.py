"""Public wrapper: GQA-aware flash attention with jnp fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "use_pallas", "interpret"))
def gqa_flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        use_pallas=True, interpret=True):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) — layout of models/layers.sdpa.
    Repeats kv heads to H, dispatches to the Pallas kernel or the oracle."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    if use_pallas:
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, interpret=interpret)
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window,
                            softcap=softcap)
    return out.transpose(0, 2, 1, 3)
