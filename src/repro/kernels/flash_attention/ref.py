"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q,k,v: (B, H, S, d)."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_lazy_ref(q, k, v, cached, skip, *, causal=False, window=0,
                       softcap=0.0):
    """Oracle for the plan-aware kernel: where the per-example skip bit is
    set the cached tile is served verbatim (bit-exact — no arithmetic
    touches it), elsewhere fresh attention.  q/k/v/cached: (B, H, S, d);
    skip: (B,) bool/int."""
    fresh = attention_ref(q, k, v, causal=causal, window=window,
                          softcap=softcap)
    keep = (skip != 0).reshape(-1, 1, 1, 1)
    return jnp.where(keep, cached, fresh)
