"""Blocked flash attention (TPU Pallas) with causal, sliding-window and
logit-softcap support — the prefill hot-spot.

TPU adaptation (DESIGN.md §3): tiles are MXU-aligned (multiples of 128 on
the contracting dims), the working set per grid step is
(BLOCK_Q + 2·BLOCK_K) × head_dim + BLOCK_Q × BLOCK_K floats in VMEM, and the
online-softmax running stats (m, l, acc) live in VMEM scratch that persists
across the sequential trailing grid dimension (k-blocks).

Grid: (B·H, nQ, nK) — nK iterates innermost/sequentially per (bh, q).

Two kernels live here:

* ``flash_attention`` — the dense kernel.  Fully-masked k-blocks under
  ``causal``/``window`` are pruned: the accumulate body runs under
  ``pl.when(valid)`` where ``valid`` is the block-level mask-coverage
  predicate, so a causal lower-triangle visit costs ~half the blocks and a
  sliding window costs O(window) blocks per q-row instead of O(Sk).
  Init (ki == 0) and finish (ki == n_k - 1) stay unconditional so
  fully-masked q-rows still produce the zeros the oracle produces.

* ``flash_attention_lazy`` — the plan-aware kernel (DESIGN.md §Kernels).
  A scalar-prefetched skip row (one int32 per batch example) gates the
  whole grid body: when the example's plan bit says reuse, every q/k/v
  index map collapses to block (0, 0, 0) (nothing new is streamed in) and
  the only work is a single copy-through of the cached output tile at the
  last k-step — a skipped layer costs O(1) tiles instead of O(Sq·Sk).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _block_valid(qi, ki, *, causal: bool, window: int, block_q: int,
                 block_k: int):
    """Block-level mask coverage: False iff every (qpos, kpos) pair in the
    (qi, ki) tile is masked out, in which case the tile contributes exactly
    nothing to the online softmax and can be skipped whole.  Returns a
    traced bool, or the static True when no mask prunes anything."""
    valid = True
    if causal:
        # any kpos <= qpos  <=>  first kpos <= last qpos
        valid = ki * block_k <= qi * block_q + block_q - 1
    if window:
        # any kpos > qpos - window  <=>  last kpos > first qpos - window
        w_ok = ki * block_k + block_k - 1 > qi * block_q - window
        valid = w_ok if valid is True else valid & w_ok
    return valid


def _accumulate(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *, qi, ki,
                causal: bool, window: int, softcap: float, sm_scale: float,
                block_q: int, block_k: int, seq_k: int):
    """One online-softmax step over the (qi, ki) tile."""
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    s = q @ k.T                                          # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, softcap: float, sm_scale: float,
                  block_q: int, block_k: int, n_k: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        _accumulate(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, qi=qi, ki=ki,
                    causal=causal, window=window, softcap=softcap,
                    sm_scale=sm_scale, block_q=block_q, block_k=block_k,
                    seq_k=seq_k)

    valid = _block_valid(qi, ki, causal=causal, window=window,
                         block_q=block_q, block_k=block_k)
    if valid is True:
        _body()
    else:
        pl.when(valid)(_body)

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, interpret: Optional[bool] = None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """q: (B, H, Sq, d); k/v: (B, H, Sk, d) (kv heads pre-repeated for GQA).
    Returns (B, H, Sq, d).  ``interpret=None`` auto-detects the backend
    (interpret on CPU, compiled Mosaic on TPU — ``backend.resolve_interpret``)."""
    interpret = resolve_interpret(interpret)
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    qf = q.reshape(B * H, nq * block_q, d)
    kf = k.reshape(B * H, nk * block_k, d)
    vf = v.reshape(B * H, nk * block_k, d)

    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, softcap=softcap,
        sm_scale=d ** -0.5, block_q=block_q, block_k=block_k, n_k=nk,
        seq_k=Sk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, nq * block_q, d)[:, :, :Sq]


def _flash_lazy_kernel(skip_ref, q_ref, k_ref, v_ref, c_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, causal: bool, window: int,
                       softcap: float, sm_scale: float, block_q: int,
                       block_k: int, n_k: int, seq_k: int, n_heads: int):
    """Plan-aware flash body.  ``skip_ref`` is the scalar-prefetched (B,)
    int32 plan row: nonzero means this example's layer output is served from
    cache.  The contract with the index maps below: when skip is set, the
    q/k/v maps all collapse to block (0, 0, 0) and the cached map points at
    the real tile, so the ONLY memory this grid step touches is one cached
    output tile, copied through at the final k-step."""
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    skip = skip_ref[bh // n_heads] != 0
    compute = jnp.logical_not(skip)

    @pl.when(compute & (ki == 0))
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = _block_valid(qi, ki, causal=causal, window=window,
                         block_q=block_q, block_k=block_k)
    run = compute if valid is True else compute & valid

    @pl.when(run)
    def _body():
        _accumulate(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, qi=qi, ki=ki,
                    causal=causal, window=window, softcap=softcap,
                    sm_scale=sm_scale, block_q=block_q, block_k=block_k,
                    seq_k=seq_k)

    @pl.when(compute & (ki == n_k - 1))
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)

    @pl.when(skip & (ki == n_k - 1))
    def _serve():
        o_ref[0] = c_ref[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret", "block_q", "block_k"))
def flash_attention_lazy(q, k, v, cached, skip, *, causal: bool = False,
                         window: int = 0, softcap: float = 0.0,
                         interpret: Optional[bool] = None,
                         block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """Plan-aware flash attention.

    q: (B, H, Sq, d); k/v: (B, H, Sk, d); cached: (B, H, Sq, d) — the
    layer's cached attention output from the previous diffusion step;
    skip: (B,) bool/int — the plan bit per batch example.  Where skip is
    set the cached tile is served bit-exactly; elsewhere fresh attention
    is computed.  Returns (B, H, Sq, d)."""
    interpret = resolve_interpret(interpret)
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        cached = jnp.pad(cached, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    qf = q.reshape(B * H, nq * block_q, d)
    kf = k.reshape(B * H, nk * block_k, d)
    vf = v.reshape(B * H, nk * block_k, d)
    cf = cached.reshape(B * H, nq * block_q, d)
    skip_i32 = skip.astype(jnp.int32).reshape(B)

    def _bit(s_ref, bh):
        return s_ref[bh // H] != 0

    # Index-map contract: skipped examples stream in nothing but the cached
    # tile; fresh examples never touch the cache operand.
    def qmap(bh, qi, ki, s_ref):
        s = _bit(s_ref, bh)
        return (jnp.where(s, 0, bh), jnp.where(s, 0, qi), 0)

    def kvmap(bh, qi, ki, s_ref):
        s = _bit(s_ref, bh)
        return (jnp.where(s, 0, bh), jnp.where(s, 0, ki), 0)

    def cmap(bh, qi, ki, s_ref):
        s = _bit(s_ref, bh)
        return (jnp.where(s, bh, 0), jnp.where(s, qi, 0), 0)

    kern = functools.partial(
        _flash_lazy_kernel, causal=causal, window=window, softcap=softcap,
        sm_scale=d ** -0.5, block_q=block_q, block_k=block_k, n_k=nk,
        seq_k=Sk, n_heads=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), qmap),
            pl.BlockSpec((1, block_k, d), kvmap),
            pl.BlockSpec((1, block_k, d), kvmap),
            pl.BlockSpec((1, block_q, d), cmap),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki, s_ref: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, nq * block_q, d), q.dtype),
        interpret=interpret,
    )(skip_i32, qf, kf, vf, cf)
    return out.reshape(B, H, nq * block_q, d)[:, :, :Sq]
