"""Blocked flash attention (TPU Pallas) with causal, sliding-window and
logit-softcap support — the prefill hot-spot.

TPU adaptation (DESIGN.md §3): tiles are MXU-aligned (multiples of 128 on
the contracting dims), the working set per grid step is
(BLOCK_Q + 2·BLOCK_K) × head_dim + BLOCK_Q × BLOCK_K floats in VMEM, and the
online-softmax running stats (m, l, acc) live in VMEM scratch that persists
across the sequential trailing grid dimension (k-blocks).

Grid: (B·H, nQ, nK) — nK iterates innermost/sequentially per (bh, q).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, softcap: float, sm_scale: float,
                  block_q: int, block_k: int, n_k: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    s = q @ k.T                                          # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, interpret: bool = True,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """q: (B, H, Sq, d); k/v: (B, H, Sk, d) (kv heads pre-repeated for GQA).
    Returns (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    qf = q.reshape(B * H, nq * block_q, d)
    kf = k.reshape(B * H, nk * block_k, d)
    vf = v.reshape(B * H, nk * block_k, d)

    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, softcap=softcap,
        sm_scale=d ** -0.5, block_q=block_q, block_k=block_k, n_k=nk,
        seq_k=Sk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, nq * block_q, d)[:, :, :Sq]
