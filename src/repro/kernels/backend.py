"""Kernel-backend switch: ``xla`` (the bit-exactness baseline) vs ``pallas``
(skip-aware kernels — laziness realized at the memory level).

One process-wide selector, mirrored by the ``--kernels pallas|xla`` CLI flag
(launch/serve.py, launch/obs.py) and the ``REPRO_KERNELS`` env var.  The
default is ``xla``: every executor keeps the where-select semantics that the
bit-exactness contracts (fused-vs-host parity, mesh parity, serve digests)
were pinned against.  Selecting ``pallas`` routes the hot paths through the
skip-aware kernels (DESIGN.md §Kernels):

  * plan-mode module skips early-exit via ``lax.cond`` / the plan-aware
    flash-attention kernel instead of computing both select branches;
  * masked mode fuses gate-score + threshold + select into one pass;
  * the DDIM update (eps -> x_{t-1} + eta-noise) runs as one fused
    read-modify-write.

The two backends are numerically equivalent but NOT bit-identical to each
other (different fusion boundaries); each backend is internally bit-exact
between the fused and host-loop executors, because both trace the same
``trajectory_step`` graph.  The sampler trace cache keys on the backend
(sampling/trajectory._sampler_cache_key), so flipping it never serves a
stale executable.

``resolve_interpret`` is the one place interpret-mode defaulting lives:
Pallas kernels interpret on hosts with no Mosaic lowering (CPU) and compile
everywhere else, with ``REPRO_PALLAS_INTERPRET=0|1`` as the override for
tests and TPU-sim debugging.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Optional

import jax

BACKENDS = ("xla", "pallas")

_state = {"backend": None}          # lazily seeded from the env


def _from_env() -> str:
    name = os.environ.get("REPRO_KERNELS", "xla").strip().lower() or "xla"
    if name not in BACKENDS:
        raise ValueError(
            f"REPRO_KERNELS={name!r}: unknown kernel backend; "
            f"expected one of {BACKENDS}")
    return name


def get_backend() -> str:
    """The active kernel backend: 'xla' (default) or 'pallas'."""
    if _state["backend"] is None:
        _state["backend"] = _from_env()
    return _state["backend"]


def set_backend(name: str) -> str:
    """Select the kernel backend process-wide.  Returns the previous one."""
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"expected one of {BACKENDS}")
    prev = get_backend()
    _state["backend"] = name
    return prev


@contextmanager
def use_backend(name: str):
    """Scoped backend selection (tests, benches):

        with backend.use_backend("pallas"):
            ...
    """
    prev = set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Interpret-mode default for Pallas kernels.

    ``None`` (the production default) auto-detects: interpret on backends
    with no Mosaic lowering (``jax.default_backend() == 'cpu'``), compiled
    Mosaic on TPU/GPU.  ``REPRO_PALLAS_INTERPRET=0|1`` overrides the
    auto-detection (tests that must pin one mode); an explicit bool arg
    beats both."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"
