"""Single-compile fused DDIM trajectory executor.

The paper's speedup claim is about per-step compute skipped across the
denoising trajectory — but a host-side Python loop that bakes each policy
plan row in as a *static* jit argument pays up to one XLA compilation per
distinct row plus per-step dispatch/sync, exactly the overhead regime
where lazy skipping stops mattering.  Schedule-based policies
(smoothcache, static_router, stride, plan) produce the whole (T, L, 2)
skip plan up front, which is precisely the shape ``lax.scan`` wants as a
scanned input: this module compiles the ENTIRE sampling loop as one
``jax.lax.scan`` over steps.

Carry layout (DESIGN.md §Trajectory):
  (z, lazy_cache, policy_state, noise_keys, n_skipped)
    z            — (B, H, W, C) DDIM latent
    lazy_cache   — {"attn": (L, B', N, D), "ffn": ...} previous-step module
                   outputs (B' doubled under CFG); None when exec_mode 'off'
    policy_state — the policy's traced pytree state
                   (CachePolicy.init_traced_state / update_traced_state)
    noise_keys   — (B, 2) per-example keys, split every step inside
                   ddim.trajectory_step for eta > 0 stochastic DDIM; None
                   at eta = 0 (deterministic DDIM draws no per-step noise)
    n_skipped    — realized skipped-module-call counter (scalar f32)
    telemetry    — optional repro.obs counter pytree (per-(step, layer,
                   module) executed/skipped/gate/drift, (T, L, 2) f32
                   each); None — zero pytree leaves — when telemetry is
                   off, keeping the traced program identical

Scanned inputs: (t, t_prev, step_index, plan_row) — plan rows are a
(T, L, 2) bool DEVICE array (CachePolicy.device_plan) consumed via
where-selects (core.lazy.select_cached), so changing the schedule never
retraces; the first sampling step is handled by a traced ``fresh`` flag
instead of a static ``first_step`` branch.

Under an active ``dist.ctx.mesh(data=N)`` context the whole-trajectory
scan is jitted with ``in_shardings``/``out_shardings`` derived from
``dist/sharding.trajectory_shardings``: latents, labels, per-example
noise keys, the lazy-cache carry and every layer activation shard along
the batch ("data") axis, while the plan array, schedule tables and the
policy's traced state stay replicated — plan rows are batch-invariant,
so every policy runs unchanged and per-example bit-exact on any mesh
size (tests/test_trajectory_sharded.py).  CFG pairs are kept shard-local
(interleaved batch, see ddim.trajectory_step), so guidance adds no
resharding; the one caveat on CPU is that each shard must keep >= 2
forward rows (CFG pairs count) — a single-example shard takes XLA's
degenerate-dim GEMM path, which rounds ~1 ulp differently.

The result is bit-exact with the host-loop reference
(sampling/ddim.ddim_sample_reference) for every registered policy, at
exactly ONE compile per (config, policy, horizon, guidance, eta, mesh) —
tests/test_trajectory.py.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.dist import ctx
from repro.dist import sharding as sharding_lib
from repro.kernels import backend as kernel_backend
from repro.models import dit as dit_lib
from repro.obs import telemetry as obs_telemetry
from repro.sampling import ddim

Array = jax.Array

N_MODULES = 2          # plan columns: 0 = attention, 1 = ffn


def timestep_arrays(n_train: int, n_steps: int) -> Tuple[Array, Array]:
    """(t, t_prev) int32 device arrays for the scan's per-step inputs.

    Passed into the jitted sampler as TRACED arguments, never baked in as
    closure constants — constant timesteps would let XLA constant-fold
    the sinusoidal embedding at compile time, and the compile-time
    evaluator's cos/sin round differently than the runtime kernels (a
    1-ulp break of the bit-exactness contract vs the host reference,
    whose per-step jit always receives t as a traced scalar)."""
    ts_np = ddim.sampling_timesteps(n_train, n_steps)
    ts = jnp.asarray(ts_np, jnp.int32)
    ts_prev = jnp.asarray(np.concatenate([ts_np[1:], [-1]]), jnp.int32)
    return ts, ts_prev


_SAMPLER_CACHE: Dict[tuple, object] = {}


def _sampler_cache_key(cfg: ModelConfig, pol, n_steps: int,
                       cfg_scale: float, eta: float,
                       batch: Optional[int],
                       telemetry: bool) -> tuple:
    """What the TRACE actually depends on.  Keying on the policy instance
    would defeat the compile-once contract: resolve() builds a fresh
    policy object per ddim_sample call for legacy/lazy-mode/string args,
    so every call would recompile the whole trajectory.  Two policies of
    the same class, exec mode and threshold trace identically — the
    schedule itself is a traced input (device_plan), never part of the
    trace.  The mesh (axis sizes + device assignment) and — under a mesh
    only — the global batch join the key: in/out shardings are baked into
    the jit wrapper, and a batch-sharded executable is only valid for the
    batch it was built for.  ``telemetry`` joins it too: the telemetry
    carry (repro.obs) changes the traced program, so on/off each own a
    separate executable and toggling observability never retraces the
    other's.  The kernel backend (repro.kernels.backend) joins the key
    last: 'pallas' traces cond-hoisted skips and fused kernels into the
    scan body, so flipping ``--kernels`` must never serve the other
    backend's executable."""
    mesh_key = ctx.mesh_cache_key()
    return (cfg, type(pol), pol.exec_mode,
            float(getattr(pol, "threshold", 0.5)),
            int(n_steps), float(cfg_scale), float(eta),
            mesh_key, int(batch) if mesh_key and batch else None,
            bool(telemetry), kernel_backend.get_backend())


def build_sampler(cfg: ModelConfig, policy, n_steps: int, cfg_scale: float,
                  eta: float = 0.0, *, batch: Optional[int] = None,
                  telemetry: bool = False):
    """One jitted whole-trajectory sampler per (config, policy-shape,
    horizon, guidance scale, eta, mesh) — policy-shape meaning (class,
    exec_mode, threshold), see _sampler_cache_key.

    Returns ``sample(params, sched, ts, ts_prev, z0, keys, labels, plan,
    state0) -> (z, aux)`` where ``(ts, ts_prev)`` come from
    ``timestep_arrays``, ``z0`` is the initial latent (generated HOST-side
    by the caller, exactly like the reference loop — inlining the RNG
    into the trace lets XLA fuse it with the first step's math and break
    bit-parity), ``keys`` is the (B, 2) per-example noise-key array for
    eta > 0 (``ddim.per_example_keys``; any key at eta = 0, unused),
    ``plan`` is the policy's (n_steps, L, 2) bool device array (None for
    non-plan modes) and ``state0`` the traced policy state.  Timesteps,
    plan and state are *inputs*, not closure constants: different
    schedules of the same shape reuse the one compiled executable (the
    compile-once contract the trace-cache probe in tests/test_trajectory.py
    asserts).

    Under an active ``dist.ctx`` mesh the jit carries
    ``in_shardings``/``out_shardings`` from
    ``dist/sharding.trajectory_shardings`` (``batch`` sizes the specs) and
    the traced body runs inside the activation-sharding context, so the
    scan carry — latent, lazy cache, per-example keys — stays pinned to
    the batch axis across all n_steps iterations.

    ``telemetry=True`` (repro.obs) threads the per-(step, layer, module)
    counter pytree through the scan carry — executed/skipped fractions,
    gate-score summaries and cached-vs-fresh drift against the lazy cache
    — surfaced as ``aux["telemetry"]`` and drained by the caller in one
    device->host sync.  An exec_mode-'off' policy gets a lazy cache
    threaded anyway (mode 'off' never READS it, so the latent math is
    unchanged) purely so consecutive-step drift is measurable for the
    `none` baseline.  With telemetry off the carry entry is None — zero
    pytree leaves, identical jaxpr/HLO to a telemetry-free build.
    """
    key = _sampler_cache_key(cfg, policy, n_steps, cfg_scale, eta, batch,
                             telemetry)
    cached = _SAMPLER_CACHE.get(key)
    if cached is not None:
        return cached

    pol = policy
    mode = pol.exec_mode
    use_cfg = cfg_scale != 1.0
    lazy = mode != "off"
    threshold = getattr(pol, "threshold", 0.5)
    mesh = ctx.current_mesh()

    def sample(params, sched, ts, ts_prev, z0, keys, labels, plan, state0):
        shard_ctx = (ctx.activation_sharding(mesh) if mesh is not None
                     else nullcontext())
        with shard_ctx:
            return _sample(params, sched, ts, ts_prev, z0, keys, labels,
                           plan, state0)

    def _sample(params, sched, ts, ts_prev, z0, keys, labels, plan, state0):
        B = labels.shape[0]
        BB = 2 * B if use_cfg else B
        z = ctx.constrain(z0, "batch")
        lazy_cache = None
        # telemetry threads a cache even at exec_mode 'off': mode 'off'
        # never reads it (the latent math is untouched) but its next value
        # is the step's fresh module outputs, so consecutive-step drift is
        # measurable for the `none` baseline too
        if lazy or telemetry:
            lazy_cache = jax.tree.map(
                lambda a: ctx.constrain(a, None, "batch"),
                dit_lib.init_dit_lazy_cache(cfg, BB))
        steps = jnp.arange(n_steps, dtype=jnp.int32)
        noise_keys = keys if eta > 0.0 else None
        tele0 = (obs_telemetry.init_trajectory_telemetry(
            n_steps, cfg.n_layers, N_MODULES) if telemetry else None)

        def body(carry, xs):
            z, lzc, pstate, nkeys, n_skipped, tele = carry
            t, t_prev, step, row = xs
            first = step == 0
            z, new_lzc, scores, nkeys = ddim.trajectory_step(
                params, cfg, sched, pol, cfg_scale, z, labels, t, t_prev,
                step, lzc, row, eta=eta, noise_keys=nkeys)

            sc = None
            if scores and mode in ("masked", "soft"):
                # policy state carries the same layer-mean statistic the
                # host loop feeds update_state...
                sc = jnp.stack([scores["attn"].mean(-1),
                                scores["ffn"].mean(-1)], axis=-1)   # (L, 2)
                # ...but the skip accounting mirrors the ACTUAL select:
                # lazy_execute thresholds per SAMPLE, so count the
                # batch-mean fraction of per-sample skips per module call
                # (thresholding the batch-mean score would miss modules
                # where scores straddle the threshold)
                per_sample = jnp.stack([scores["attn"], scores["ffn"]],
                                       axis=-1) > threshold      # (L, B', 2)
                n_skipped = n_skipped + jnp.where(
                    first, 0.0,
                    jnp.sum(per_sample.astype(jnp.float32).mean(axis=1)))
            elif row is not None:
                n_skipped = n_skipped + jnp.where(
                    first, 0.0, jnp.sum(row.astype(jnp.float32)))
            pstate = pol.update_traced_state(pstate, scores=sc, plan_row=row)
            tele = obs_telemetry.trajectory_step_update(
                tele, step, first=first, mode=mode, threshold=threshold,
                row=row, scores=scores, old_cache=lzc, new_cache=new_lzc)
            return (z, new_lzc, pstate, nkeys, n_skipped, tele), None

        carry0 = (z, lazy_cache, state0, noise_keys,
                  jnp.zeros((), jnp.float32), tele0)
        (z, _, pstate, _, n_skipped, tele), _ = jax.lax.scan(
            body, carry0, (ts, ts_prev, steps, plan))
        aux = {"policy_state": pstate, "n_skipped": n_skipped}
        if tele is not None:
            aux["telemetry"] = tele
        return z, aux

    if mesh is not None:
        if batch is None:
            raise ValueError("build_sampler under a dist.ctx mesh needs "
                             "batch= to derive in/out shardings")
        in_sh, out_sh = sharding_lib.trajectory_shardings(
            mesh, batch, per_example_keys=eta > 0.0)
        fn = jax.jit(sample, in_shardings=in_sh, out_shardings=out_sh)
    else:
        fn = jax.jit(sample)

    _SAMPLER_CACHE[key] = fn
    return fn


build_sampler.cache_clear = _SAMPLER_CACHE.clear    # test/bench hook


def prepare_inputs(cfg: ModelConfig, sched: ddim.DiffusionSchedule, pol, *,
                   key, labels: Array, n_steps: int,
                   eta: float = 0.0) -> tuple:
    """The fused sampler's argument tuple after ``params``:
    ``(sched, ts, ts_prev, z0, keys, labels, plan, state0)``.

    Shared by ``sample_trajectory``, the dry-run lowering path and the
    mesh-scaling bench so they feed the jitted sampler identically.  The
    initial latent is generated host-side (eager, device 0) so its bits
    never depend on the mesh, exactly like the reference loop."""
    ts, ts_prev = timestep_arrays(sched.n_train_steps, n_steps)
    z0 = jax.random.normal(key, (labels.shape[0], cfg.dit_input_size,
                                 cfg.dit_input_size, cfg.dit_in_channels),
                           jnp.float32)
    keys = (ddim.per_example_keys(key, labels.shape[0]) if eta > 0.0
            else key)
    plan_arr = (pol.device_plan(n_steps, cfg.n_layers, N_MODULES)
                if pol.exec_mode == "plan" else None)
    state0 = pol.init_traced_state(n_steps=n_steps, n_layers=cfg.n_layers,
                                   n_modules=N_MODULES)
    return (sched, ts, ts_prev, z0, keys, labels, plan_arr, state0)


def sample_trajectory(params: dict, cfg: ModelConfig,
                      sched: ddim.DiffusionSchedule, *,
                      key, labels: Array, n_steps: int,
                      cfg_scale: float = 1.5,
                      eta: float = 0.0,
                      lazy_mode: str = "off",
                      plan: Optional[np.ndarray] = None,
                      policy=None,
                      telemetry: bool = False) -> Tuple[Array, Dict]:
    """Fused DDIM sampling: the whole trajectory in one compiled scan.

    Same contract as sampling/ddim.ddim_sample (which routes here unless
    a debug collector forces the host loop): CFG doubles the batch, every
    skip/reuse decision goes through one cache policy, and the output is
    bit-exact with the host-loop reference.  ``eta`` > 0 draws per-step
    per-example DDIM noise from the reserved keys in the carry.  Under an
    active ``dist.ctx.mesh`` the batch shards along the data axis with
    per-example outputs bit-exact vs the single-device run.

    Returns (samples (B, H, W, C), aux) with
      aux["policy_state"]        — the policy's final traced state pytree
      aux["realized_skip_ratio"] — skipped gated-module calls / total
                                   (plan rows for static policies, probe
                                   thresholding for lazy_gate).
      aux["telemetry"]           — only with ``telemetry=True``: the
                                   drained (numpy) per-(step, layer,
                                   module) counter pytree (repro.obs).
    """
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    fn = build_sampler(cfg, pol, int(n_steps), float(cfg_scale),
                       float(eta), batch=int(labels.shape[0]),
                       telemetry=telemetry)
    args = prepare_inputs(cfg, sched, pol, key=key, labels=labels,
                          n_steps=n_steps, eta=eta)
    z, aux = fn(params, *args)
    gated = max(n_steps * cfg.n_layers * N_MODULES, 1)
    out = {"policy_state": aux["policy_state"],
           "realized_skip_ratio": float(aux["n_skipped"]) / gated}
    if "telemetry" in aux:
        out["telemetry"] = obs_telemetry.drain(aux["telemetry"])
    return z, out
