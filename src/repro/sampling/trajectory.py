"""Single-compile fused DDIM trajectory executor.

The paper's speedup claim is about per-step compute skipped across the
denoising trajectory — but a host-side Python loop that bakes each policy
plan row in as a *static* jit argument pays up to one XLA compilation per
distinct row plus per-step dispatch/sync, exactly the overhead regime
where lazy skipping stops mattering.  Schedule-based policies
(smoothcache, static_router, stride, plan) produce the whole (T, L, 2)
skip plan up front, which is precisely the shape ``lax.scan`` wants as a
scanned input: this module compiles the ENTIRE sampling loop as one
``jax.lax.scan`` over steps.

Carry layout (DESIGN.md §Trajectory):
  (z, lazy_cache, policy_state, rng_key, n_skipped)
    z            — (B, H, W, C) DDIM latent
    lazy_cache   — {"attn": (L, B', N, D), "ffn": ...} previous-step module
                   outputs (B' doubled under CFG); None when exec_mode 'off'
    policy_state — the policy's traced pytree state
                   (CachePolicy.init_traced_state / update_traced_state)
    rng_key      — split every step; reserved for eta > 0 samplers (eta = 0
                   DDIM draws no per-step noise)
    n_skipped    — realized skipped-module-call counter (scalar f32)

Scanned inputs: (t, t_prev, step_index, plan_row) — plan rows are a
(T, L, 2) bool DEVICE array (CachePolicy.device_plan) consumed via
where-selects (core.lazy.select_cached), so changing the schedule never
retraces; the first sampling step is handled by a traced ``fresh`` flag
instead of a static ``first_step`` branch.

The result is bit-exact with the host-loop reference
(sampling/ddim.ddim_sample_reference) for every registered policy, at
exactly ONE compile per (config, policy, horizon, guidance) —
tests/test_trajectory.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.models import dit as dit_lib
from repro.sampling import ddim

Array = jax.Array

N_MODULES = 2          # plan columns: 0 = attention, 1 = ffn


def timestep_arrays(n_train: int, n_steps: int) -> Tuple[Array, Array]:
    """(t, t_prev) int32 device arrays for the scan's per-step inputs.

    Passed into the jitted sampler as TRACED arguments, never baked in as
    closure constants — constant timesteps would let XLA constant-fold
    the sinusoidal embedding at compile time, and the compile-time
    evaluator's cos/sin round differently than the runtime kernels (a
    1-ulp break of the bit-exactness contract vs the host reference,
    whose per-step jit always receives t as a traced scalar)."""
    ts_np = ddim.sampling_timesteps(n_train, n_steps)
    ts = jnp.asarray(ts_np, jnp.int32)
    ts_prev = jnp.asarray(np.concatenate([ts_np[1:], [-1]]), jnp.int32)
    return ts, ts_prev


_SAMPLER_CACHE: Dict[tuple, object] = {}


def _sampler_cache_key(cfg: ModelConfig, pol, n_steps: int,
                       cfg_scale: float) -> tuple:
    """What the TRACE actually depends on.  Keying on the policy instance
    would defeat the compile-once contract: resolve() builds a fresh
    policy object per ddim_sample call for legacy/lazy-mode/string args,
    so every call would recompile the whole trajectory.  Two policies of
    the same class, exec mode and threshold trace identically — the
    schedule itself is a traced input (device_plan), never part of the
    trace."""
    return (cfg, type(pol), pol.exec_mode,
            float(getattr(pol, "threshold", 0.5)),
            int(n_steps), float(cfg_scale))


def build_sampler(cfg: ModelConfig, policy, n_steps: int, cfg_scale: float):
    """One jitted whole-trajectory sampler per (config, policy-shape,
    horizon, guidance scale) — policy-shape meaning (class, exec_mode,
    threshold), see _sampler_cache_key.

    Returns ``sample(params, sched, ts, ts_prev, z0, key, labels, plan,
    state0) -> (z, aux)`` where ``(ts, ts_prev)`` come from
    ``timestep_arrays``, ``z0`` is the initial latent (generated HOST-side
    by the caller, exactly like the reference loop — inlining the RNG
    into the trace lets XLA fuse it with the first step's math and break
    bit-parity), ``plan`` is the policy's (n_steps, L, 2) bool device
    array (None for non-plan modes) and ``state0`` the traced policy
    state.  Timesteps, plan and state are *inputs*, not closure
    constants: different schedules of the same shape reuse the one
    compiled executable (the compile-once contract the trace-cache probe
    in tests/test_trajectory.py asserts).
    """
    key = _sampler_cache_key(cfg, policy, n_steps, cfg_scale)
    cached = _SAMPLER_CACHE.get(key)
    if cached is not None:
        return cached

    pol = policy
    mode = pol.exec_mode
    use_cfg = cfg_scale != 1.0
    lazy = mode != "off"
    threshold = getattr(pol, "threshold", 0.5)

    @jax.jit
    def sample(params, sched, ts, ts_prev, z0, key, labels, plan, state0):
        B = labels.shape[0]
        BB = 2 * B if use_cfg else B
        z = z0
        lazy_cache = dit_lib.init_dit_lazy_cache(cfg, BB) if lazy else None
        steps = jnp.arange(n_steps, dtype=jnp.int32)

        def body(carry, xs):
            z, lzc, pstate, key, n_skipped = carry
            t, t_prev, step, row = xs
            key, _noise_key = jax.random.split(key)      # eta > 0 reserve
            first = step == 0
            z, new_lzc, scores = ddim.trajectory_step(
                params, cfg, sched, pol, cfg_scale, z, labels, t, t_prev,
                step, lzc, row)

            sc = None
            if scores and mode in ("masked", "soft"):
                # policy state carries the same layer-mean statistic the
                # host loop feeds update_state...
                sc = jnp.stack([scores["attn"].mean(-1),
                                scores["ffn"].mean(-1)], axis=-1)   # (L, 2)
                # ...but the skip accounting mirrors the ACTUAL select:
                # lazy_execute thresholds per SAMPLE, so count the
                # batch-mean fraction of per-sample skips per module call
                # (thresholding the batch-mean score would miss modules
                # where scores straddle the threshold)
                per_sample = jnp.stack([scores["attn"], scores["ffn"]],
                                       axis=-1) > threshold      # (L, B', 2)
                n_skipped = n_skipped + jnp.where(
                    first, 0.0,
                    jnp.sum(per_sample.astype(jnp.float32).mean(axis=1)))
            elif row is not None:
                n_skipped = n_skipped + jnp.where(
                    first, 0.0, jnp.sum(row.astype(jnp.float32)))
            pstate = pol.update_traced_state(pstate, scores=sc, plan_row=row)
            return (z, new_lzc, pstate, key, n_skipped), None

        carry0 = (z, lazy_cache, state0, key, jnp.zeros((), jnp.float32))
        (z, _, pstate, _, n_skipped), _ = jax.lax.scan(
            body, carry0, (ts, ts_prev, steps, plan))
        return z, {"policy_state": pstate, "n_skipped": n_skipped}

    _SAMPLER_CACHE[key] = sample
    return sample


build_sampler.cache_clear = _SAMPLER_CACHE.clear    # test/bench hook


def sample_trajectory(params: dict, cfg: ModelConfig,
                      sched: ddim.DiffusionSchedule, *,
                      key, labels: Array, n_steps: int,
                      cfg_scale: float = 1.5,
                      lazy_mode: str = "off",
                      plan: Optional[np.ndarray] = None,
                      policy=None) -> Tuple[Array, Dict]:
    """Fused DDIM sampling: the whole trajectory in one compiled scan.

    Same contract as sampling/ddim.ddim_sample (which routes here unless
    a debug collector forces the host loop): CFG doubles the batch, every
    skip/reuse decision goes through one cache policy, and the output is
    bit-exact with the host-loop reference.

    Returns (samples (B, H, W, C), aux) with
      aux["policy_state"]        — the policy's final traced state pytree
      aux["realized_skip_ratio"] — skipped gated-module calls / total
                                   (plan rows for static policies, probe
                                   thresholding for lazy_gate).
    """
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    fn = build_sampler(cfg, pol, int(n_steps), float(cfg_scale))
    ts, ts_prev = timestep_arrays(sched.n_train_steps, n_steps)
    z0 = jax.random.normal(key, (labels.shape[0], cfg.dit_input_size,
                                 cfg.dit_input_size, cfg.dit_in_channels),
                           jnp.float32)
    plan_arr = (pol.device_plan(n_steps, cfg.n_layers, N_MODULES)
                if pol.exec_mode == "plan" else None)
    state0 = pol.init_traced_state(n_steps=n_steps, n_layers=cfg.n_layers,
                                   n_modules=N_MODULES)
    z, aux = fn(params, sched, ts, ts_prev, z0, key, labels, plan_arr,
                state0)
    gated = max(n_steps * cfg.n_layers * N_MODULES, 1)
    return z, {"policy_state": aux["policy_state"],
               "realized_skip_ratio": float(aux["n_skipped"]) / gated}
