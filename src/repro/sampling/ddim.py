"""DDIM sampler (Song et al. 2020) with classifier-free guidance and
LazyDiT cache threading across denoising steps."""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.models import dit as dit_lib

Array = jax.Array


class DiffusionSchedule(NamedTuple):
    betas: Array                 # (T,)
    alphas_cumprod: Array        # (T,)

    @property
    def n_train_steps(self) -> int:
        return self.betas.shape[0]


def linear_schedule(n_steps: int = 1000, beta_start: float = 1e-4,
                    beta_end: float = 0.02) -> DiffusionSchedule:
    betas = jnp.linspace(beta_start, beta_end, n_steps, dtype=jnp.float32)
    return DiffusionSchedule(betas, jnp.cumprod(1.0 - betas))


def sampling_timesteps(n_train: int, n_sample: int) -> np.ndarray:
    """DDIM timestep subset, descending (e.g. 1000 train -> 50 sample)."""
    step = n_train // n_sample
    ts = (np.arange(0, n_sample) * step + 1).clip(0, n_train - 1)
    return ts[::-1].copy()


def q_sample(sched: DiffusionSchedule, x0: Array, t: Array, noise: Array) -> Array:
    """Forward diffusion: z_t = sqrt(a_t) x0 + sqrt(1-a_t) eps."""
    a = sched.alphas_cumprod[t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(a).reshape(shape) * x0
            + jnp.sqrt(1.0 - a).reshape(shape) * noise)


def ddim_step(sched: DiffusionSchedule, z_t: Array, eps: Array,
              t: Array, t_prev: Array) -> Array:
    """z_{t'} = sqrt(a_{t'}) * (z_t - sqrt(1-a_t) eps)/sqrt(a_t)
              + sqrt(1-a_{t'}) * eps   (eta = 0)."""
    a_t = sched.alphas_cumprod[t]
    a_p = jnp.where(t_prev >= 0, sched.alphas_cumprod[jnp.maximum(t_prev, 0)], 1.0)
    shape = (-1,) + (1,) * (z_t.ndim - 1)
    a_t, a_p = a_t.reshape(shape), a_p.reshape(shape)
    x0 = (z_t - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps


def cfg_eps(eps_cond: Array, eps_uncond: Array, w: float) -> Array:
    """Paper Eq.: hat_eps = w*eps_cond - (w-1)*eps_uncond."""
    return w * eps_cond - (w - 1.0) * eps_uncond


def ddim_sample(params: dict, cfg: ModelConfig, sched: DiffusionSchedule, *,
                key, labels: Array, n_steps: int, cfg_scale: float = 1.5,
                lazy_mode: str = "off",
                plan: Optional[np.ndarray] = None,
                policy=None,
                collect_scores: bool = False,
                collect_traces: bool = False,
                ) -> Tuple[Array, Dict]:
    """Full DDIM sampling loop for the DiT denoiser.

    CFG doubles the batch (cond rows + null-label rows); the lazy cache is
    per batch row, so cond/uncond streams each keep their own cache —
    matching the paper's implementation.

    Every skip/reuse decision routes through one cache policy
    (repro.cache; DESIGN.md §Cache).  ``policy`` names or carries it
    directly; the legacy (``lazy_mode``, ``plan``) pair is an alias mapped
    onto a policy via repro.cache.from_legacy, so existing callers are
    unchanged.  Static policies serve per-step plan rows that are removed
    from the compiled HLO; dynamic policies (lazy_gate) decide in traced
    code.

    Returns (samples (B,H,W,C), aux) where aux may contain per-step probe
    scores and/or module output traces (for the similarity benchmarks).
    """
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    lazy_mode = pol.exec_mode
    pstate = pol.init_state(n_steps=n_steps, n_layers=cfg.n_layers,
                            n_modules=2)

    B = labels.shape[0]
    H = cfg.dit_input_size
    C = cfg.dit_in_channels
    z = jax.random.normal(key, (B, H, H, C), jnp.float32)
    ts = sampling_timesteps(sched.n_train_steps, n_steps)

    use_cfg = cfg_scale != 1.0
    if use_cfg:
        y_all = jnp.concatenate([labels, jnp.full_like(labels, cfg.dit_n_classes)])
    else:
        y_all = labels

    lazy_cache = None
    if lazy_mode != "off":
        lazy_cache = dit_lib.init_dit_lazy_cache(cfg, 2 * B if use_cfg else B)

    @functools.partial(jax.jit, static_argnames=("plan_row", "first"))
    def model_eval(z, t_scalar, lazy_cache, plan_row, first):
        zz = jnp.concatenate([z, z]) if use_cfg else z
        tt = jnp.full((zz.shape[0],), t_scalar, jnp.float32)
        pr = np.asarray(plan_row) if plan_row is not None else None
        out, new_lazy, scores = dit_lib.dit_forward(
            params, cfg, zz, tt, y_all, lazy_cache=lazy_cache,
            lazy_mode=lazy_mode, plan_row=pr, first_step=first, policy=pol)
        eps_all, _ = dit_lib.split_eps(out, C)
        if use_cfg:
            e_c, e_u = jnp.split(eps_all, 2)
            eps = cfg_eps(e_c, e_u, cfg_scale)
        else:
            eps = eps_all
        return eps, new_lazy, scores

    score_log, trace_log = [], []
    for i, t in enumerate(ts):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        plan_row = None
        if lazy_mode == "plan" and i > 0:
            # hashable static arg: the row is baked into the trace, so
            # skipped modules are absent from the compiled HLO
            row = pol.plan_row(i, pstate)
            plan_row = tuple(tuple(bool(b) for b in r) for r in row)
        eps, lazy_cache, scores = model_eval(z, float(t), lazy_cache, plan_row,
                                             i == 0)
        z = ddim_step(sched, z, eps, jnp.full((B,), t), jnp.full((B,), t_prev))
        if collect_scores and scores:
            sc_np = jax.tree.map(np.asarray, scores)
            score_log.append(sc_np)
            pstate = pol.update_state(
                pstate, step=i,
                scores=np.stack([sc_np["attn"].mean(-1),
                                 sc_np["ffn"].mean(-1)], axis=-1))
        else:
            pstate = pol.update_state(pstate, step=i)
        if collect_traces and lazy_cache is not None:
            trace_log.append(jax.tree.map(np.asarray, lazy_cache))

    aux = {}
    if score_log:
        aux["scores"] = score_log
    if trace_log:
        aux["traces"] = trace_log
    return z, aux
