"""DDIM sampler (Song et al. 2020) with classifier-free guidance and
LazyDiT cache threading across denoising steps.

``ddim_sample`` is a thin dispatcher: the default execution path is the
fused single-compile trajectory executor (sampling/trajectory.py — the
whole loop is one ``lax.scan``, plan rows are scanned device arrays); the
host-side step loop survives ONLY as ``ddim_sample_reference``, reached
through the ``collect_scores``/``collect_traces`` debug flags (per-step
score/trace logging needs host access between steps) and used by
tests/test_trajectory.py as the bit-exactness oracle.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policy as cache_policy
from repro.configs.base import ModelConfig
from repro.kernels import backend as kernel_backend
from repro.kernels.ddim_update import ops as ddim_update_ops
from repro.models import dit as dit_lib

Array = jax.Array


class DiffusionSchedule(NamedTuple):
    betas: Array                 # (T,)
    alphas_cumprod: Array        # (T,)

    @property
    def n_train_steps(self) -> int:
        return self.betas.shape[0]


def linear_schedule(n_steps: int = 1000, beta_start: float = 1e-4,
                    beta_end: float = 0.02) -> DiffusionSchedule:
    betas = jnp.linspace(beta_start, beta_end, n_steps, dtype=jnp.float32)
    return DiffusionSchedule(betas, jnp.cumprod(1.0 - betas))


def sampling_timesteps(n_train: int, n_sample: int) -> np.ndarray:
    """DDIM timestep subset, descending (e.g. 1000 train -> 50 sample)."""
    step = n_train // n_sample
    ts = (np.arange(0, n_sample) * step + 1).clip(0, n_train - 1)
    return ts[::-1].copy()


def q_sample(sched: DiffusionSchedule, x0: Array, t: Array, noise: Array) -> Array:
    """Forward diffusion: z_t = sqrt(a_t) x0 + sqrt(1-a_t) eps."""
    a = sched.alphas_cumprod[t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(a).reshape(shape) * x0
            + jnp.sqrt(1.0 - a).reshape(shape) * noise)


def ddim_step(sched: DiffusionSchedule, z_t: Array, eps: Array,
              t: Array, t_prev: Array, *, eta: float = 0.0,
              noise: Optional[Array] = None) -> Array:
    """z_{t'} = sqrt(a_{t'}) * (z_t - sqrt(1-a_t) eps)/sqrt(a_t)
              + sqrt(1-a_{t'} - sigma^2) * eps + sigma * noise

    with  sigma = eta * sqrt((1-a_{t'})/(1-a_t)) * sqrt(1 - a_t/a_{t'})
    (Song et al. 2020, Eq. 16).  ``eta`` is STATIC: at eta = 0 the
    deterministic update is emitted verbatim (no dead noise ops in the
    graph — the bit-exactness contract with pre-eta samplers), and the
    final step (t_prev < 0, a_{t'} = 1) gets sigma = 0 so the emitted
    sample is never perturbed."""
    a_t = sched.alphas_cumprod[t]
    a_p = jnp.where(t_prev >= 0, sched.alphas_cumprod[jnp.maximum(t_prev, 0)], 1.0)
    if kernel_backend.get_backend() == "pallas":
        # fused update (DESIGN.md §Kernels): one read-modify-write on a
        # compiled-Pallas target; on interpret hosts the op's reference is
        # the identical expression tree below, so CPU output is unchanged
        return ddim_update_ops.ddim_update(
            z_t, eps, a_t.reshape(-1), a_p.reshape(-1), noise, eta=eta)
    shape = (-1,) + (1,) * (z_t.ndim - 1)
    a_t, a_p = a_t.reshape(shape), a_p.reshape(shape)
    x0 = (z_t - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    if eta == 0.0 or noise is None:
        return jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps
    sigma = (eta * jnp.sqrt((1 - a_p) / (1 - a_t))
             * jnp.sqrt(1 - a_t / a_p))
    dir_eps = jnp.sqrt(jnp.maximum(1 - a_p - sigma ** 2, 0.0))
    return jnp.sqrt(a_p) * x0 + dir_eps * eps + sigma * noise


def cfg_eps(eps_cond: Array, eps_uncond: Array, w: float) -> Array:
    """Paper Eq.: hat_eps = w*eps_cond - (w-1)*eps_uncond."""
    return w * eps_cond - (w - 1.0) * eps_uncond


@jax.custom_vjp
def _fusion_barrier(xs):
    """``optimization_barrier`` with a pass-through gradient.

    The primal is the barrier verbatim (identical HLO, so the
    bit-exactness contract between the executors is untouched), but the
    stock primitive has no differentiation rule — and the learned-router
    trainer (train/learned.py) backpropagates through whole unrolled
    ``trajectory_step`` chains.  The barrier only constrains *scheduling*;
    its Jacobian is the identity, so cotangents pass straight through."""
    return jax.lax.optimization_barrier(xs)


def _fusion_barrier_fwd(xs):
    return jax.lax.optimization_barrier(xs), None


def _fusion_barrier_bwd(_, g):
    return (g,)


_fusion_barrier.defvjp(_fusion_barrier_fwd, _fusion_barrier_bwd)


def per_example_keys(key, batch: int) -> Array:
    """(B, 2) uint32 key array — one fold_in-derived key per example.

    The eta > 0 noise stream is keyed per EXAMPLE, not per batch: example
    i's noise depends only on (key, i, step), so it is invariant to how
    the batch is sharded across a device mesh (each shard folds its own
    rows) and to the batch size around it — the property the
    mesh-parity tests pin (tests/test_trajectory_sharded.py)."""
    return jax.vmap(jax.random.fold_in, (None, 0))(
        key, jnp.arange(batch, dtype=jnp.uint32))


def trajectory_step(params: dict, cfg: ModelConfig, sched: DiffusionSchedule,
                    pol, cfg_scale: float, z: Array, labels: Array,
                    t: Array, t_prev: Array, step: Array,
                    lazy_cache: Optional[dict], row, *,
                    eta: float = 0.0, noise_keys: Optional[Array] = None):
    """ONE denoising step — the single implementation BOTH executors trace.

    The host-loop reference jits this directly (one dispatch per step);
    the fused executor (sampling/trajectory.py) makes it the body of a
    ``lax.scan``.  Sharing the exact subgraph — traced plan ``row``,
    traced first-step flag (``step == 0``), identical op order — is the
    precondition for the bit-exactness contract: any divergence in graph
    shape (a static-arg plan row here, a live debug output there) changes
    XLA's fusion choices and flips low bits.

    ``t``/``t_prev``/``step`` are traced int32 scalars; ``row`` is this
    step's traced (L, 2) bool plan row or None; ``lazy_cache`` is the
    previous step's module outputs (never served at ``step == 0``).
    ``eta`` is the STATIC DDIM stochasticity knob: at eta > 0 the step
    consumes ``noise_keys`` ((B, 2) per-example keys, see
    ``per_example_keys``), splits each, and draws this step's noise from
    the split-off halves — the key bookkeeping lives HERE so the fused
    scan and the host loop replay the identical stream by construction.
    Returns (z_next, new_lazy_cache, scores, new_noise_keys) with
    ``new_noise_keys`` None at eta = 0.
    """
    C = cfg.dit_in_channels
    use_cfg = cfg_scale != 1.0
    B0 = z.shape[0]
    if use_cfg:
        # CFG doubles the batch INTERLEAVED — [cond_0, uncond_0, cond_1,
        # ...] rather than [cond...; uncond...] — so each example's pair
        # is contiguous: under a batch-sharded mesh the pair stays on one
        # shard (a [z; z] concat along the sharded axis would interleave
        # shard ownership and force a reshard of every layer activation)
        y_all = jnp.stack([labels, jnp.full_like(labels, cfg.dit_n_classes)],
                          axis=1).reshape(-1)
        zz = jnp.stack([z, z], axis=1).reshape((2 * B0,) + z.shape[1:])
    else:
        y_all = labels
        zz = z
    tt = jnp.full((zz.shape[0],), t.astype(jnp.float32), jnp.float32)
    out, new_lazy, scores = dit_lib.dit_forward(
        params, cfg, zz, tt, y_all, lazy_cache=lazy_cache,
        lazy_mode=pol.exec_mode, plan_row=row, fresh=step == 0, policy=pol)
    eps_all, _ = dit_lib.split_eps(out, C)
    if use_cfg:
        # un-interleave via a local reshape (no cross-shard slicing)
        pair = eps_all.reshape((B0, 2) + eps_all.shape[1:])
        eps = cfg_eps(pair[:, 0], pair[:, 1], cfg_scale)
    else:
        eps = eps_all
    # fusion boundary shared by both executors: without it XLA fuses the
    # DDIM update with whatever surrounds it (a scan carry vs a jit
    # epilogue), changing FMA contraction and flipping ~1 ulp per step
    z, eps = _fusion_barrier((z, eps))
    B = z.shape[0]
    noise, new_keys = None, noise_keys
    if eta > 0.0:
        splits = jax.vmap(jax.random.split)(noise_keys)       # (B, 2, 2)
        new_keys, step_keys = splits[:, 0], splits[:, 1]
        noise = jax.vmap(
            lambda k: jax.random.normal(k, z.shape[1:], z.dtype))(step_keys)
    z = ddim_step(sched, z, eps, jnp.full((B,), t), jnp.full((B,), t_prev),
                  eta=eta, noise=noise)
    return z, new_lazy, scores, new_keys


def ddim_sample(params: dict, cfg: ModelConfig, sched: DiffusionSchedule, *,
                key, labels: Array, n_steps: int, cfg_scale: float = 1.5,
                eta: float = 0.0,
                lazy_mode: str = "off",
                plan: Optional[np.ndarray] = None,
                policy=None,
                collect_scores: bool = False,
                collect_traces: bool = False,
                telemetry: bool = False,
                ) -> Tuple[Array, Dict]:
    """Full DDIM sampling loop for the DiT denoiser.

    CFG doubles the batch — INTERLEAVED, [cond_0, uncond_0, cond_1, ...],
    so each example's pair stays on one shard under a data-parallel mesh
    (see trajectory_step); the lazy cache is per batch row, so cond/uncond
    streams each keep their own cache — matching the paper's
    implementation.

    Every skip/reuse decision routes through one cache policy
    (repro.cache; DESIGN.md §Cache).  ``policy`` names or carries it
    directly; the legacy (``lazy_mode``, ``plan``) pair is an alias mapped
    onto a policy via repro.cache.from_legacy, so existing callers are
    unchanged.

    Execution: the fused trajectory executor (sampling/trajectory.py)
    compiles the whole loop once, with plan rows as scanned device arrays.
    The ``collect_scores``/``collect_traces`` debug flags force the
    host-loop reference (``ddim_sample_reference``) instead — per-step
    probe scores / module-output traces need host access between steps.

    ``eta`` > 0 enables stochastic DDIM (Song et al. Eq. 16) on the
    reserved per-step keys — per-example noise, reproducible under a
    fixed seed and invariant to batch sharding across a device mesh.

    ``telemetry=True`` (repro.obs) rides the fused executor's scan carry
    with per-(step, layer, module) counters — executed/skipped fractions,
    gate scores, cached-vs-fresh drift — returned drained (numpy) as
    ``aux["telemetry"]``.  Telemetry is a fused-path feature; combining it
    with the debug collectors (which force the host loop) is an error.

    Returns (samples (B,H,W,C), aux); aux carries the final policy state
    and realized skip ratio (fused path) or the per-step score/trace logs
    (debug path).
    """
    if telemetry and (collect_scores or collect_traces):
        raise ValueError(
            "telemetry=True requires the fused trajectory executor; "
            "collect_scores/collect_traces force the host-loop reference "
            "— drop the collectors or the telemetry flag")
    if not (collect_scores or collect_traces):
        from repro.sampling import trajectory
        return trajectory.sample_trajectory(
            params, cfg, sched, key=key, labels=labels, n_steps=n_steps,
            cfg_scale=cfg_scale, eta=eta, lazy_mode=lazy_mode, plan=plan,
            policy=policy, telemetry=telemetry)
    return ddim_sample_reference(
        params, cfg, sched, key=key, labels=labels, n_steps=n_steps,
        cfg_scale=cfg_scale, eta=eta, lazy_mode=lazy_mode, plan=plan,
        policy=policy,
        collect_scores=collect_scores, collect_traces=collect_traces)


def ddim_sample_reference(params: dict, cfg: ModelConfig,
                          sched: DiffusionSchedule, *,
                          key, labels: Array, n_steps: int,
                          cfg_scale: float = 1.5,
                          eta: float = 0.0,
                          lazy_mode: str = "off",
                          plan: Optional[np.ndarray] = None,
                          policy=None,
                          collect_scores: bool = False,
                          collect_traces: bool = False,
                          ) -> Tuple[Array, Dict]:
    """Host-loop reference sampler (the debug path).

    One jitted ``trajectory_step`` dispatch per sampling step — the SAME
    step computation the fused scan body traces (plan rows as traced
    device arrays, traced first-step flag), so the fused executor matches
    this loop bit-for-bit (tests/test_trajectory.py).  What stays
    host-side is the per-step dispatch and the score/trace collection;
    what the fused executor removes is exactly that per-step overhead
    plus the per-call retrace this closure pays.  (The compile-time
    static-row path — skipped modules absent from the HLO, the measured
    FLOP saving — lives in dit_forward's host-array plan rows and is
    exercised directly by dist/hlo accounting in the benches and
    launch/dryrun.)

    Score/trace logs are collected with pipelined async device->host
    transfers (see ``_log``): the loop never blocks on its own step's
    data, so debug collection doesn't serialize the device queue
    step-by-step, and at most one step of logs stays on device.
    """
    pol = cache_policy.resolve(policy, lazy_mode=lazy_mode, plan=plan,
                               threshold=cfg.lazy.threshold)
    lazy_mode = pol.exec_mode
    pstate = pol.init_state(n_steps=n_steps, n_layers=cfg.n_layers,
                            n_modules=2)

    B = labels.shape[0]
    H = cfg.dit_input_size
    C = cfg.dit_in_channels
    z = jax.random.normal(key, (B, H, H, C), jnp.float32)
    ts = sampling_timesteps(sched.n_train_steps, n_steps)
    use_cfg = cfg_scale != 1.0

    lazy_cache = None
    if lazy_mode != "off":
        lazy_cache = dit_lib.init_dit_lazy_cache(cfg, 2 * B if use_cfg else B)
    plan_dev = (pol.device_plan(n_steps, cfg.n_layers, 2)
                if lazy_mode == "plan" else None)
    noise_keys = per_example_keys(key, B) if eta > 0.0 else None

    @jax.jit
    def step_eval(params, sched, z, labels, t, t_prev, step, lazy_cache,
                  row, noise_keys):
        return trajectory_step(params, cfg, sched, pol, cfg_scale, z,
                               labels, t, t_prev, step, lazy_cache, row,
                               eta=eta, noise_keys=noise_keys)

    def _log(log, tree):
        """Pipelined device->host collection: start THIS step's transfer
        asynchronously, materialize the PREVIOUS step's (whose transfer
        has had a full step to complete).  The loop never blocks on its
        own step's data, and at most one step of logged trees stays on
        device — keeping whole-trajectory trace collection (n_steps ×
        (L, B', N, D) activations) from pinning accelerator memory the
        way an after-the-loop batch conversion would."""
        jax.tree.map(lambda a: a.copy_to_host_async(), tree)
        log.append(tree)
        if len(log) > 1:
            log[-2] = jax.tree.map(np.asarray, log[-2])

    score_log, trace_log = [], []
    for i, t in enumerate(ts):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        row = plan_dev[i] if plan_dev is not None else None
        z, lazy_cache, scores, noise_keys = step_eval(
            params, sched, z, labels, jnp.int32(t), jnp.int32(t_prev),
            jnp.int32(i), lazy_cache, row, noise_keys)
        if scores:
            # the same layer-mean statistic the fused executor feeds
            # update_traced_state, kept device-side (no per-step sync)
            pstate = pol.update_state(
                pstate, step=i,
                scores=jnp.stack([scores["attn"].mean(-1),
                                  scores["ffn"].mean(-1)], axis=-1))
        else:
            pstate = pol.update_state(pstate, step=i)
        if collect_scores and scores:
            _log(score_log, scores)
        if collect_traces and lazy_cache is not None:
            _log(trace_log, lazy_cache)

    aux = {}
    # only the LAST step still needs materializing here
    if score_log:
        aux["scores"] = jax.tree.map(np.asarray, score_log)
    if trace_log:
        aux["traces"] = jax.tree.map(np.asarray, trace_log)
    return z, aux
