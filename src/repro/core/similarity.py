"""Empirical validators for the paper's theory (Theorems 1-3).

These run against real module traces collected during sampling and are used
by ``benchmarks/bench_similarity.py`` (the paper's Fig. 4 / §3.2 analysis).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def cosine_similarity(x: Array, y: Array) -> Array:
    """Paper Eq. (3): f(X, Y) = tr[X^T Y] / (||X||_F ||Y||_F), batched over
    leading dims beyond the final two."""
    x32, y32 = x.astype(jnp.float32), y.astype(jnp.float32)
    num = jnp.sum(x32 * y32, axis=(-2, -1))
    den = jnp.linalg.norm(x32, axis=(-2, -1)) * jnp.linalg.norm(y32, axis=(-2, -1))
    return num / jnp.maximum(den, 1e-12)


def similarity_from_distance(x: Array, y: Array) -> Array:
    """Fact 7 identity used in Thm 18: with ||X||_F = ||Y||_F = 1,
    f(X, Y) = 1 - ||X - Y||_F^2 / 2.  Normalizes inputs first."""
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    xn = x32 / jnp.maximum(jnp.linalg.norm(x32, axis=(-2, -1), keepdims=True), 1e-12)
    yn = y32 / jnp.maximum(jnp.linalg.norm(y32, axis=(-2, -1), keepdims=True), 1e-12)
    return 1.0 - 0.5 * jnp.sum((xn - yn) ** 2, axis=(-2, -1))


def consecutive_step_similarity(outputs: Array) -> Array:
    """outputs: (T, ..., N, D) module outputs over sampling steps.
    Returns (T-1, ...) cosine similarities between steps t-1 and t."""
    return cosine_similarity(outputs[:-1], outputs[1:])


def empirical_lipschitz(fn, x: Array, key, n_probes: int = 8,
                        eps: float = 1e-2) -> float:
    """Estimate the module Lipschitz constant C of Thm 17 via random
    finite-difference probes: C >= ||F(x+d) - F(x)|| / ||d||."""
    y0 = fn(x)
    best = 0.0
    for k in jax.random.split(key, n_probes):
        d = jax.random.normal(k, x.shape, jnp.float32) * eps
        y1 = fn(x + d.astype(x.dtype))
        num = float(jnp.linalg.norm((y1 - y0).astype(jnp.float32)))
        den = float(jnp.linalg.norm(d))
        best = max(best, num / max(den, 1e-12))
    return best


def linear_probe_fit(z: np.ndarray, sims: np.ndarray) -> Tuple[np.ndarray, float]:
    """Thm 3 validation: least-squares fit sims ~ <W, Z> over a trace.

    z: (n, N, D) modulated inputs; sims: (n,) true consecutive-step
    similarities.  Returns (w, r2).  Pools tokens (mean over N) to match the
    deployed probe's ``mean_N(Z W)`` form."""
    feats = z.reshape(z.shape[0], z.shape[1], -1).mean(axis=1)     # (n, D)
    feats = np.concatenate([feats, np.ones((len(feats), 1))], axis=1)
    w, *_ = np.linalg.lstsq(feats, sims, rcond=None)
    pred = feats @ w
    ss_res = float(((sims - pred) ** 2).sum())
    ss_tot = float(((sims - sims.mean()) ** 2).sum()) + 1e-12
    return w, 1.0 - ss_res / ss_tot


def theorem2_bound(C: float, eta: float) -> float:
    """Similarity lower bound 1 - alpha with alpha = O(C^2 eta^2) (Thm 18,
    alpha = 0.5 C^2 eta^2 min(N,D) folded into eta here)."""
    return 1.0 - 0.5 * (C * eta) ** 2
