"""LazyDiT core: lazy-learning gates, step caches, and lazy execution.

The paper (LazyDiT, AAAI 2025) adds a linear probe before every MHSA and
Feedforward module.  The probe reads the *modulated* input Z (after adaLN
scale/shift in DiT; after pre-norm in LLM decoders) and emits a per-batch
laziness score

    s = sigmoid( mean_N( Z @ W + b ) )            # paper Eq. "Training Forward"

Training (``mode='soft'``) runs the module and mixes with the previous step's
cached output

    Y_t = diag(1 - s) F(Z_t) + diag(s) Y_{t-1}

with the *lazy loss*  L_lazy = rho * mean_b sum_l (1 - s_{l,b})  pushing s up.
Inference skips the module when s > 0.5 and reuses the cache.

Execution modes (see DESIGN.md §3 for the TPU adaptation):
  * ``soft``    — paper-faithful training mixture.
  * ``masked``  — per-sample ``where`` select; faithful semantics under SPMD,
                  used for measuring realized lazy ratios (no FLOP saving).
  * ``plan``    — a static (steps × modules) boolean plan applied at trace
                  time: skipped modules are absent from the compiled HLO, so
                  the saving is visible in cost_analysis / the roofline.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as kernel_backend
from repro.kernels.lazy_gate import ops as lazy_gate_ops

Array = jax.Array

# ---------------------------------------------------------------------------
# Gate params
# ---------------------------------------------------------------------------


GATE_W_INIT_SCALE = 0.1


def init_lazy_gate(key, d_model: int, dtype="float32", init_bias: float = -2.0) -> dict:
    """Probe params.  ``init_bias`` < 0 starts the model diligent (s ~ 0.12),
    matching the paper's observation that laziness must be *learned*.

    The weight is initialized SMALL (0.1/sqrt(d)) so the pre-sigmoid spread
    (~0.1 on unit-RMS inputs) stays far inside the 2.0 bias margin: with a
    1/sqrt(d) init the single-token decode probe (no sequence pooling to
    average the noise down) crosses the 0.5 threshold on ~2% of inputs and
    an *untrained* model starts skipping modules."""
    w = (jax.random.normal(key, (d_model, 1), jnp.float32)
         * (GATE_W_INIT_SCALE / math.sqrt(d_model)))
    return {"w": w.astype(dtype), "b": jnp.full((1,), init_bias, dtype)}


def gate_score(gate: dict, z: Array) -> Array:
    """s in (0,1), shape (B,).  f32 accumulation regardless of z dtype."""
    zp = z.astype(jnp.float32) @ gate["w"].astype(jnp.float32)     # (B, N, 1)
    pooled = jnp.mean(zp[..., 0], axis=-1) + gate["b"].astype(jnp.float32)[0]
    return jax.nn.sigmoid(pooled)


# ---------------------------------------------------------------------------
# Lazy execution
# ---------------------------------------------------------------------------


class LazyOut(NamedTuple):
    y: Array                 # module output (possibly cached)
    new_cache: Array         # value to cache for the next step
    score: Optional[Array]   # (B,) laziness score; None in plan mode


def _not_fresh(fresh: Array, ndim: int) -> Array:
    """~fresh broadcast to ``ndim`` trailing dims.  ``fresh`` is (B,) host-
    batched, 0-d under the per-slot vmap of decode_step_mixed, or a traced
    first-step scalar inside the fused trajectory scan."""
    return jnp.logical_not(jnp.reshape(fresh, (-1,) + (1,) * (ndim - 1)))


def select_cached(skip, y_new: Array, cache_y: Array,
                  fresh: Optional[Array] = None) -> Array:
    """The one select-based gating rule: serve ``cache_y`` where ``skip``,
    ``y_new`` elsewhere, never serving a just-reset (``fresh``) cache.

    ``skip`` is a traced boolean — scalar (one plan entry applied to the
    whole batch, the fused trajectory executor), (B,) per-sample (masked
    probes / per-slot plan rows under decode_step_mixed's vmap), or
    anything broadcastable over ``y_new``'s trailing dims.  Both the
    DiT sampling path and the LM decode path route their where-selects
    through here, so traced plan rows and masked probe decisions share
    one implementation (DESIGN.md §Trajectory).
    """
    skip = jnp.reshape(skip, (-1,) + (1,) * (y_new.ndim - 1))
    if fresh is not None:
        skip = jnp.logical_and(skip, _not_fresh(fresh, y_new.ndim))
    return jnp.where(skip, cache_y, y_new)


def mix_cached(weight, y_new: Array, cache_y: Array,
               fresh: Optional[Array] = None) -> Array:
    """Differentiable relaxation of ``select_cached``: a convex mixture

        y = (1 - w) * y_new + w * cache_y

    with ``weight`` in [0, 1] — scalar, (B,), or broadcastable like
    ``select_cached``'s skip.  This is the path a *learned router* trains
    through (train/learned.py): the relaxed-Bernoulli gate rides a traced
    FLOAT plan row, gradients flow into the router logits, and hardening
    the weights (w -> {0, 1}) recovers the select exactly.  ``fresh``
    zeroes the mixture weight so a just-reset cache is never blended in
    (same contract as soft mode)."""
    w = jnp.reshape(weight.astype(y_new.dtype),
                    (-1,) + (1,) * (y_new.ndim - 1))
    if fresh is not None:
        w = w * _not_fresh(fresh, y_new.ndim).astype(w.dtype)
    return (1 - w) * y_new + w * cache_y


def lazy_execute(fn: Callable[[Array], Array], z: Array, *,
                 gate: Optional[dict],
                 cache_y: Optional[Array],
                 mode: str = "off",
                 threshold: float = 0.5,
                 plan_skip=False,
                 fresh: Optional[Array] = None,
                 policy=None) -> LazyOut:
    """Run/skip one gated module.

    ``fn`` computes the module on the modulated input ``z``; ``cache_y`` is
    the previous diffusion/decode step's output for this module (None on the
    first step -> always run).

    ``plan_skip`` is either a static bool (compile-time skip: the module is
    absent from the HLO — the paper's FLOP saving) or a traced boolean array
    (continuous batching: slots sit at different request steps, so the skip
    decision is a per-slot ``where`` select; see DESIGN.md §Serve).
    ``fresh`` (per-sample bool) marks slots whose lazy cache was just reset
    (request admitted this step): a fresh slot never serves its cache.

    ``policy`` (repro.cache.CachePolicy, duck-typed to avoid a circular
    import) is the single authority on mode + threshold when given: every
    executor routes its skip decision through one policy object
    (DESIGN.md §Cache); the bare ``mode``/``threshold`` args remain as the
    legacy alias path.
    """
    if policy is not None:
        mode = policy.exec_mode
        threshold = getattr(policy, "threshold", threshold)
    if mode == "off" or (gate is None and mode != "plan"):
        y = fn(z)
        return LazyOut(y, y, None)

    # plan mode does not read the gate: skips come from the plan, so it
    # works (and its accounted savings are real) even with no probe params
    if mode == "plan":
        if isinstance(plan_skip, jax.Array):
            if cache_y is None:
                y = fn(z)
                return LazyOut(y, y, None)
            if jnp.issubdtype(plan_skip.dtype, jnp.floating):
                # relaxed plan entry (learned-router training): mix
                # instead of select so gradients reach the router logits
                y = mix_cached(plan_skip, fn(z), cache_y, fresh)
                return LazyOut(y, y, None)
            if (kernel_backend.get_backend() == "pallas"
                    and plan_skip.ndim == 0
                    and (fresh is None or getattr(fresh, "ndim", 0) == 0)):
                # pallas backend, whole-batch plan bit (the fused/host DiT
                # executors — plan rows are per layer, not per example):
                # hoist the skip to a runtime ``lax.cond`` so a skipped
                # module costs one cache read instead of both select
                # branches.  Under a per-slot vmap (batched predicate) XLA
                # lowers the cond back to the select — identical semantics,
                # so the serving path is unaffected.
                serve = plan_skip
                if fresh is not None:
                    serve = jnp.logical_and(serve, jnp.logical_not(fresh))
                y = jax.lax.cond(serve, lambda: cache_y, lambda: fn(z))
                return LazyOut(y, y, None)
            y = select_cached(plan_skip, fn(z), cache_y, fresh)
            return LazyOut(y, y, None)
        if plan_skip and cache_y is not None:
            return LazyOut(cache_y, cache_y, None)   # module absent from HLO
        y = fn(z)
        return LazyOut(y, y, None)

    if (mode == "masked" and cache_y is not None
            and kernel_backend.get_backend() == "pallas"
            and z.ndim == 3 and cache_y.ndim == 3
            and cache_y.shape[:2] == z.shape[:2]):
        # fused gate+select (DESIGN.md §Kernels): probe score, threshold
        # and fresh-or-cached tile write in one pass.  On interpret hosts
        # the op dispatches to a jnp reference that is op-for-op the
        # gate_score + select_cached math below — bit-exact with the XLA
        # baseline — so this path only changes the HLO on compiled-Pallas
        # targets.
        y, s = lazy_gate_ops.lazy_gate_select(
            z, gate["w"], gate["b"], fn(z), cache_y, fresh,
            threshold=float(threshold))
        return LazyOut(y, y, s)

    s = gate_score(gate, z)                                        # (B,)
    if cache_y is None:
        y = fn(z)
        return LazyOut(y, y, s)

    if mode == "soft":
        y_new = fn(z)
        mix = s[:, None, None].astype(y_new.dtype)
        if fresh is not None:
            # fresh slots must not blend their zeroed cache into the output
            mix = mix * _not_fresh(fresh, y_new.ndim).astype(mix.dtype)
        y = (1 - mix) * y_new + mix * cache_y
        return LazyOut(y, y, s)
    if mode == "masked":
        y_new = fn(z)
        y = select_cached(s > threshold, y_new, cache_y, fresh)
        return LazyOut(y, y, s)
    raise ValueError(f"unknown lazy mode: {mode}")


# ---------------------------------------------------------------------------
# Cache drift — the telemetry statistic (repro.obs)
# ---------------------------------------------------------------------------


def module_drift(new_y: Array, old_y: Array, *,
                 eps: float = 1e-12) -> Tuple[Array, Array]:
    """(cosine, relative-L2) drift between a module's fresh output and its
    previous-step lazy cache, batched over leading dims beyond (N, D).

    This is the statistic SmoothCache calibrates offline and the paper's
    §3.2 similarity analysis measures — exposed here so the fused
    executor's telemetry carry (repro.obs.telemetry) can compute it
    in-trace from the scan's cache buffers, with no extra forward pass:

        cos = tr[new^T old] / max(||new||_F ||old||_F, eps)     (paper Eq. 3)
        rel = ||new - old||_F / max(||old||_F, eps)

    Reductions run in f32 regardless of input dtype.  A zero ``old``
    (just-initialized cache) yields cos = 0, rel = ||new|| / eps — callers
    mask first-step / fresh entries rather than this function guessing."""
    n32, o32 = new_y.astype(jnp.float32), old_y.astype(jnp.float32)
    old_norm = jnp.linalg.norm(o32, axis=(-2, -1))
    new_norm = jnp.linalg.norm(n32, axis=(-2, -1))
    cos = (jnp.sum(n32 * o32, axis=(-2, -1))
           / jnp.maximum(new_norm * old_norm, eps))
    rel = (jnp.linalg.norm(n32 - o32, axis=(-2, -1))
           / jnp.maximum(old_norm, eps))
    return cos, rel


# ---------------------------------------------------------------------------
# Lazy loss + realized ratio (paper Eq. 5 and the lazy-ratio Γ)
# ---------------------------------------------------------------------------


def lazy_loss(scores: Dict[str, Array], rho_attn: float, rho_ffn: float,
              rho_block: Optional[float] = None) -> Array:
    """scores: mapping module-kind -> stacked scores (L, B) or (B,).

    The rho mapping is EXPLICIT per module kind — 'attn' -> rho_attn,
    'ffn' -> rho_ffn, 'block' (single-module SSM/xLSTM layers) ->
    rho_block, defaulting to rho_ffn.  An unknown score key raises
    instead of silently inheriting a penalty: the old substring match
    ('attn' in name) handed every future module kind rho_ffn, which
    miscalibrated the laziness pressure without any signal.

    Returns a scalar:  sum_kinds rho_kind * mean_b sum_l (1 - s_{l,b}).
    """
    rho_by_kind = {"attn": rho_attn, "ffn": rho_ffn,
                   "block": rho_ffn if rho_block is None else rho_block}
    total = jnp.zeros((), jnp.float32)
    for name, s in scores.items():
        if name not in rho_by_kind:
            raise ValueError(
                f"unknown gated-module kind {name!r} in lazy-loss scores; "
                f"known kinds: {tuple(rho_by_kind)} — add an explicit rho "
                "mapping before gating a new module kind")
        s2 = s if s.ndim == 2 else s[None]
        total = total + rho_by_kind[name] * jnp.mean(jnp.sum(1.0 - s2, axis=0))
    return total


def realized_lazy_ratio(scores_over_steps: Array, threshold: float = 0.5) -> Array:
    """Γ = (1/LT) Σ_l Σ_t ceil(s - 0.5): fraction of skipped module calls.

    scores_over_steps: (T, L, ...) with trailing batch dims averaged."""
    skips = (scores_over_steps > threshold).astype(jnp.float32)
    return jnp.mean(skips)


# ---------------------------------------------------------------------------
# Step cache — one cached output per gated module
# ---------------------------------------------------------------------------


def init_step_cache(module_shapes: Dict[str, Tuple[int, ...]], dtype) -> Dict[str, Array]:
    return {k: jnp.zeros(sh, dtype) for k, sh in module_shapes.items()}


# ---------------------------------------------------------------------------
# Per-slot step-cache helpers (continuous batching; serving/slots.py)
#
# A slot pool stacks one single-sequence cache per slot along a leading axis.
# Every leaf of a stacked tree is (n_slots, *single_leaf_shape); these
# helpers init/reset/gather/scatter along that axis so a request joining a
# slot never observes the previous occupant's cached module outputs.
# ---------------------------------------------------------------------------


def stack_for_slots(single_cache, n_slots: int):
    """Stack one single-sequence cache tree into an ``n_slots``-slot pool."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_slots,) + a.shape).copy()
        if hasattr(a, "shape") else a, single_cache)


def slot_cache_reset(stacked, slot: int):
    """Zero slot ``slot``'s entries (request admitted / evicted): the next
    occupant starts from an empty step cache and must prime it (``fresh``)."""
    return jax.tree.map(lambda a: a.at[slot].set(jnp.zeros_like(a[slot])),
                        stacked)


def slot_cache_gather(stacked, slot: int):
    """Extract slot ``slot``'s single-sequence cache tree."""
    return jax.tree.map(lambda a: a[slot], stacked)


def slot_cache_scatter(stacked, slot: int, single):
    """Write a single-sequence cache tree into slot ``slot`` (admission:
    the request's freshly prefilled cache replaces the evictee's)."""
    return jax.tree.map(lambda big, small: big.at[slot].set(small),
                        stacked, single)


# ---------------------------------------------------------------------------
# Static lazy plans
# ---------------------------------------------------------------------------


class LazyPlan(NamedTuple):
    """Boolean skip plan, shape (n_steps, n_layers, n_modules_per_layer).

    ``skip[t, l, m]`` True -> module m of layer l is skipped at step t.
    Stored as a host-side numpy array so it is static at trace time.
    """
    skip: np.ndarray

    @property
    def lazy_ratio(self) -> float:
        return float(self.skip.mean())

    def layer_ratio(self) -> np.ndarray:
        return self.skip.mean(axis=(0,))


def plan_from_scores(scores: np.ndarray, threshold: float = 0.5) -> LazyPlan:
    """Calibrated plan: batch-averaged probe scores thresholded.

    scores: (T, L, M) batch-averaged sigmoid scores.  Step 0 never skips
    (there is no cache yet)."""
    skip = np.asarray(scores) > threshold
    skip[0] = False
    return LazyPlan(skip)


def plan_with_target_ratio(scores: np.ndarray, target: float,
                           per_step: bool = True,
                           per_layer: bool = False) -> LazyPlan:
    """Pick the top-q scoring module calls to hit a target lazy ratio
    — the knob the paper turns via the penalty rho, exposed directly
    for deployment ('50% lazy ratio' rows of Tables 1/2).

    Every mode keeps the FIRST and LAST steps always-fresh: the paper's
    similarity analysis (§3.2) shows trajectory endpoints are least similar
    across steps — early steps shape structure, and the final step is the
    emitted output, so neither may serve a stale cache.

    ``per_step=True`` allocates the skip budget uniformly per sampling step
    AND rotates a forced-refresh hole (period REFRESH): a static plan that
    skips the same module every step lets its cache go stale for the whole
    trajectory, which the paper's dynamic gates never do — the refresh
    rotation recovers that behaviour in a compiled plan.  The rotation caps
    the achievable per-step ratio at 1 - 1/REFRESH (0.75): targets above
    that are clipped to the feasible set, not errored.

    ``per_layer=True`` (overrides ``per_step``) additionally pins a uniform
    per-LAYER quota each step — the Learning-to-Cache-style router shape
    (repro.cache.StaticRouterPolicy): no layer may hog the skip budget, so
    depth-local error cannot concentrate."""
    REFRESH = 4
    s = np.asarray(scores, np.float64).copy()
    T = s.shape[0]
    skip = np.zeros_like(s, bool)
    # T < 3: every step is the first or the last -> nothing may skip
    if target <= 0 or T < 3:
        return LazyPlan(skip)
    last = T - 1
    n_skippable = T - 2

    def pick(flat: np.ndarray, allowed: np.ndarray, n: int) -> np.ndarray:
        order = [j for j in np.argsort(flat)
                 if allowed[j] and np.isfinite(flat[j])]
        idx = order[-min(n, len(order)):] if n else []
        sk = np.zeros(flat.size, bool)
        sk[idx] = True
        return sk

    if per_layer:
        n_layers = s.shape[1]
        m = s[0, 0].size if s.ndim > 2 else 1
        # Bresenham accumulation of the exact per-layer-per-step quota:
        # with few modules per layer (m = 2) an integer quota quantizes
        # the achievable ratios to multiples of ~1/m, so small targets
        # would round to an empty plan — spreading floor/ceil quotas over
        # steps hits the target in aggregate while every layer still
        # spends the same budget each step.
        q_exact = target * T * m / n_skippable
        acc = taken = 0.0
        for t in range(1, last):
            acc += q_exact
            quota = min(int(round(acc - taken)), m)
            taken += quota
            for l in range(n_layers):
                flat = s[t, l].reshape(-1)
                # the refresh rotation indexes modules globally so holes
                # still rotate across layers
                gidx = l * m + np.arange(m)
                allowed = gidx % REFRESH != t % REFRESH
                skip[t, l] = pick(flat, allowed, quota).reshape(s.shape[2:])
        return LazyPlan(skip)

    if per_step:
        per = s[0].size
        n_skip = min(int(round(target * T * per / n_skippable)), per)
        for t in range(1, last):
            flat = s[t].reshape(-1)
            # forced refresh: module j may not skip on its refresh step
            allowed = np.arange(per) % REFRESH != t % REFRESH
            skip[t] = pick(flat, allowed, n_skip).reshape(s[t].shape)
        return LazyPlan(skip)

    s[0] = -np.inf                       # never skip the first step...
    s[last] = -np.inf                    # ...or the last
    flat = s.reshape(-1)
    # pick indices, not a threshold compare: a `s >= thresh` select would
    # over-skip on duplicate scores and — for targets above (T-2)/T, where
    # the budget exceeds the finite entries — sweep in the first/last-step
    # -inf sentinels themselves.
    n_skip = min(int(round(target * flat.size)), int(np.isfinite(flat).sum()))
    if n_skip == 0:
        return LazyPlan(skip)
    skip_flat = np.zeros(flat.size, bool)
    skip_flat[np.argsort(flat)[-n_skip:]] = True
    return LazyPlan(skip_flat.reshape(s.shape))


def uniform_plan(n_steps: int, n_layers: int, n_modules: int,
                 ratio: float, seed: int = 0) -> LazyPlan:
    """Baseline plan: random uniform skips at a given ratio (ablation --
    what the learned probes must beat)."""
    rng = np.random.default_rng(seed)
    skip = rng.random((n_steps, n_layers, n_modules)) < ratio
    skip[0] = False
    return LazyPlan(skip)
