"""AdamW + schedules, pure-pytree (no optax in this environment)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_update(state: AdamWState, grads, params, *, lr: Array | float,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0,
                 mask=None) -> tuple[dict, AdamWState]:
    """One AdamW step.  ``mask``: pytree of bools — False leaves are frozen
    (used by lazy learning to train only the probe weights)."""
    step = state.step + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf

    def upd(p, g, m, v, trainable=True):
        if not trainable:
            return p, m, v
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # three passes keep pytree structure simple; XLA CSEs the duplicates
    args = (params, grads, state.mu, state.nu) + ((mask,) if mask is not None else ())
    new_p = jax.tree.map(lambda *a: upd(*a)[0], *args)
    new_m = jax.tree.map(lambda *a: upd(*a)[1], *args)
    new_v = jax.tree.map(lambda *a: upd(*a)[2], *args)
    return new_p, AdamWState(step, new_m, new_v)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
