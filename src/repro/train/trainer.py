"""Training loops.

Two phases mirror the paper:
  1. ``diffusion_train_step`` — standard DiT pretraining (full params).
  2. ``lazy_train_step`` — the paper's 500-step lazy learning: base weights
     FROZEN, only the probe weights train.  Per batch we sample a sampling-
     step pair (t_prev -> t), run the frozen model at t_prev to fill the
     step cache (stop_gradient), then run soft-mode at t with
     loss = ||eps_theta - eps||^2 + L_lazy  (paper Eq. 5).
Also ``lm_train_step`` for the assigned LLM architectures.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.models import dit as dit_lib
from repro.models import transformer as tf_lib
from repro.sampling import ddim
from repro.train import optim

Array = jax.Array

# ---------------------------------------------------------------------------
# Gate-parameter masking (freeze everything but the lazy probes)
# ---------------------------------------------------------------------------

GATE_KEYS = ("g_attn", "g_ffn", "g_block")


def gate_mask(params) -> dict:
    """Pytree of bools: True only under lazy-gate subtrees."""
    def walk(node, in_gate):
        if isinstance(node, dict):
            return {k: walk(v, in_gate or k in GATE_KEYS) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            return type(node)(walk(v, in_gate) for v in node)
        return in_gate
    return walk(params, False)


def mask_grads(grads, mask):
    """Zero every gradient leaf outside ``mask`` (the frozen base weights).

    This must happen BEFORE global-norm clipping: the frozen base-weight
    gradients dominate the global norm (they outnumber the probe params by
    orders of magnitude), so clipping the raw tree silently shrank every
    probe update by the base-weight norm — laziness then trains at a tiny
    effective LR no matter what ``lr`` says.  Zeroing also makes the
    frozen-weight VJP branches dead code inside the jitted step, so XLA
    prunes the wasted backward through the frozen trunk."""
    return jax.tree.map(
        lambda g, m: g if m else jnp.zeros_like(g), grads, mask)


# ---------------------------------------------------------------------------
# DiT diffusion pretraining
# ---------------------------------------------------------------------------


def diffusion_loss(params, cfg: ModelConfig, sched: ddim.DiffusionSchedule,
                   x0: Array, y: Array, key) -> Array:
    kt, kn = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.randint(kt, (B,), 0, sched.n_train_steps)
    noise = jax.random.normal(kn, x0.shape, jnp.float32)
    z_t = ddim.q_sample(sched, x0, t, noise)
    out, _, _ = dit_lib.dit_forward(params, cfg, z_t, t.astype(jnp.float32), y)
    eps, _ = dit_lib.split_eps(out, cfg.dit_in_channels)
    return jnp.mean((eps.astype(jnp.float32) - noise) ** 2)


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def diffusion_train_step(params, opt_state, cfg: ModelConfig,
                         sched: ddim.DiffusionSchedule, x0, y, key,
                         lr: float = 1e-4):
    loss, grads = jax.value_and_grad(diffusion_loss)(params, cfg, sched, x0, y, key)
    grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
    params, opt_state = optim.adamw_update(opt_state, grads, params, lr=lr)
    return params, opt_state, {"loss": loss, "gnorm": gnorm}


# ---------------------------------------------------------------------------
# Lazy learning (paper §3.3)
# ---------------------------------------------------------------------------


def lazy_learning_loss(params, frozen_params, cfg: ModelConfig,
                       sched: ddim.DiffusionSchedule, x0: Array, y: Array,
                       key, n_sample_steps: int) -> Tuple[Array, Dict]:
    """Soft-mode loss at a sampled sampling-step transition.

    The cache comes from the *frozen* model evaluated at the previous
    (noisier) sampling step t_prev, exactly the tensor the deployed sampler
    would have cached."""
    kt, kn, kn2 = jax.random.split(key, 3)
    B = x0.shape[0]
    ts = ddim.sampling_timesteps(sched.n_train_steps, n_sample_steps)  # descending
    idx = jax.random.randint(kt, (B,), 1, len(ts))          # position in schedule
    t = jnp.asarray(ts)[idx]
    t_prev = jnp.asarray(ts)[idx - 1]                       # noisier step

    noise = jax.random.normal(kn, x0.shape, jnp.float32)
    z_prev = ddim.q_sample(sched, x0, t_prev, noise)
    # fill cache at t_prev with frozen weights (priming pass, no grad)
    cache0 = dit_lib.init_dit_lazy_cache(cfg, B)
    _, cache, _ = dit_lib.dit_forward(
        frozen_params, cfg, z_prev, t_prev.astype(jnp.float32), y,
        lazy_cache=cache0, lazy_mode="soft", first_step=True)
    cache = jax.lax.stop_gradient(cache)

    noise2 = jax.random.normal(kn2, x0.shape, jnp.float32)
    z_t = ddim.q_sample(sched, x0, t, noise2)
    out, _, scores = dit_lib.dit_forward(
        params, cfg, z_t, t.astype(jnp.float32), y,
        lazy_cache=cache, lazy_mode="soft")
    eps, _ = dit_lib.split_eps(out, cfg.dit_in_channels)
    dloss = jnp.mean((eps.astype(jnp.float32) - noise2) ** 2)
    lloss = lazy_lib.lazy_loss(scores, cfg.lazy.rho_attn, cfg.lazy.rho_ffn)
    mean_s = {k: jnp.mean(v) for k, v in scores.items()}
    return dloss + lloss, {"diffusion_loss": dloss, "lazy_loss": lloss,
                           **{f"s_{k}": v for k, v in mean_s.items()}}


@functools.partial(jax.jit, static_argnames=("cfg", "n_sample_steps", "lr"))
def lazy_train_step(params, opt_state, cfg: ModelConfig,
                    sched: ddim.DiffusionSchedule, x0, y, key,
                    n_sample_steps: int = 50, lr: float = 1e-4):
    """Paper recipe: AdamW 1e-4, only probes trainable."""
    frozen = jax.lax.stop_gradient(params)
    (loss, aux), grads = jax.value_and_grad(lazy_learning_loss, has_aux=True)(
        params, frozen, cfg, sched, x0, y, key, n_sample_steps)
    mask = gate_mask(params)
    # gate-subtree grads ONLY reach the clip: the global norm (and the
    # reported gnorm) describes the probe updates, not the frozen trunk
    grads = mask_grads(grads, mask)
    grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
    params, opt_state = optim.adamw_update(opt_state, grads, params, lr=lr,
                                           mask=mask)
    aux.update({"loss": loss, "gnorm": gnorm})
    return params, opt_state, aux


# ---------------------------------------------------------------------------
# LM training (assigned architectures)
# ---------------------------------------------------------------------------


CE_CHUNK = 512


def chunked_ce(x: Array, head: Array, tgt: Array, softcap: float = 0.0,
               chunk: int = CE_CHUNK) -> Array:
    """Cross-entropy with the (B, S, V) logits never fully materialized:
    scans over sequence chunks (production necessity at vocab 256k)."""
    B, S, D = x.shape
    if S <= chunk:
        logits = x @ head
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, tgt[..., None], axis=-1))
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = tgt.reshape(B, nc, chunk).transpose(1, 0, 2)
    valid = (jnp.arange(nc * chunk) < S).reshape(nc, chunk)

    def body(acc, inp):
        xb, tb, vb = inp
        logits = xb @ head
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, tb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(nll * vb[None, :]), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc, valid))
    return total / (B * S)


def lm_loss(params, cfg: ModelConfig, tokens: Array,
            embeds: Optional[Array] = None, remat: bool = False,
            carry_sharding=None) -> Array:
    """Next-token CE + MoE aux.  tokens: (B, S+1)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x, aux = tf_lib.forward(params, cfg, tokens=inp, embeds=embeds,
                            remat=remat, return_hidden=True,
                            carry_sharding=carry_sharding)
    if embeds is not None:
        x = x[:, embeds.shape[1]:]               # predict only the token tail
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    ce = chunked_ce(x, head, tgt, cfg.final_logit_softcap)
    return ce + aux


@functools.partial(jax.jit, static_argnames=("cfg", "lr", "remat"))
def lm_train_step(params, opt_state, cfg: ModelConfig, tokens, key,
                  lr: float = 3e-4, remat: bool = False):
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, remat=remat)
    grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
    params, opt_state = optim.adamw_update(opt_state, grads, params, lr=lr,
                                           weight_decay=0.01)
    return params, opt_state, {"loss": loss, "gnorm": gnorm}
