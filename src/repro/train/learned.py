"""Learned skip schedules — the harness that closes the lazy-learning loop.

Three trained variants share this one harness (ROADMAP item 2), each
distilling to a ``cache/schedule.ScheduleArtifact`` the fused trajectory
executor and the serving engines consume unchanged (via the ``learned``
cache policy):

  * ``train_lazy_gates`` — the PAPER's contribution (LazyDiT §3.3, Eq. 5):
    base weights frozen, only the linear probes train, loss =
    diffusion MSE + rho * sum(1 - s).  Wraps trainer.lazy_train_step in a
    resumable recipe: per-step keys are fold_in-derived (resume-exact) and
    the gate params + AdamW state checkpoint via checkpoint/io mid-run.
  * ``train_router`` — Learning-to-Cache-style (arXiv:2406.01733)
    differentiable per-layer router: relaxed-Bernoulli gates
    w = sigmoid((theta + logistic)/tau) ride the traced FLOAT plan rows
    (core.lazy.mix_cached) through the whole unrolled DDIM trajectory,
    trained against the no-skip teacher's final latent with a
    target-ratio penalty, temperature annealed toward the hard plan.
  * the Δ-DiT feature-residual variant needs no gradients — it is the
    ``delta`` cache policy over a calibration profile (cache/policies.py)
    — but ships through the same benchmark column family (``learned_*``
    in bench_cache_policies) so the three are compared head-to-head.

DESIGN.md §Train documents the artifact flow; launch/train.py is the CLI.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import policies as cache_policies
from repro.cache import policy as cache_policy
from repro.cache.schedule import ScheduleArtifact, distill_scores
from repro.checkpoint import io as ckpt_io
from repro.configs.base import ModelConfig
from repro.data.synthetic import LatentImageDataset
from repro.sampling import ddim
from repro.train import optim, trainer

Array = jax.Array

N_MODULES = 2                    # plan columns: 0 = attention, 1 = ffn


# ---------------------------------------------------------------------------
# Checkpointing — gate params + AdamW state, resumable mid-recipe
# ---------------------------------------------------------------------------


def save_train_state(path: str, params, opt_state: optim.AdamWState,
                     step: int) -> str:
    """Checkpoint the lazy-training state: params (the gates are the only
    leaves that move; the frozen trunk rides along so restore is bit-exact
    with zero merge logic — a production impl would shard/subset), both
    AdamW moment trees, and the step counters."""
    ckpt_io.save_checkpoint(
        path, {"params": params, "mu": opt_state.mu, "nu": opt_state.nu},
        extra={"step": int(step), "opt_step": int(opt_state.step)})
    return path


def restore_train_state(path: str, params_template
                        ) -> Tuple[dict, optim.AdamWState, int]:
    """Restore (params, opt_state, next_step) from ``save_train_state``."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                         params_template)
    tree = ckpt_io.restore_checkpoint(
        path, {"params": params_template, "mu": zeros, "nu": zeros})
    extras = ckpt_io.load_extras(path)
    opt = optim.AdamWState(jnp.asarray(int(extras["opt_step"]), jnp.int32),
                           tree["mu"], tree["nu"])
    return tree["params"], opt, int(extras["step"])


# ---------------------------------------------------------------------------
# Variant (a): the paper's lazy-gate probe training
# ---------------------------------------------------------------------------


def train_lazy_gates(params, cfg: ModelConfig, sched: ddim.DiffusionSchedule,
                     *, steps: int, batch: int = 8, lr: float = 1e-2,
                     n_sample_steps: int = 10, seed: int = 0,
                     data: Optional[LatentImageDataset] = None,
                     opt_state: Optional[optim.AdamWState] = None,
                     start_step: int = 0,
                     ckpt_path: str = "", ckpt_every: int = 0,
                     log_every: int = 0
                     ) -> Tuple[dict, optim.AdamWState, List[Dict[str, float]]]:
    """The paper's 500-step lazy recipe, shrunk to ``steps``.

    Frozen base + probe-only AdamW updates (trainer.lazy_train_step: gate
    grads masked BEFORE global-norm clipping).  Deterministic given
    (seed, batch): batch ``i`` and RNG key ``i`` are derived by index, so
    a run restored from a mid-recipe checkpoint (``start_step`` > 0)
    continues bit-exactly where the interrupted one left off
    (tests/test_trainer.py).  Returns (params, opt_state, history) with
    one float-dict per executed step."""
    data = data or LatentImageDataset(cfg, seed=seed)
    it = data.batches(batch, seed=seed + 1)
    base_key = jax.random.PRNGKey(seed)
    opt = opt_state if opt_state is not None else optim.adamw_init(params)
    history: List[Dict[str, float]] = []
    for i in range(steps):
        x0, y = next(it)
        if i < start_step:
            continue                       # replay the data stream only
        k = jax.random.fold_in(base_key, i)
        params, opt, aux = trainer.lazy_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            n_sample_steps=n_sample_steps, lr=lr)
        history.append({k2: float(v) for k2, v in aux.items()})
        if log_every and (i % log_every == 0 or i == steps - 1):
            h = history[-1]
            print(f"lazy step {i:4d} loss {h['loss']:.4f} "
                  f"lazy {h['lazy_loss']:.5f} gnorm {h['gnorm']:.4f} "
                  f"s_attn {h.get('s_attn', 0.0):.3f}")
        if ckpt_path and ckpt_every and ((i + 1) % ckpt_every == 0
                                         or i == steps - 1):
            save_train_state(ckpt_path, params, opt, i + 1)
    return params, opt, history


def collect_gate_scores(params, cfg: ModelConfig,
                        sched: ddim.DiffusionSchedule, *, key, labels,
                        n_steps: int, cfg_scale: float = 1.5) -> np.ndarray:
    """Batch-averaged trained-probe scores over a masked-mode sampling
    run: the (T, L, 2) evidence a gate schedule distills from."""
    _, aux = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                              n_steps=n_steps, cfg_scale=cfg_scale,
                              lazy_mode="masked", collect_scores=True)
    sc = np.stack([np.stack([s["attn"], s["ffn"]], -1)
                   for s in aux["scores"]])          # (T, L, B', 2)
    return sc.mean(2)


def distill_gate_schedule(params, cfg: ModelConfig,
                          sched: ddim.DiffusionSchedule, *, key, labels,
                          n_steps: int, cfg_scale: float = 1.5,
                          threshold: float = 0.5,
                          target_ratio: Optional[float] = None
                          ) -> ScheduleArtifact:
    """Trained gates -> deployable schedule artifact.

    ``target_ratio=None`` thresholds the scores (the paper's inference
    rule, core.lazy.plan_from_scores); a target ratio instead picks the
    top-scoring calls (deployment's '50% lazy' knob) with endpoint
    freshness + refresh rotation."""
    scores = collect_gate_scores(params, cfg, sched, key=key, labels=labels,
                                 n_steps=n_steps, cfg_scale=cfg_scale)
    return distill_scores(
        "lazy_gate", cfg.name, scores, threshold=threshold,
        target_ratio=target_ratio,
        meta={"cfg_scale": cfg_scale, "batch": int(labels.shape[0]),
              "lazy_threshold": cfg.lazy.threshold})


# ---------------------------------------------------------------------------
# Variant (b): differentiable per-layer router (Learning-to-Cache-style)
# ---------------------------------------------------------------------------


def init_router_logits(n_steps: int, n_layers: int,
                       n_modules: int = N_MODULES,
                       init: float = -1.0) -> Array:
    """(T, L, M) router logits; ``init`` < 0 starts diligent, like the
    probes — caching must be learned, not assumed."""
    return jnp.full((n_steps, n_layers, n_modules), init, jnp.float32)


def _router_allow(n_steps: int, n_layers: int,
                  n_modules: int = N_MODULES) -> np.ndarray:
    """Trajectory endpoints are pinned fresh (the repo-wide invariant):
    the router may not even *relax* toward skipping them."""
    allow = np.ones((n_steps, n_layers, n_modules), np.float32)
    allow[0] = 0.0
    allow[-1] = 0.0
    return allow


def _build_router_step(cfg: ModelConfig, cfg_scale: float):
    """The jitted router update.  The student trajectory is the SAME
    ddim.trajectory_step both executors trace, unrolled over the (small)
    sampling horizon with a traced FLOAT plan row per step — plan-mode
    lazy execution then mixes instead of selecting (core.lazy.mix_cached),
    so gradients flow from the final latent into every gate weight."""
    from repro.models import dit as dit_lib

    pol = cache_policies.PlanPolicy(
        plan=np.zeros((1, cfg.n_layers, N_MODULES), bool))

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def step(theta, opt_state, params, sched, ts, ts_prev, z0, teacher,
             labels, noise, tau, allow, target_ratio, lam, lr,
             n_steps: int):
        B = labels.shape[0]
        BB = 2 * B if cfg_scale != 1.0 else B

        def loss_fn(theta):
            w = jax.nn.sigmoid((theta + noise) / tau) * allow   # (T, L, M)
            z = z0
            cache = dit_lib.init_dit_lazy_cache(cfg, BB)
            for i in range(n_steps):
                z, cache, _, _ = ddim.trajectory_step(
                    params, cfg, sched, pol, cfg_scale, z, labels,
                    ts[i], ts_prev[i], jnp.int32(i), cache, w[i])
            distill = jnp.mean((z - teacher) ** 2)
            ratio = jnp.mean(w)
            return distill + lam * (ratio - target_ratio) ** 2, \
                (distill, ratio)

        (loss, (distill, ratio)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(theta)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        theta, opt_state = optim.adamw_update(opt_state, grads, theta, lr=lr)
        return theta, opt_state, {"loss": loss, "distill": distill,
                                  "relaxed_ratio": ratio, "gnorm": gnorm}
    return step


def train_router(params, cfg: ModelConfig, sched: ddim.DiffusionSchedule, *,
                 n_steps: int, target_ratio: float = 0.5,
                 steps: int = 100, batch: int = 2, lr: float = 5e-2,
                 cfg_scale: float = 1.5, lam: float = 10.0,
                 tau0: float = 2.0, tau1: float = 0.25, seed: int = 0,
                 log_every: int = 0
                 ) -> Tuple[Array, List[Dict[str, float]]]:
    """Learn the static router's (T, L, M) schedule by gradient descent.

    Per update: fresh latents + labels, the no-skip TEACHER final latent
    from the fused none-policy sampler (one compile, reused every step),
    then relaxed-Bernoulli gates through the unrolled student trajectory
    with loss = ||z_student - z_teacher||^2 + lam * (ratio - target)^2.
    Temperature anneals geometrically tau0 -> tau1, hardening the gates;
    ``distill_router_schedule`` snaps them to the per-layer-quota plan
    (the static_router shape, now learned instead of calibrated)."""
    ts, ts_prev = _timestep_arrays(sched, n_steps)
    none_pol = cache_policy.get_policy("none")
    from repro.sampling import trajectory as traj_lib
    teacher_fn = traj_lib.build_sampler(cfg, none_pol, n_steps,
                                        float(cfg_scale), 0.0)
    state0 = none_pol.init_traced_state(n_steps=n_steps,
                                        n_layers=cfg.n_layers,
                                        n_modules=N_MODULES)
    step_fn = _build_router_step(cfg, float(cfg_scale))
    allow = jnp.asarray(_router_allow(n_steps, cfg.n_layers))

    theta = init_router_logits(n_steps, cfg.n_layers)
    opt = optim.adamw_init(theta)
    base_key = jax.random.PRNGKey(seed)
    history: List[Dict[str, float]] = []
    for i in range(steps):
        kz, kl, kn, kt = jax.random.split(jax.random.fold_in(base_key, i), 4)
        z0 = jax.random.normal(kz, (batch, cfg.dit_input_size,
                                    cfg.dit_input_size, cfg.dit_in_channels),
                               jnp.float32)
        labels = jax.random.randint(kl, (batch,), 0, cfg.dit_n_classes)
        teacher, _ = teacher_fn(params, sched, ts, ts_prev, z0, kt, labels,
                                None, state0)
        teacher = jax.lax.stop_gradient(teacher)
        u = jax.random.uniform(kn, theta.shape, minval=1e-6, maxval=1 - 1e-6)
        noise = jnp.log(u) - jnp.log1p(-u)           # logistic (concrete)
        tau = float(tau0 * (tau1 / tau0) ** (i / max(steps - 1, 1)))
        theta, opt, aux = step_fn(theta, opt, params, sched, ts, ts_prev,
                                  z0, teacher, labels, noise,
                                  jnp.float32(tau), allow,
                                  jnp.float32(target_ratio),
                                  jnp.float32(lam), jnp.float32(lr),
                                  n_steps=n_steps)
        history.append({k: float(v) for k, v in aux.items()})
        if log_every and (i % log_every == 0 or i == steps - 1):
            h = history[-1]
            print(f"router step {i:4d} loss {h['loss']:.5f} "
                  f"distill {h['distill']:.5f} tau {tau:.3f} "
                  f"ratio {h['relaxed_ratio']:.3f}")
    return theta, history


def distill_router_schedule(theta: Array, cfg: ModelConfig, *,
                            target_ratio: float,
                            meta: Optional[dict] = None) -> ScheduleArtifact:
    """Annealed router logits -> hard plan: sigmoid(theta) as affinities
    through the per-layer-quota distill (every layer spends the same skip
    budget per step — the Learning-to-Cache router shape)."""
    scores = np.asarray(jax.nn.sigmoid(theta), np.float64)
    scores *= _router_allow(*scores.shape)
    return distill_scores("router", cfg.name, scores,
                          target_ratio=target_ratio, per_layer=True,
                          meta=dict(meta or {}))


def _timestep_arrays(sched: ddim.DiffusionSchedule,
                     n_steps: int) -> Tuple[Array, Array]:
    from repro.sampling import trajectory as traj_lib
    return traj_lib.timestep_arrays(sched.n_train_steps, n_steps)
