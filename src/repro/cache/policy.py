"""Cache-policy interface and registry (`repro.cache`).

LazyDiT's learned gates are ONE policy for deciding when a module's
previous-step output is similar enough to reuse.  SmoothCache
(arXiv:2411.10510) shows a training-free calibrate-then-threshold rule
works too; Learning-to-Cache (arXiv:2406.01733) shows a static per-layer
router does as well.  This package makes the skip/reuse decision a
first-class object so policies compose with every executor in the repo —
DiT DDIM sampling, static-batch LLM decode, and mixed-position continuous
batching — and can be benchmarked head-to-head
(benchmarks/bench_cache_policies.py).

Execution contract (DESIGN.md §Cache): policies decide, the existing lazy
executor (core/lazy.lazy_execute) applies.  A policy declares which
executor mode carries its decisions:

  * exec_mode 'off'           — never skip (the `none` baseline);
  * exec_mode 'masked'/'soft' — the decision is *dynamic* (input-dependent)
    and lives in traced code (the learned probes); the policy carries the
    mode + threshold, and `decide` reproduces the comparison host-side;
  * exec_mode 'plan'          — the decision is *static*: the policy
    compiles a core.lazy.LazyPlan and serves per-step boolean rows; at
    trace time a static row removes the module from the HLO (the measured
    FLOP saving, `dist/hlo`).

State protocol: ``init_state`` builds a host-side dict (compiled plan,
step counter, last observed scores), ``decide``/``plan_row`` read it, and
``update_state`` advances it once per sampling/decode step.  State is
plain data so it can ride in slot-cache payloads (core/lazy slot helpers).

Traced-state protocol (the fused trajectory executor, DESIGN.md
§Trajectory): ``init_traced_state`` builds the same state as a pytree of
DEVICE arrays, ``update_traced_state`` is a pure pytree transform safe to
call inside a ``lax.scan`` body, and ``device_plan`` materializes the
compiled schedule as a (n_steps, L, M) bool device array to be SCANNED
over (one plan row per step) instead of baked in as a static jit arg —
the whole sampling loop then compiles exactly once.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lazy as lazy_lib

EXEC_MODES = ("off", "masked", "soft", "plan")


class CachePolicy:
    """Base skip/reuse policy.

    Subclasses override ``compile_plan`` (static policies) or ``decide``
    (dynamic policies).  ``module`` indices follow the repo-wide plan
    column convention: column 0 = attention, column 1 = ffn (or the whole
    block for single-module SSM/xLSTM layers).
    """

    name: str = "base"
    exec_mode: str = "plan"
    threshold: float = 0.5          # dynamic-decision threshold (probes)
    requires_gates: bool = False
    requires_calibration: bool = False

    # ------------------------------------------------------------ state
    def init_state(self, *, n_steps: int, n_layers: int,
                   n_modules: int = 2) -> Dict:
        return {"step": 0, "n_steps": n_steps,
                "plan": self.compile_plan(n_steps, n_layers, n_modules),
                "scores": None}

    def update_state(self, state: Dict, *, step: Optional[int] = None,
                     scores=None) -> Dict:
        """Advance the host-side state one step; ``scores`` is the last
        observed (layer-averaged) probe-score mapping, if any."""
        state = dict(state)
        state["step"] = (state["step"] + 1) if step is None else step + 1
        if scores is not None:
            state["scores"] = scores
        return state

    # ------------------------------------------------------------ traced state
    def init_traced_state(self, *, n_steps: int, n_layers: int,
                          n_modules: int = 2) -> Dict:
        """Policy state as a pytree of device arrays — the representation
        that rides a ``lax.scan`` carry (fused trajectory executor).
        Mirrors ``init_state``'s step counter and last-observed scores;
        the compiled plan travels separately via ``device_plan`` as a
        scanned input, not carry state."""
        return {"step": jnp.zeros((), jnp.int32),
                "scores": jnp.zeros((n_layers, n_modules), jnp.float32)}

    def update_traced_state(self, state: Dict, *, scores=None,
                            plan_row=None) -> Dict:
        """Advance the traced state one step — a PURE pytree transform
        (trace-safe: no host reads, no mutation).  ``scores`` is this
        step's (n_layers, n_modules) layer-mean probe scores when the
        executor computed any; ``plan_row`` is the (n_layers, n_modules)
        bool row the step consumed, for policies that track realized
        reuse runs."""
        state = dict(state)
        state["step"] = state["step"] + 1
        if scores is not None:
            state["scores"] = scores
        return state

    def device_plan(self, n_steps: int, n_layers: int,
                    n_modules: int = 2) -> Optional[jax.Array]:
        """The compiled schedule as an (n_steps, n_layers, n_modules) bool
        DEVICE array for scanned (traced-row) execution, or None for
        dynamic policies.  Schedules shorter/longer than ``n_steps``
        cycle rows exactly like ``plan_row`` does, so the fused executor
        consumes the same schedule the host loop serves."""
        plan = self.compile_plan(n_steps, n_layers, n_modules)
        if plan is None:
            return None
        skip = np.asarray(plan.skip, bool)
        if skip.shape[0] != n_steps:
            skip = skip[np.arange(n_steps) % skip.shape[0]]
        return jnp.asarray(skip)

    # ------------------------------------------------------------ schedule
    def plan_horizon(self, default: int) -> int:
        """Decode-schedule horizon: the policy's natural schedule length,
        falling back to ``default`` for policies with no intrinsic one.
        Serving engines cycle rows over this horizon; deriving it here
        (instead of a fixed global) keeps schedules whose length is not a
        divisor of the old fixed horizon from being truncated or
        misaligned (serving/engine.py)."""
        return default

    def compile_plan(self, n_steps: int, n_layers: int,
                     n_modules: int = 2) -> Optional[lazy_lib.LazyPlan]:
        """Full static (n_steps, n_layers, n_modules) schedule, or None for
        dynamic policies."""
        return None

    def plan_row(self, step: int, state: Optional[Dict] = None
                 ) -> Optional[np.ndarray]:
        """This step's (n_layers, n_modules) boolean skip row (static
        policies; rows cycle when the executor runs past the plan length),
        or None when the decision is dynamic."""
        plan = state.get("plan") if state else None
        if plan is None:
            return None
        return plan.skip[step % plan.skip.shape[0]]

    # ------------------------------------------------------------ decision
    def decide(self, step: int, layer: int, module: int, z=None,
               state: Optional[Dict] = None) -> bool:
        """Skip module ``module`` of layer ``layer`` at step ``step``?

        The host-side reference decision — the single place a policy's rule
        is written down.  Static policies answer from the compiled plan;
        dynamic policies answer from observed scores (or ``z`` + gate
        params when provided).  Traced executors apply the *same* rule via
        lazy_execute's mode machinery.
        """
        row = self.plan_row(step, state)
        if row is None:
            return False
        return bool(row[layer, module])

    def expected_skip_ratio(self, n_steps: int, n_layers: int,
                            n_modules: int = 2) -> float:
        """Planned fraction of gated module calls removed (0 for dynamic
        policies — their ratio is realized, not planned)."""
        plan = self.compile_plan(n_steps, n_layers, n_modules)
        return plan.lazy_ratio if plan is not None else 0.0

    def describe(self) -> Dict:
        """JSON-ready self-description — the label block obs reports and
        benches attach to a policy's rows.  Subclasses add their knobs via
        ``describe_params`` so the report says WHICH smoothcache/stride/...
        produced a curve, not just the policy family."""
        out = {"name": self.name, "exec_mode": self.exec_mode,
               "requires_gates": self.requires_gates,
               "requires_calibration": self.requires_calibration}
        params = self.describe_params()
        if params:
            out["params"] = params
        return out

    def describe_params(self) -> Dict:
        """Policy-specific knobs for describe(); JSON-serializable."""
        return {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


_REGISTRY: Dict[str, Type[CachePolicy]] = {}


def register_policy(name: str) -> Callable[[Type[CachePolicy]],
                                           Type[CachePolicy]]:
    def deco(cls: Type[CachePolicy]) -> Type[CachePolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_policy(name: str, **kwargs) -> CachePolicy:
    if name not in _REGISTRY:
        raise ValueError(f"unknown cache policy {name!r}; "
                         f"registered: {available_policies()}")
    return _REGISTRY[name](**kwargs)


# ---------------------------------------------------------------------------
# Legacy-flag bridge — the old `--lazy off|masked|plan` surface maps onto
# policies so every executor has exactly one decision path.
# ---------------------------------------------------------------------------


def from_legacy(lazy_mode: str, plan=None,
                threshold: float = 0.5) -> CachePolicy:
    """Map the pre-policy (lazy_mode, plan) calling convention onto a
    policy object.  Kept so `--lazy` CLI flags and existing call sites
    remain aliases rather than a second code path."""
    if lazy_mode == "off":
        return get_policy("none")
    if lazy_mode in ("masked", "soft"):
        return get_policy("lazy_gate", threshold=threshold,
                          soft=(lazy_mode == "soft"))
    if lazy_mode == "plan":
        if plan is None:
            raise ValueError("lazy_mode='plan' requires a plan")
        return get_policy("plan", plan=plan)
    raise ValueError(
        f"lazy_mode must be one of ('off', 'masked', 'soft', 'plan'), "
        f"got {lazy_mode!r}")


def resolve(policy=None, *, lazy_mode: str = "off", plan=None,
            threshold: float = 0.5) -> CachePolicy:
    """Normalize (policy | name | legacy flags) -> a CachePolicy instance.

    ``policy`` wins when given (a CachePolicy or registered name); the
    legacy (lazy_mode, plan) pair is the fallback alias path.
    """
    if policy is None:
        return from_legacy(lazy_mode, plan=plan, threshold=threshold)
    if isinstance(policy, str):
        if policy == "lazy_gate":
            # the caller's threshold (cfg.lazy.threshold at the executors)
            # must reach the gate policy, or the name form would decide
            # differently from the legacy 'masked' alias
            return get_policy(policy, threshold=threshold)
        if policy == "plan":
            return get_policy(policy, plan=plan)
        return get_policy(policy)
    if not isinstance(policy, CachePolicy):
        raise TypeError(f"policy must be a CachePolicy or registered name, "
                        f"got {type(policy).__name__}")
    return policy
