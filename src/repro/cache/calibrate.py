"""Calibration probe pass for training-free cache policies.

SmoothCache's (arXiv:2411.10510) key observation: the per-module
consecutive-step output error measured on ONE probe run is stable across
inputs, so a single calibration pass yields a reusable skip schedule.
This module runs that probe — a no-skip pass that still threads the lazy
cache, so every gated module's previous-step output is available — and
records, per (step, layer, module),

    rel_err[t, l, m] = ||Y_t - Y_{t-1}||_F / ||Y_{t-1}||_F   (batch mean)

with +inf on step 0 (no previous step: never skippable).  The result is a
``CalibrationArtifact``: a small JSON any policy can load (schema
documented in DESIGN.md §Cache) — `smoothcache` thresholds it directly,
`static_router` uses it as skip affinities.

Probes exist for both executors:
  * ``calibrate_dit`` — DDIM sampling of the DiT denoiser (the paper's
    setting; module axis = (attn, ffn)).
  * ``calibrate_lm``  — autoregressive decode of the generic transformer
    (our beyond-paper transfer; single-module SSM/xLSTM layers map onto
    column 1 with column 0 pinned +inf, matching the plan-column
    convention of serving/metrics.attn_like_mask).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

SCHEMA = "repro.cache.calibration/v1"
_EPS = 1e-12


@dataclass
class CalibrationArtifact:
    kind: str                    # 'dit' | 'lm'
    arch: str
    n_steps: int
    n_layers: int
    modules: Tuple[str, ...]     # plan-column names, e.g. ('attn', 'ffn')
    rel_err: np.ndarray          # (T, L, M) float64; non-finite = never skip
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        self.rel_err = np.asarray(self.rel_err, np.float64)
        expect = (self.n_steps, self.n_layers, len(self.modules))
        if self.rel_err.shape != expect:
            raise ValueError(f"rel_err shape {self.rel_err.shape} != "
                             f"(n_steps, n_layers, n_modules) {expect}")

    # ------------------------------------------------------------ transforms
    def resampled(self, n_steps: int) -> np.ndarray:
        """Nearest-step resample onto a different deployment step count."""
        if n_steps == self.n_steps:
            return self.rel_err
        idx = np.round(np.linspace(0.0, self.n_steps - 1,
                                   n_steps)).astype(int)
        return self.rel_err[idx]

    def quantile_threshold(self, q: float) -> float:
        """Error threshold skipping ~``q`` of the calibrated module calls
        (finite entries only) — the knob SmoothCache sweeps."""
        finite = self.rel_err[np.isfinite(self.rel_err)]
        if finite.size == 0:
            return 0.0
        return float(np.quantile(finite, q))

    # ------------------------------------------------------------ (de)serialize
    def to_json(self) -> dict:
        err: List = np.where(np.isfinite(self.rel_err), self.rel_err,
                             np.nan).tolist()

        def scrub(x):
            if isinstance(x, list):
                return [scrub(v) for v in x]
            return None if (x != x) else x          # NaN -> null

        return {"schema": SCHEMA, "kind": self.kind, "arch": self.arch,
                "n_steps": self.n_steps, "n_layers": self.n_layers,
                "modules": list(self.modules), "rel_err": scrub(err),
                "meta": self.meta}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def from_json(cls, obj: dict) -> "CalibrationArtifact":
        if obj.get("schema") != SCHEMA:
            raise ValueError(f"not a calibration artifact "
                             f"(schema={obj.get('schema')!r})")

        def unscrub(x):
            if isinstance(x, list):
                return [unscrub(v) for v in x]
            return np.inf if x is None else x       # null -> never skip

        return cls(kind=obj["kind"], arch=obj["arch"],
                   n_steps=obj["n_steps"], n_layers=obj["n_layers"],
                   modules=tuple(obj["modules"]),
                   rel_err=np.asarray(unscrub(obj["rel_err"]), np.float64),
                   meta=obj.get("meta", {}))

    @classmethod
    def load(cls, path: str) -> "CalibrationArtifact":
        with open(path) as f:
            return cls.from_json(json.load(f))


def _rel(cur: np.ndarray, prev: np.ndarray, axes) -> float:
    """Batch-mean relative Frobenius change between step outputs."""
    cur = cur.astype(np.float64)
    prev = prev.astype(np.float64)
    num = np.sqrt(((cur - prev) ** 2).sum(axis=axes))
    den = np.maximum(np.sqrt((prev ** 2).sum(axis=axes)), _EPS)
    return float((num / den).mean())


# ---------------------------------------------------------------------------
# DiT probe
# ---------------------------------------------------------------------------


def calibrate_dit(params: dict, cfg, sched, *, key, labels,
                  n_steps: int, cfg_scale: float = 1.0) -> CalibrationArtifact:
    """Probe a DDIM sampling trajectory: run every module (an all-False
    plan keeps the cache threaded without skipping) and profile each
    module's consecutive-step output error."""
    import numpy as _np

    from repro.core import lazy as lazy_lib
    from repro.sampling import ddim

    plan = lazy_lib.LazyPlan(np.zeros((n_steps, cfg.n_layers, 2), bool))
    _, aux = ddim.ddim_sample(params, cfg, sched, key=key, labels=labels,
                              n_steps=n_steps, cfg_scale=cfg_scale,
                              lazy_mode="plan", plan=plan.skip,
                              collect_traces=True)
    traces = aux["traces"]           # list of {"attn": (L,B,N,D), "ffn": ...}
    L = cfg.n_layers
    rel = np.full((n_steps, L, 2), np.inf)
    for t in range(1, len(traces)):
        for m, name in enumerate(("attn", "ffn")):
            cur, prev = traces[t][name], traces[t - 1][name]
            for l in range(L):
                rel[t, l, m] = _rel(_np.asarray(cur[l]),
                                    _np.asarray(prev[l]), axes=(-2, -1))
    return CalibrationArtifact(
        kind="dit", arch=cfg.name, n_steps=n_steps, n_layers=L,
        modules=("attn", "ffn"), rel_err=rel,
        meta={"cfg_scale": cfg_scale, "batch": int(labels.shape[0]),
              "sampler": "ddim"})


# ---------------------------------------------------------------------------
# LM decode probe
# ---------------------------------------------------------------------------


def _lm_layer_rows(lazy_cache, cfg, window_override) -> List[Dict[str, np.ndarray]]:
    """Flatten a decode lazy-cache tree into per-layer module dicts in the
    same layer order decode_step consumes plan rows (prefix, period
    repeats, suffix)."""
    from repro.models import transformer as tf

    specs = tf.build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = tf.factor_stack(specs)
    rows: List[Dict[str, np.ndarray]] = []
    for i in range(len(prefix)):
        rows.append({k: np.asarray(v)
                     for k, v in lazy_cache["prefix"][i].items()})
    for r in range(nrep):
        for j in range(len(period)):
            rows.append({k: np.asarray(v[r])
                         for k, v in lazy_cache["period"][j].items()})
    for i in range(len(suffix)):
        rows.append({k: np.asarray(v)
                     for k, v in lazy_cache["suffix"][i].items()})
    return rows


def calibrate_lm(params: dict, cfg, prompt: np.ndarray, n_steps: int, *,
                 window_override: Optional[int] = None) -> CalibrationArtifact:
    """Probe a greedy decode trajectory: prefill, then ``n_steps`` no-skip
    decode steps with the lazy cache threaded, profiling each gated
    module's consecutive-step output error.  Column 0 = attention (pinned
    +inf for single-module SSM/xLSTM layers), column 1 = ffn/block."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as tf

    prompt = np.asarray(prompt, np.int32)
    if prompt.ndim != 2:
        raise ValueError(f"prompt must be (B, P), got {prompt.shape}")
    B, P = prompt.shape
    max_len = P + n_steps + 1
    cache = tf.init_decode_cache(cfg, B, max_len,
                                 window_override=window_override)
    lazy_cache = tf.init_lazy_decode_cache(cfg, B,
                                           window_override=window_override)

    @jax.jit
    def _prefill(params, tokens, cache):
        logits, cache, _, _ = tf.decode_step(
            params, cfg, tokens, jnp.int32(0), cache,
            window_override=window_override)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    import functools

    @functools.partial(jax.jit, static_argnames=("first",))
    def _decode(params, tok, index, cache, lazy_cache, first):
        logits, cache, lazy_cache, _ = tf.decode_step(
            params, cfg, tok, index, cache, lazy_cache=lazy_cache,
            lazy_mode="plan", lazy_first_step=first,
            window_override=window_override)
        return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                cache, lazy_cache)

    nxt, cache = _prefill(params, jnp.asarray(prompt), cache)
    rows_prev = None
    L = cfg.n_layers
    rel = np.full((n_steps, L, 2), np.inf)
    for t in range(n_steps):
        nxt, cache, lazy_cache = _decode(params, nxt[:, None],
                                         jnp.int32(P + t), cache, lazy_cache,
                                         first=(t == 0))
        rows = _lm_layer_rows(lazy_cache, cfg, window_override)
        if rows_prev is not None:
            for l, (cur, prev) in enumerate(zip(rows, rows_prev)):
                for name, y in cur.items():
                    m = 0 if name == "attn" else 1
                    rel[t, l, m] = _rel(y, prev[name], axes=(-2, -1))
        rows_prev = rows
    return CalibrationArtifact(
        kind="lm", arch=cfg.name, n_steps=n_steps, n_layers=L,
        modules=("attn", "ffn_or_block"), rel_err=rel,
        meta={"batch": B, "prompt_len": P,
              "window_override": window_override})
