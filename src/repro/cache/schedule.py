"""Learned-schedule artifacts: the distilled form of a trained skip policy.

The training harness (train/learned.py) produces *scores* — per
(step, layer, module) laziness evidence: batch-averaged probe sigmoids for
the paper's lazy gates, annealed router gate probabilities for the
Learning-to-Cache-style router.  Deployment wants a static
``core.lazy.LazyPlan`` the fused trajectory executor and the serving
engines consume unchanged (exec_mode 'plan': skipped modules absent from
the compiled HLO).  A ``ScheduleArtifact`` records both — the learned
scores (so the plan can be re-distilled at a different ratio or step
count without retraining) and the distilled boolean plan — as a small
JSON, mirroring the calibration artifact (cache/calibrate.py) that the
training-free policies use.

    artifact = distill_scores("lazy_gate", cfg.name, scores,
                              target_ratio=0.4)
    artifact.save("artifacts/schedule_lazy_gate.json")
    pol = repro.cache.get_policy("learned", artifact=artifact)   # or path=

Schema ``repro.cache.schedule/v1`` (DESIGN.md §Train).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import lazy as lazy_lib

SCHEMA = "repro.cache.schedule/v1"

#: the trained variants a schedule artifact may record
KINDS = ("lazy_gate", "router")


@dataclass
class ScheduleArtifact:
    kind: str                    # one of KINDS — which trainer produced it
    arch: str
    n_steps: int
    n_layers: int
    modules: Tuple[str, ...]     # plan-column names, e.g. ('attn', 'ffn')
    scores: np.ndarray           # (T, L, M) learned scores in [0, 1]
    skip: np.ndarray             # (T, L, M) bool distilled plan
    threshold: float = 0.5       # only meaningful for threshold distills
    target_ratio: Optional[float] = None   # only for target-ratio distills
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"schedule kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        self.scores = np.asarray(self.scores, np.float64)
        self.skip = np.asarray(self.skip, bool)
        expect = (self.n_steps, self.n_layers, len(self.modules))
        for name, arr in (("scores", self.scores), ("skip", self.skip)):
            if arr.shape != expect:
                raise ValueError(f"{name} shape {arr.shape} != "
                                 f"(n_steps, n_layers, n_modules) {expect}")
        if self.skip[0].any():
            raise ValueError("schedule skips on step 0 (no cache exists "
                             "yet) — distillation must keep it fresh")

    # ------------------------------------------------------------ views
    def plan(self) -> lazy_lib.LazyPlan:
        return lazy_lib.LazyPlan(self.skip.copy())

    @property
    def lazy_ratio(self) -> float:
        return float(self.skip.mean())

    # ------------------------------------------------------------ (de)serialize
    def to_json(self) -> dict:
        return {"schema": SCHEMA, "kind": self.kind, "arch": self.arch,
                "n_steps": self.n_steps, "n_layers": self.n_layers,
                "modules": list(self.modules),
                "scores": self.scores.tolist(),
                "skip": self.skip.astype(int).tolist(),
                "threshold": self.threshold,
                "target_ratio": self.target_ratio,
                "meta": self.meta}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def from_json(cls, obj: dict) -> "ScheduleArtifact":
        if obj.get("schema") != SCHEMA:
            raise ValueError(f"not a schedule artifact "
                             f"(schema={obj.get('schema')!r})")
        return cls(kind=obj["kind"], arch=obj["arch"],
                   n_steps=obj["n_steps"], n_layers=obj["n_layers"],
                   modules=tuple(obj["modules"]),
                   scores=np.asarray(obj["scores"], np.float64),
                   skip=np.asarray(obj["skip"], bool),
                   threshold=float(obj.get("threshold", 0.5)),
                   target_ratio=obj.get("target_ratio"),
                   meta=obj.get("meta", {}))

    @classmethod
    def load(cls, path: str) -> "ScheduleArtifact":
        with open(path) as f:
            return cls.from_json(json.load(f))


def distill_scores(kind: str, arch: str, scores: np.ndarray, *,
                   modules: Tuple[str, ...] = ("attn", "ffn"),
                   threshold: float = 0.5,
                   target_ratio: Optional[float] = None,
                   per_layer: bool = False,
                   meta: Optional[Dict[str, Any]] = None
                   ) -> ScheduleArtifact:
    """Learned (T, L, M) scores -> a deployable ScheduleArtifact.

    Two distillation rules, matching the two training variants:
      * ``target_ratio=None`` — the paper's rule: threshold the scores
        (core.lazy.plan_from_scores; inference skips where s > 0.5).
      * ``target_ratio=r`` — deployment's knob: pick the top-scoring
        module calls to hit ratio ``r`` exactly
        (core.lazy.plan_with_target_ratio: endpoints always fresh, the
        REFRESH rotation bounds staleness).  ``per_layer=True`` adds the
        uniform per-layer quota — the Learning-to-Cache router shape.
    """
    scores = np.asarray(scores, np.float64)
    if target_ratio is None:
        plan = lazy_lib.plan_from_scores(scores, threshold=threshold)
    else:
        plan = lazy_lib.plan_with_target_ratio(scores, target_ratio,
                                               per_layer=per_layer)
    return ScheduleArtifact(
        kind=kind, arch=arch, n_steps=scores.shape[0],
        n_layers=scores.shape[1], modules=modules, scores=scores,
        skip=plan.skip, threshold=threshold, target_ratio=target_ratio,
        meta=dict(meta or {}))
