"""repro.cache — pluggable skip/reuse policy subsystem.

See policy.py for the interface/registry, policies.py for the built-in
policies (none | stride | lazy_gate | smoothcache | static_router | plan |
delta | learned), calibrate.py for the probe pass that emits the reusable
calibration artifact the training-free policies consume, and schedule.py
for the learned-schedule artifact the trained policies distill into.
DESIGN.md §Cache documents how each policy maps onto the lazy executor's
modes; DESIGN.md §Train covers the trained variants.

``calibrate`` is intentionally not imported here: it pulls in the samplers
(sampling/ddim, models/transformer), which themselves route decisions
through this package — import ``repro.cache.calibrate`` explicitly.
"""
from repro.cache.policy import (CachePolicy, available_policies,
                                from_legacy, get_policy, register_policy,
                                resolve)
from repro.cache.policies import (DeltaCachePolicy, LazyGatePolicy,
                                  LearnedSchedulePolicy, NonePolicy,
                                  PlanPolicy, SmoothCachePolicy,
                                  StaticRouterPolicy, StridePolicy,
                                  noop_plan_row)
from repro.cache.schedule import ScheduleArtifact, distill_scores

__all__ = [
    "CachePolicy", "available_policies", "from_legacy", "get_policy",
    "register_policy", "resolve",
    "DeltaCachePolicy", "LazyGatePolicy", "LearnedSchedulePolicy",
    "NonePolicy", "PlanPolicy", "SmoothCachePolicy", "StaticRouterPolicy",
    "StridePolicy", "noop_plan_row",
    "ScheduleArtifact", "distill_scores",
]
