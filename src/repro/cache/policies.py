"""Concrete cache policies.

  none          — never skip; drives exec_mode 'off' (the parity baseline).
  stride        — skip every module except on refresh steps t % stride == 0
                  (the simplest training-free baseline).
  lazy_gate     — the paper's learned linear probes (LazyDiT, AAAI 2025);
                  dynamic per-sample decisions in traced code ('masked',
                  or 'soft' for the training mixture).
  smoothcache   — SmoothCache (Liu et al., arXiv:2411.10510): training-free.
                  A probe run calibrates each module's consecutive-step
                  relative error; modules whose calibrated error stays
                  under a threshold are skipped, with a cap on consecutive
                  reuses (the staleness guard).
  static_router — Learning-to-Cache-style (Ma et al., arXiv:2406.01733)
                  static per-layer schedule: a uniform-per-layer skip quota
                  compiled into a LazyPlan from calibration (or seeded)
                  affinities.
  plan          — thin wrapper over an explicit core.lazy.LazyPlan (the
                  legacy `--lazy plan` path).
  delta         — Δ-DiT-style (Chen et al., arXiv:2406.01125) feature-
                  residual cache: skip a contiguous DEPTH BAND of blocks
                  per step, sliding rear->front across the trajectory
                  (or placed by calibrated residuals), re-adding each
                  skipped module's cached residual-branch output.
  learned       — deployable form of a TRAINED schedule
                  (cache/schedule.ScheduleArtifact from train/learned.py):
                  the distilled LazyPlan of the paper's trained lazy
                  gates or the differentiable router.

All static policies keep the first AND last steps always-fresh — the
paper's observation that trajectory endpoints are least similar across
steps (early steps shape structure; the last step is the emitted output).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.cache.policy import CachePolicy, register_policy
from repro.cache.schedule import ScheduleArtifact
from repro.core import lazy as lazy_lib


def _as_profile(calibration, what: str) -> np.ndarray:
    """CalibrationArtifact | ndarray -> (T, L, M) float rel-error profile."""
    if calibration is None:
        raise ValueError(f"{what} requires a calibration profile "
                         "(repro.cache.calibrate) or a (T, L, M) array")
    prof = getattr(calibration, "rel_err", calibration)
    prof = np.asarray(prof, np.float64)
    if prof.ndim != 3:
        raise ValueError(f"calibration profile must be (T, L, M), "
                         f"got shape {prof.shape}")
    return prof


def _resample_steps(calibration, prof: np.ndarray, n_steps: int
                    ) -> np.ndarray:
    """Resample a (T, L, M) profile onto ``n_steps`` rows (calibration and
    deployment step counts need not match).  Artifacts own the rule
    (CalibrationArtifact.resampled); the nearest-step fallback covers raw
    arrays."""
    if hasattr(calibration, "resampled"):
        return np.asarray(calibration.resampled(n_steps), np.float64)
    Tc = prof.shape[0]
    if Tc == n_steps:
        return prof
    idx = np.round(np.linspace(0.0, Tc - 1, n_steps)).astype(int)
    return prof[idx]


# ---------------------------------------------------------------------------


@register_policy("none")
class NonePolicy(CachePolicy):
    """Run everything.  The baseline every policy must token/latent-match
    at zero skip ratio."""

    exec_mode = "off"

    def decide(self, step, layer, module, z=None, state=None) -> bool:
        return False


@register_policy("stride")
class StridePolicy(CachePolicy):
    """Skip every gated module except on refresh steps (t % stride == 0),
    first/last steps always fresh.  Input- and layer-agnostic — the floor
    any calibrated or learned policy must beat at equal ratio."""

    exec_mode = "plan"

    def __init__(self, stride: int = 2):
        if stride < 2:
            raise ValueError(f"stride must be >= 2, got {stride}")
        self.stride = stride

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        skip = np.zeros((n_steps, n_layers, n_modules), bool)
        for t in range(1, n_steps - 1):
            if t % self.stride != 0:
                skip[t] = True
        return lazy_lib.LazyPlan(skip)

    def plan_horizon(self, default: int) -> int:
        # a stride-aligned horizon keeps the cycled refresh pattern
        # congruent with the t % stride rule across cycle boundaries
        return -(-default // self.stride) * self.stride

    def describe_params(self):
        return {"stride": self.stride}

    def decide(self, step, layer, module, z=None, state=None) -> bool:
        if state is not None:
            return super().decide(step, layer, module, z, state)
        return step > 0 and step % self.stride != 0


@register_policy("lazy_gate")
class LazyGatePolicy(CachePolicy):
    """LazyDiT's learned probes: s = sigmoid(mean_N(Z W + b)) per sample;
    skip when s > threshold.  The decision is input-dependent, so it runs
    inside traced code (lazy_execute modes 'masked'/'soft'); this object
    carries the mode + threshold and reproduces the rule host-side."""

    requires_gates = True

    def __init__(self, threshold: float = 0.5, soft: bool = False):
        self.threshold = float(threshold)
        self.exec_mode = "soft" if soft else "masked"

    def init_traced_state(self, *, n_steps, n_layers, n_modules=2):
        st = super().init_traced_state(n_steps=n_steps, n_layers=n_layers,
                                       n_modules=n_modules)
        # the threshold rides the carry so a scan body can reproduce
        # decide() without closing over host floats
        st["threshold"] = jnp.float32(self.threshold)
        return st

    def decide(self, step, layer, module, z=None, state=None, *,
               gate=None, score=None) -> bool:
        if step == 0:
            return False                      # no cache yet: always run
        if score is not None:
            return bool(np.asarray(score).mean() > self.threshold)
        if state is not None and state.get("scores") is not None:
            sc = np.asarray(state["scores"])
            return bool(sc[layer, module] > self.threshold)
        if gate is not None and z is not None:
            s = lazy_lib.gate_score(gate, z)
            return bool(np.asarray(s).mean() > self.threshold)
        return False                          # no information: run diligent

    def distill(self, scores: np.ndarray) -> lazy_lib.LazyPlan:
        """Batch-averaged probe scores (T, L, M) -> the calibrated static
        plan (core.lazy.plan_from_scores) for compiled deployment."""
        return lazy_lib.plan_from_scores(scores, threshold=self.threshold)

    def describe_params(self):
        return {"threshold": self.threshold, "soft": self.exec_mode == "soft"}


@register_policy("smoothcache")
class SmoothCachePolicy(CachePolicy):
    """SmoothCache (arXiv:2411.10510): training-free error-threshold rule.

    A probe run (repro.cache.calibrate) records each module's relative
    consecutive-step output error  e[t,l,m] = ||Y_t - Y_{t-1}|| / ||Y_{t-1}||.
    Module calls whose calibrated error is <= ``error_threshold`` reuse the
    cache; ``max_skip_run`` caps consecutive reuses so no cache serves
    stale outputs indefinitely (the same staleness bound the REFRESH
    rotation gives target-ratio plans)."""

    requires_calibration = True

    def __init__(self, calibration=None, error_threshold: float = 0.1,
                 max_skip_run: int = 3):
        self.calibration = calibration
        self.profile = _as_profile(calibration, "smoothcache")
        self.error_threshold = float(error_threshold)
        if max_skip_run < 1:
            raise ValueError(f"max_skip_run must be >= 1, got {max_skip_run}")
        self.max_skip_run = int(max_skip_run)

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        prof = _resample_steps(self.calibration, self.profile, n_steps)
        if prof.shape[1:] != (n_layers, n_modules):
            raise ValueError(
                f"calibration profile is (T, {prof.shape[1]}, "
                f"{prof.shape[2]}), model needs (T, {n_layers}, "
                f"{n_modules})")
        with np.errstate(invalid="ignore"):
            skip = prof <= self.error_threshold
        skip &= np.isfinite(prof)
        skip[0] = False
        skip[-1] = False
        # staleness guard: force a refresh after max_skip_run reuses
        run_len = np.zeros((n_layers, n_modules), int)
        for t in range(n_steps):
            hit = skip[t] & (run_len >= self.max_skip_run)
            skip[t] &= ~hit
            run_len = np.where(skip[t], run_len + 1, 0)
        return lazy_lib.LazyPlan(skip)

    def plan_horizon(self, default: int) -> int:
        # serve the full calibrated schedule, never a resampled slice
        return self.profile.shape[0]

    def init_traced_state(self, *, n_steps, n_layers, n_modules=2):
        st = super().init_traced_state(n_steps=n_steps, n_layers=n_layers,
                                       n_modules=n_modules)
        # threshold + realized consecutive-reuse counters ride the scan
        # carry: the staleness guard is baked into the compiled plan, but
        # the traced run_len tracks what the trajectory actually served
        # (and lets a future in-trace guard compare against max_skip_run)
        st["threshold"] = jnp.float32(self.error_threshold)
        st["run_len"] = jnp.zeros((n_layers, n_modules), jnp.int32)
        return st

    def update_traced_state(self, state, *, scores=None, plan_row=None):
        state = super().update_traced_state(state, scores=scores,
                                            plan_row=plan_row)
        if plan_row is not None:
            state["run_len"] = jnp.where(plan_row, state["run_len"] + 1, 0)
        return state

    def describe_params(self):
        return {"error_threshold": self.error_threshold,
                "max_skip_run": self.max_skip_run}


@register_policy("static_router")
class StaticRouterPolicy(CachePolicy):
    """Learning-to-Cache-style static per-layer router (arXiv:2406.01733).

    L2C learns an input-independent router choosing which layers to cache
    at each step.  The stand-in here compiles the same *shape* of schedule
    without the training loop: per-module skip affinities (low calibrated
    error -> attractive to cache; seeded uniform when no calibration is
    given) fed through core.lazy.plan_with_target_ratio's per-layer mode,
    so every layer spends the same skip quota per step."""

    def __init__(self, ratio: float = 0.5, calibration=None, seed: int = 0):
        if not 0.0 <= ratio <= 1.0:
            raise ValueError(f"ratio must be in [0, 1], got {ratio}")
        self.ratio = float(ratio)
        self.seed = int(seed)
        self.calibration = calibration
        self.profile = (None if calibration is None
                        else _as_profile(calibration, "static_router"))

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        if self.profile is not None:
            prof = _resample_steps(self.calibration, self.profile, n_steps)
            if prof.shape[1:] != (n_layers, n_modules):
                raise ValueError(
                    f"calibration profile is (T, {prof.shape[1]}, "
                    f"{prof.shape[2]}), model needs (T, {n_layers}, "
                    f"{n_modules})")
            affinity = np.where(np.isfinite(prof), -prof, -np.inf)
        else:
            rng = np.random.default_rng(self.seed)
            affinity = rng.random((n_steps, n_layers, n_modules))
        return lazy_lib.plan_with_target_ratio(affinity, self.ratio,
                                               per_layer=True)

    def plan_horizon(self, default: int) -> int:
        return self.profile.shape[0] if self.profile is not None else default

    def describe_params(self):
        return {"ratio": self.ratio, "seed": self.seed,
                "calibrated": self.profile is not None}


@register_policy("delta")
class DeltaCachePolicy(CachePolicy):
    """Δ-DiT-style feature-residual cache (arXiv:2406.01125).

    Δ-DiT caches Δ-Cache — the residual a block group ADDS to the stream
    (group output minus group input) — and re-applies the stale Δ instead
    of recomputing the group, caching REAR blocks early in the trajectory
    (when steps shape outlines) and FRONT blocks late (when they refine
    detail).  Our lazy cache already stores each module's residual-branch
    output F(Z) pre-output-gate (models/dit.py), which IS the per-module
    feature residual, so the policy reduces to a depth-banded schedule
    over the existing plan machinery: each skipping step freezes one
    contiguous band of ``width`` layers (both modules — Δ-DiT caches
    whole blocks).

    Band placement: with a calibration profile (cache/calibrate), each
    step's band is the contiguous window with the SMALLEST summed
    consecutive-step residual error — the measured "this Δ barely moved"
    signal; without one, the Δ-DiT default slides rear -> front at
    ``split`` (fraction of the trajectory at which the band flips ends).
    ``refresh`` forces full-recompute steps (t % refresh == 0) so no Δ
    serves stale features indefinitely — Δ-DiT's cache interval.  The
    traced run_len state mirrors smoothcache's, so the fused executor
    accounts realized reuse runs identically.
    """

    def __init__(self, ratio: float = 0.5, calibration=None,
                 split: float = 0.5, refresh: int = 4):
        if not 0.0 <= ratio <= 1.0:
            raise ValueError(f"ratio must be in [0, 1], got {ratio}")
        if not 0.0 <= split <= 1.0:
            raise ValueError(f"split must be in [0, 1], got {split}")
        if refresh < 2:
            raise ValueError(f"refresh must be >= 2, got {refresh}")
        self.ratio = float(ratio)
        self.split = float(split)
        self.refresh = int(refresh)
        self.calibration = calibration
        self.profile = (None if calibration is None
                        else _as_profile(calibration, "delta"))

    def _band(self, t: int, n_steps: int, n_layers: int, width: int,
              prof_row) -> slice:
        """The contiguous layer band frozen at step ``t``."""
        if width >= n_layers:
            return slice(0, n_layers)
        if prof_row is not None:
            # calibrated placement: window with the least summed residual
            # error (non-finite entries mean "never skip" -> +inf cost)
            cost = np.where(np.isfinite(prof_row), prof_row, np.inf).sum(-1)
            sums = [cost[i:i + width].sum() for i in
                    range(n_layers - width + 1)]
            start = int(np.argmin(sums))
            if not np.isfinite(sums[start]):
                return slice(0, 0)            # nothing safely skippable
            return slice(start, start + width)
        # Δ-DiT default: rear band while outlines form, front band after
        if t < self.split * n_steps:
            return slice(n_layers - width, n_layers)
        return slice(0, width)

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        skip = np.zeros((n_steps, n_layers, n_modules), bool)
        skippable = [t for t in range(1, n_steps - 1)
                     if t % self.refresh != 0]
        if self.ratio <= 0 or not skippable:
            return lazy_lib.LazyPlan(skip)
        prof = (None if self.profile is None else
                _resample_steps(self.calibration, self.profile, n_steps))
        # band width compensating for refresh holes, so the overall plan
        # ratio tracks ``ratio`` (clipped to the full depth)
        width = min(n_layers, int(round(
            self.ratio * n_steps * n_layers / len(skippable))))
        for t in skippable:
            band = self._band(t, n_steps, n_layers, width,
                              None if prof is None else prof[t])
            skip[t, band, :] = True
        return lazy_lib.LazyPlan(skip)

    def plan_horizon(self, default: int) -> int:
        # refresh-aligned horizon keeps cycled schedules congruent with
        # the t % refresh recompute rule (same reasoning as stride)
        base = (self.profile.shape[0] if self.profile is not None
                else default)
        return -(-base // self.refresh) * self.refresh

    def init_traced_state(self, *, n_steps, n_layers, n_modules=2):
        st = super().init_traced_state(n_steps=n_steps, n_layers=n_layers,
                                       n_modules=n_modules)
        st["run_len"] = jnp.zeros((n_layers, n_modules), jnp.int32)
        return st

    def update_traced_state(self, state, *, scores=None, plan_row=None):
        state = super().update_traced_state(state, scores=scores,
                                            plan_row=plan_row)
        if plan_row is not None:
            state["run_len"] = jnp.where(plan_row, state["run_len"] + 1, 0)
        return state

    def describe_params(self):
        return {"ratio": self.ratio, "split": self.split,
                "refresh": self.refresh,
                "calibrated": self.profile is not None}


@register_policy("learned")
class LearnedSchedulePolicy(CachePolicy):
    """A trained schedule, deployed.

    Wraps a ``cache/schedule.ScheduleArtifact`` — the distilled output of
    the learned-schedule harness (train/learned.py): the paper's trained
    lazy-gate probes or the differentiable per-layer router, hardened
    into a static LazyPlan.  Pass the artifact object (``artifact=``) or
    a saved JSON path (``path=``).  Exec mode is 'plan', so the fused
    trajectory executor, the serving engines and the dist/hlo FLOP
    accounting consume it exactly like any other static policy — the
    whole point of the distill step.

    Deployment step counts different from the trained horizon resample
    the stored SCORES (nearest-step, like calibration artifacts) and
    re-distill with the artifact's recorded rule, rather than crudely
    cycling plan rows — the learned evidence, not one hardening of it,
    is the durable object."""

    def __init__(self, artifact=None, path: str = ""):
        if artifact is None and not path:
            raise ValueError("learned policy needs artifact= or path=")
        if artifact is None:
            artifact = ScheduleArtifact.load(path)
        if not isinstance(artifact, ScheduleArtifact):
            raise TypeError("artifact must be a cache.schedule."
                            f"ScheduleArtifact, got {type(artifact).__name__}")
        self.artifact = artifact

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        art = self.artifact
        if (art.n_layers, len(art.modules)) != (n_layers, n_modules):
            raise ValueError(
                f"schedule artifact is (T, {art.n_layers}, "
                f"{len(art.modules)}), model needs (T, {n_layers}, "
                f"{n_modules})")
        if n_steps == art.n_steps:
            return art.plan()
        idx = np.round(np.linspace(0.0, art.n_steps - 1,
                                   n_steps)).astype(int)
        scores = art.scores[idx]
        if art.target_ratio is None:
            return lazy_lib.plan_from_scores(scores,
                                             threshold=art.threshold)
        return lazy_lib.plan_with_target_ratio(
            scores, art.target_ratio, per_layer=(art.kind == "router"))

    def plan_horizon(self, default: int) -> int:
        return self.artifact.n_steps

    def describe_params(self):
        art = self.artifact
        return {"kind": art.kind, "arch": art.arch, "n_steps": art.n_steps,
                "threshold": art.threshold, "target_ratio": art.target_ratio}


@register_policy("plan")
class PlanPolicy(CachePolicy):
    """Explicit LazyPlan wrapper — the legacy `--lazy plan` path expressed
    as a policy, so pre-built/saved plans keep working unchanged."""

    def __init__(self, plan=None):
        if plan is None:
            raise ValueError("lazy_mode='plan' requires a plan")
        skip = np.asarray(getattr(plan, "skip", plan), bool)
        if skip.ndim != 3:
            raise ValueError(
                f"plan must be (n_steps, n_layers, n_modules) bool, "
                f"got shape {skip.shape}")
        self.plan = lazy_lib.LazyPlan(skip)

    def compile_plan(self, n_steps, n_layers, n_modules=2):
        T, L, M = self.plan.skip.shape
        if (L, M) != (n_layers, n_modules):
            raise ValueError(
                f"plan must be (n_steps, {n_layers}, {n_modules}) bool, "
                f"got {self.plan.skip.shape}")
        return self.plan

    def plan_horizon(self, default: int) -> int:
        return self.plan.skip.shape[0]

    def describe_params(self):
        return {"n_steps": int(self.plan.skip.shape[0]),
                "lazy_ratio": float(self.plan.lazy_ratio)}


def noop_plan_row(n_layers: int, n_modules: int = 2) -> np.ndarray:
    """All-False plan row — the no-skip baseline for HLO comparisons."""
    return np.zeros((n_layers, n_modules), bool)
