"""Generic decoder-only transformer covering all assigned architectures.

Layer stacks are factored into ``prefix + period × n_repeats + suffix`` so
that pjit lowers a single scanned block body per periodic family — this keeps
the HLO compact enough to dry-run 104B-parameter configs on one CPU core.

Supported block kinds (see configs/base.py):
  attn_ffn, attn_moe, parallel (cohere), mamba2, mlstm, slstm
plus an optional *shared-weight* attention block injected every
``shared_attn_every`` layers (zamba2, arXiv:2411.15242).

LazyDiT gates (core/lazy.py) attach before each attention / ffn / block
module; in autoregressive decode the "previous step" is the previous decode
step (our beyond-paper transfer of the paper's diffusion-step caching).

Kernel backend (DESIGN.md §Kernels): skip/reuse selects route through
``core.lazy.lazy_execute`` and full-sequence attention through
``layers.attention_apply``, so ``--kernels pallas`` rewires this model the
same way it rewires DiT — cond-hoisted plan skips, fused masked-mode
gate+select, and (on compiled-Pallas targets) the blocked flash kernel
for prefill.  The per-slot vmapped decode path keeps its where-selects:
under a batched predicate XLA lowers ``lax.cond`` back to the same
select, so serving semantics are backend-invariant by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.models import layers as L

Array = jax.Array

# ---------------------------------------------------------------------------
# Layer specs and stack factorization
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    kind: str
    window: int                 # 0 = global attention
    shared_attn_before: bool    # zamba2: run the shared attn block first


def build_layer_specs(cfg: ModelConfig, *, window_override: Optional[int] = None
                      ) -> Tuple[LayerSpec, ...]:
    kinds = cfg.layer_kinds()
    windows = cfg.layer_windows()
    out = []
    for i in range(cfg.n_layers):
        w = windows[i]
        if window_override is not None and (w == 0 or w > window_override):
            w = window_override
        shared = bool(cfg.shared_attn_every) and (i % cfg.shared_attn_every == 0)
        out.append(LayerSpec(kinds[i], w, shared))
    return tuple(out)


def factor_stack(specs: Sequence[LayerSpec]
                 ) -> Tuple[Tuple[LayerSpec, ...], Tuple[LayerSpec, ...], int,
                            Tuple[LayerSpec, ...]]:
    """(prefix, period, n_repeats, suffix) minimizing unrolled HLO size."""
    Lz = len(specs)
    best_cost, best = Lz + 1, (tuple(specs), (), 0, ())
    for p in range(1, Lz + 1):
        for k in range(0, min(p, max(Lz - p, 0)) + 1):
            n = (Lz - k) // p
            if n < 1:
                continue
            body = specs[k:k + n * p]
            if any(body[i] != body[i - p] for i in range(p, len(body))):
                continue
            suffix = specs[k + n * p:]
            cost = k + p + len(suffix)
            if cost < best_cost or (cost == best_cost and n > best[2]):
                best_cost = cost
                best = (tuple(specs[:k]), tuple(specs[k:k + p]), n, tuple(suffix))
    return best


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------


def _attn_is_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


def init_block(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    lz = cfg.lazy
    if spec.kind in ("attn_ffn", "attn_moe", "parallel"):
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["attn"] = (L.init_mla(ks[0], cfg) if _attn_is_mla(cfg)
                     else L.init_attention(ks[0], cfg))
        if spec.kind != "parallel":
            p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
        if spec.kind == "attn_moe":
            p["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
        if lz.enabled and lz.gate_attn:
            p["g_attn"] = lazy_lib.init_lazy_gate(ks[2], cfg.d_model)
        if lz.enabled and lz.gate_ffn:
            p["g_ffn"] = lazy_lib.init_lazy_gate(ks[3], cfg.d_model)
    elif spec.kind == "mamba2":
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["mamba"] = L.init_mamba2(ks[0], cfg)
        if lz.enabled and lz.gate_ffn:
            p["g_block"] = lazy_lib.init_lazy_gate(ks[2], cfg.d_model)
    elif spec.kind == "mlstm":
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["xblock"] = L.init_mlstm(ks[0], cfg)
        if lz.enabled and lz.gate_ffn:
            p["g_block"] = lazy_lib.init_lazy_gate(ks[2], cfg.d_model)
    elif spec.kind == "slstm":
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["xblock"] = L.init_slstm(ks[0], cfg)
        if lz.enabled and lz.gate_ffn:
            p["g_block"] = lazy_lib.init_lazy_gate(ks[2], cfg.d_model)
    else:
        raise ValueError(spec.kind)
    return p


def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int) -> dict:
    c: Dict[str, Any] = {}
    if spec.kind in ("attn_ffn", "attn_moe", "parallel"):
        c["attn"] = (L.init_mla_cache(cfg, batch, max_len, spec.window)
                     if _attn_is_mla(cfg)
                     else L.init_attention_cache(cfg, batch, max_len, spec.window))
    elif spec.kind == "mamba2":
        c["ssm"] = L.init_mamba2_cache(cfg, batch)
    elif spec.kind == "mlstm":
        c["ssm"] = L.init_mlstm_cache(cfg, batch)
    elif spec.kind == "slstm":
        c["ssm"] = L.init_slstm_cache(cfg, batch)
    if spec.shared_attn_before and cfg.shared_attn_every:
        # the shared block shares *weights* across invocations, but each
        # invocation sees different activations -> its own KV cache.
        c["shared_attn"] = L.init_attention_cache(cfg, batch, max_len,
                                                  spec.window)
    return c



def init_block_lazy_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                          seq: int) -> dict:
    """Previous-step module outputs (the LazyDiT cache)."""
    dt = jnp.dtype(cfg.dtype)
    z = jnp.zeros((batch, seq, cfg.d_model), dt)
    if spec.kind in ("attn_ffn", "attn_moe", "parallel"):
        return {"attn": z, "ffn": z}
    return {"block": z}


_ZERO_SCORES = ("attn", "ffn", "block")


def _empty_scores(batch: int) -> Dict[str, Array]:
    return {k: jnp.zeros((batch,), jnp.float32) for k in _ZERO_SCORES}


def apply_block(params: dict, cfg: ModelConfig, spec: LayerSpec, x: Array, *,
                cos: Array, sin: Array,
                cache: Optional[dict] = None,
                decode_index: Optional[Array] = None,
                shared_attn: Optional[dict] = None,
                lazy_cache: Optional[dict] = None,
                lazy_mode: str = "off",
                plan: Tuple = (False, False),
                prime: bool = False,
                fresh: Optional[Array] = None,
                policy=None,
                ) -> Tuple[Array, dict, dict, Dict[str, Array], Array]:
    """One decoder block.  Returns
    (x, new_cache, new_lazy_cache, scores, aux_loss).

    ``plan`` entries are static bools (unrolled plan mode: skipped modules
    vanish from the HLO) or traced boolean arrays (mixed-position serving:
    per-slot ``where`` select, see DESIGN.md §Serve).  ``fresh`` is a
    per-sample bool — slots whose lazy cache was reset this step never
    serve it, the per-slot analogue of the static ``prime`` flag.
    ``policy`` (repro.cache.CachePolicy) supplies mode + threshold when
    given; ``lazy_mode`` is the legacy alias path."""
    if policy is not None:
        lazy_mode = policy.exec_mode
    B = x.shape[0]
    aux = jnp.zeros((), jnp.float32)
    scores = _empty_scores(B)
    new_cache: Dict[str, Any] = {}
    new_lazy: Dict[str, Any] = dict(lazy_cache) if lazy_cache else {}
    lz = cfg.lazy

    if spec.shared_attn_before and shared_attn is not None:
        h = L.rmsnorm_apply(shared_attn["norm"], x, cfg.norm_eps)
        y, nsc = L.attention_apply(
            shared_attn["attn"], cfg, h, cos=cos, sin=sin, window=spec.window,
            cache=cache.get("shared_attn") if cache else None,
            decode_index=decode_index)
        if nsc is not None:
            new_cache["shared_attn"] = nsc
        x = x + y

    def run_gated(name: str, gate_key: str, z: Array, fn):
        nonlocal aux
        gate = params.get(gate_key)
        cache_y = (new_lazy.get(name)
                   if (lazy_cache is not None and not prime) else None)
        p_entry = plan[0] if name == "attn" else plan[1]
        if prime:
            p_entry = False
        out = lazy_lib.lazy_execute(
            fn, z, gate=gate, cache_y=cache_y, mode=lazy_mode,
            threshold=lz.threshold, plan_skip=p_entry, fresh=fresh,
            policy=policy)
        if lazy_cache is not None:
            new_lazy[name] = out.new_cache
        if out.score is not None:
            scores[name if name in scores else "block"] = out.score
        return out.y

    # compile-time attention skip (+ mandatory KV write) only for STATIC
    # plans; traced per-slot plans go through run_gated's where-select.
    plan_skip_attn = (lazy_mode == "plan"
                      and not isinstance(plan[0], jax.Array)
                      and bool(plan[0]) and not prime
                      and lazy_cache is not None)

    if spec.kind in ("attn_ffn", "attn_moe"):
        z1 = L.rmsnorm_apply(params["norm1"], x, cfg.norm_eps)

        def attn_fn(z):
            nonlocal new_cache
            if _attn_is_mla(cfg):
                y, nc = L.mla_apply(params["attn"], cfg, z, cos=cos, sin=sin,
                                    window=spec.window, cache=cache.get("attn") if cache else None,
                                    decode_index=decode_index)
            else:
                y, nc = L.attention_apply(params["attn"], cfg, z, cos=cos, sin=sin,
                                          window=spec.window,
                                          cache=cache.get("attn") if cache else None,
                                          decode_index=decode_index)
            if nc is not None:
                new_cache["attn"] = nc
            return y

        if plan_skip_attn and cache is not None:
            # lazy plan skips the module but the KV write must still land
            # (AR-decode correctness; see layers.attention_kv_write).
            kv_write = L.mla_kv_write if _attn_is_mla(cfg) else L.attention_kv_write
            new_cache["attn"] = kv_write(params["attn"], cfg, z1, cos=cos,
                                         sin=sin, cache=cache["attn"],
                                         decode_index=decode_index)
            x = x + new_lazy["attn"]
        else:
            x = x + run_gated("attn", "g_attn", z1, attn_fn)
        z2 = L.rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if spec.kind == "attn_moe":
            def ffn_fn(z):
                nonlocal aux
                y, a = L.moe_apply(params["moe"], cfg, z, cfg.act)
                aux = aux + a
                return y
        else:
            def ffn_fn(z):
                return L.mlp_apply(params["ffn"], z, cfg.act)
        x = x + run_gated("ffn", "g_ffn", z2, ffn_fn)

    elif spec.kind == "parallel":
        # cohere/command-r: attn and ffn in parallel off one norm
        z1 = L.rmsnorm_apply(params["norm1"], x, cfg.norm_eps)

        def attn_fn(z):
            nonlocal new_cache
            y, nc = L.attention_apply(params["attn"], cfg, z, cos=cos, sin=sin,
                                      window=spec.window,
                                      cache=cache.get("attn") if cache else None,
                                      decode_index=decode_index)
            if nc is not None:
                new_cache["attn"] = nc
            return y

        def ffn_fn(z):
            return L.mlp_apply(params["ffn"], z, cfg.act)

        x = x + run_gated("attn", "g_attn", z1, attn_fn) \
              + run_gated("ffn", "g_ffn", z1, ffn_fn)

    elif spec.kind in ("mamba2", "mlstm", "slstm"):
        z1 = L.rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
        apply = {"mamba2": L.mamba2_apply, "mlstm": L.mlstm_apply,
                 "slstm": L.slstm_apply}[spec.kind]
        pkey = "mamba" if spec.kind == "mamba2" else "xblock"

        def blk_fn(z):
            nonlocal new_cache
            y, nc = apply(params[pkey], cfg, z,
                          cache=cache.get("ssm") if cache else None)
            if nc is not None:
                new_cache["ssm"] = nc
            return y

        # NOTE (DESIGN.md §Arch-applicability): the lazy skip gates the block
        # *output*; the recurrent state must advance even on skip, so in
        # masked/soft modes the block still runs (state side effect) and only
        # the output mixes.  In plan mode a skipped step freezes the state —
        # recorded as an approximation in EXPERIMENTS.md.
        x = x + run_gated("block", "g_block", z1, blk_fn)
    else:
        raise ValueError(spec.kind)

    # passthrough caches for modules that did not update (plan-skip case)
    if cache is not None:
        for k, v in cache.items():
            new_cache.setdefault(k, v)
    return x, new_cache, new_lazy, scores, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig, *, window_override: Optional[int] = None) -> dict:
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    if cfg.frontend_dim:
        params["frontend_proj"] = L.dense_init(keys[2], cfg.frontend_dim,
                                               cfg.d_model, dt)
    if cfg.shared_attn_every:
        params["shared_attn"] = {
            "norm": L.init_rmsnorm(cfg.d_model, dt),
            "attn": L.init_attention(keys[3], cfg),
        }
    pkeys = jax.random.split(keys[4], max(len(prefix), 1))
    params["prefix"] = tuple(init_block(pkeys[i], cfg, s)
                             for i, s in enumerate(prefix))
    if nrep:
        period_params = []
        for j, s in enumerate(period):
            rkeys = jax.random.split(jax.random.fold_in(keys[5], j), nrep)
            period_params.append(jax.vmap(lambda k: init_block(k, cfg, s))(rkeys))
        params["period"] = tuple(period_params)
    else:
        params["period"] = ()
    skeys = jax.random.split(keys[6], max(len(suffix), 1))
    params["suffix"] = tuple(init_block(skeys[i], cfg, s)
                             for i, s in enumerate(suffix))
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _rope_dim(cfg: ModelConfig) -> int:
    return cfg.mla.qk_rope_head_dim if cfg.mla else cfg.resolved_head_dim


def _rope_tables(cfg: ModelConfig, positions: Array) -> Tuple[Array, Array]:
    return L.rope_cos_sin(positions, _rope_dim(cfg), cfg.rope_theta,
                          cfg.mrope_sections if cfg.rope_type == "mrope" else ())


def embed_inputs(params: dict, cfg: ModelConfig, tokens: Optional[Array],
                 embeds: Optional[Array]) -> Array:
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
        if tokens is not None:
            x = jnp.concatenate([x, params["embed"][tokens]], axis=1)
        return x
    return params["embed"][tokens]


def forward(params: dict, cfg: ModelConfig, *,
            tokens: Optional[Array] = None,
            embeds: Optional[Array] = None,
            positions: Optional[Array] = None,
            window_override: Optional[int] = None,
            remat: bool = False,
            return_hidden: bool = False,
            carry_sharding=None) -> Tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits | final hidden, aux_loss).

    ``carry_sharding``: optional PartitionSpec applied to the layer-boundary
    activations (Megatron-style sequence parallelism: shard S over the
    ``model`` axis between blocks so remat storage is 1/TP of the naive
    layout; see dist/sharding.py)."""
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    x = embed_inputs(params, cfg, tokens, embeds)
    B, S, D = x.shape

    def constrain(h):
        if carry_sharding is not None:
            return jax.lax.with_sharding_constraint(h, carry_sharding)
        return h
    if positions is None:
        if cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(jnp.arange(S)[None, None],
                                         (len(cfg.mrope_sections), B, S))
        else:
            positions = jnp.arange(S)
    cos, sin = _rope_tables(cfg, positions)
    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")

    def run(p, spec, x):
        return apply_block(p, cfg, spec, x, cos=cos, sin=sin,
                           shared_attn=shared)

    for p, spec in zip(params["prefix"], prefix):
        x, _, _, _, aux = run(p, spec, x)
        aux_total += aux

    if nrep:
        def body(carry, layer_params):
            x, aux_acc = carry
            for j, spec in enumerate(period):
                x, _, _, _, a = run(layer_params[j], spec, x)
                aux_acc = aux_acc + a
            return (constrain(x), aux_acc), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = lax.scan(body_fn, (constrain(x), aux_total),
                                     params["period"])

    for p, spec in zip(params["suffix"], suffix):
        x, _, _, _, aux = run(p, spec, x)
        aux_total += aux

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return logits, aux_total


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                      window_override: Optional[int] = None) -> dict:
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    cache: Dict[str, Any] = {
        "prefix": tuple(init_block_cache(cfg, s, batch, max_len) for s in prefix),
        "suffix": tuple(init_block_cache(cfg, s, batch, max_len) for s in suffix),
    }
    if nrep:
        cache["period"] = tuple(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (nrep,) + a.shape).copy()
                         if hasattr(a, "shape") else a,
                         init_block_cache(cfg, s, batch, max_len))
            for s in period)
    else:
        cache["period"] = ()
    return cache


def init_lazy_decode_cache(cfg: ModelConfig, batch: int, *,
                           window_override: Optional[int] = None) -> dict:
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    lc: Dict[str, Any] = {
        "prefix": tuple(init_block_lazy_cache(cfg, s, batch, 1) for s in prefix),
        "suffix": tuple(init_block_lazy_cache(cfg, s, batch, 1) for s in suffix),
    }
    if nrep:
        lc["period"] = tuple(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (nrep,) + a.shape).copy(),
                         init_block_lazy_cache(cfg, s, batch, 1))
            for s in period)
    else:
        lc["period"] = ()
    return lc


def decode_step(params: dict, cfg: ModelConfig, tokens: Array, index: Array,
                cache: dict, *,
                embeds: Optional[Array] = None,
                lazy_cache: Optional[dict] = None,
                lazy_mode: str = "off",
                lazy_first_step: bool = False,
                fresh: Optional[Array] = None,
                plan_row: Optional[Array] = None,
                window_override: Optional[int] = None,
                last_logit_only: bool = False,
                policy=None,
                ) -> Tuple[Array, dict, Optional[dict], Dict[str, Array]]:
    """One serving step.

    Decode: ``tokens`` (B, 1) at absolute position ``index`` -> logits (B,1,V).
    Prefill: ``tokens`` (B, S>1) with ``index == 0`` against a *fresh* cache —
    fills every layer cache in one pass and returns (B, S, V) logits.

    Lazy modes use the previous *decode step*'s module outputs as the cache
    (beyond-paper transfer; DESIGN.md §4).

    ``plan_row``: traced (n_layers, 2) bool — this step's plan-mode skips,
    applied as per-sample where-selects (serving path; the unrolled
    compile-time plan lives in decode_step_unrolled).  ``fresh``: per-sample
    bool, suppresses lazy-cache reuse for just-admitted slots.
    ``policy``: cache policy (repro.cache) supplying mode + threshold;
    ``lazy_mode`` is the legacy alias when absent."""
    if policy is not None:
        lazy_mode = policy.exec_mode
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    x = embed_inputs(params, cfg, tokens, embeds)
    B, S = x.shape[0], x.shape[1]
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to((index + jnp.arange(S))[None, None, :],
                               (len(cfg.mrope_sections), B, S))
    else:
        pos = index + jnp.arange(S)
    cos, sin = _rope_tables(cfg, pos)
    shared = params.get("shared_attn")
    new_cache: Dict[str, Any] = {"prefix": [], "suffix": [], "period": ()}
    new_lazy: Dict[str, Any] = {"prefix": [], "suffix": [], "period": ()} \
        if lazy_cache is not None else None
    all_scores = []

    def run(p, spec, x, c, lzc, pl=None):
        return apply_block(
            p, cfg, spec, x, cos=cos, sin=sin, cache=c, decode_index=index,
            shared_attn=shared, lazy_cache=lzc, lazy_mode=lazy_mode,
            prime=lazy_first_step, fresh=fresh, policy=policy,
            plan=(pl[0], pl[1]) if pl is not None else (False, False))

    n_pre, n_per = len(prefix), len(period)
    for i, (p, spec) in enumerate(zip(params["prefix"], prefix)):
        lzc = lazy_cache["prefix"][i] if lazy_cache else None
        pl = plan_row[i] if plan_row is not None else None
        x, nc, nlz, sc, _ = run(p, spec, x, cache["prefix"][i], lzc, pl)
        new_cache["prefix"].append(nc)
        if new_lazy is not None:
            new_lazy["prefix"].append(nlz)
        all_scores.append(sc)

    if nrep:
        def body(x, xs):
            layer_params, layer_cache, layer_lazy, pr = xs
            ncs, nlzs, scs = [], [], []
            for j, spec in enumerate(period):
                lzc = layer_lazy[j] if layer_lazy is not None else None
                pl = pr[j] if pr is not None else None
                x, nc, nlz, sc, _ = run(layer_params[j], spec, x,
                                        layer_cache[j], lzc, pl)
                ncs.append(nc)
                nlzs.append(nlz)
                scs.append(sc)
            return x, (tuple(ncs), tuple(nlzs), tuple(scs))

        lazy_xs = (lazy_cache["period"] if lazy_cache is not None
                   else tuple(None for _ in period))
        plan_xs = (plan_row[n_pre:n_pre + nrep * n_per].reshape(nrep, n_per, -1)
                   if plan_row is not None else None)
        x, (pcache, plazy, pscores) = lax.scan(
            body, x, (params["period"], cache["period"], lazy_xs, plan_xs))
        new_cache["period"] = pcache
        if new_lazy is not None:
            new_lazy["period"] = plazy
        for j in range(len(period)):
            # pscores[j][k] has a leading (nrep,) dim from the scan
            all_scores.append({k: jnp.mean(v, axis=0)
                               for k, v in pscores[j].items()})

    for i, (p, spec) in enumerate(zip(params["suffix"], suffix)):
        lzc = lazy_cache["suffix"][i] if lazy_cache else None
        pl = (plan_row[n_pre + nrep * n_per + i]
              if plan_row is not None else None)
        x, nc, nlz, sc, _ = run(p, spec, x, cache["suffix"][i], lzc, pl)
        new_cache["suffix"].append(nc)
        if new_lazy is not None:
            new_lazy["suffix"].append(nlz)
        all_scores.append(sc)

    new_cache["prefix"] = tuple(new_cache["prefix"])
    new_cache["suffix"] = tuple(new_cache["suffix"])
    if new_lazy is not None:
        new_lazy["prefix"] = tuple(new_lazy["prefix"])
        new_lazy["suffix"] = tuple(new_lazy["suffix"])

    if last_logit_only:
        x = x[:, -1:]
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    scores = {}
    if all_scores:
        scores = {k: jnp.stack([s[k] for s in all_scores]).mean(0)
                  for k in all_scores[0]}
    return logits, new_cache, new_lazy, scores


def decode_step_mixed(params: dict, cfg: ModelConfig, tokens: Array,
                      index: Array, cache: dict, *,
                      lazy_cache: Optional[dict] = None,
                      lazy_mode: str = "off",
                      fresh: Optional[Array] = None,
                      plan_rows: Optional[Array] = None,
                      window_override: Optional[int] = None,
                      policy=None,
                      ) -> Tuple[Array, dict, Optional[dict], Dict[str, Array]]:
    """Mixed-position decode over a slot pool (continuous batching).

    Retires the static engine's shared-position-counter assumption: every
    slot carries its own absolute position, ring-buffer ``pos`` vector, and
    lazy cache, implemented as ``jax.vmap`` of the single-sequence
    ``decode_step`` over the slot axis.

      tokens:    (B,) int32 — current input token per slot
      index:     (B,) int32 — absolute decode position per slot
      cache:     slot-stacked decode cache, leaves (B, *single_leaf)
                 (build with lazy.stack_for_slots over a batch-1 cache)
      lazy_cache: slot-stacked lazy cache or None
      fresh:     (B,) bool — slot admitted this step; its (zeroed) lazy
                 cache is never served (per-slot analogue of the static
                 prime flag)
      plan_rows: (B, n_layers, 2) bool — each slot's CURRENT plan row
                 (slots sit at different request steps, so plan booleans
                 are per-slot traced values; DESIGN.md §Serve)

    Returns (logits (B, 1, V), new_cache, new_lazy, scores {(B,)}).
    """
    def one(tok, idx, c, lzc, fr, pr):
        return decode_step(params, cfg, tok[None, None], idx, c,
                           lazy_cache=lzc, lazy_mode=lazy_mode,
                           fresh=fr, plan_row=pr, policy=policy,
                           window_override=window_override)

    axes = (0, 0, 0,
            0 if lazy_cache is not None else None,
            0 if fresh is not None else None,
            0 if plan_rows is not None else None)
    logits, new_cache, new_lazy, scores = jax.vmap(one, in_axes=axes)(
        tokens, index, cache, lazy_cache, fresh, plan_rows)
    # strip the inner batch-1 axis the vmap wrapped: (B, 1, 1, V) -> (B, 1, V)
    return (logits[:, 0], new_cache, new_lazy,
            {k: v[:, 0] for k, v in scores.items()})


def decode_step_unrolled(params: dict, cfg: ModelConfig, tokens: Array,
                         index: Array, cache: dict, lazy_cache: dict, *,
                         plan_step,
                         window_override: Optional[int] = None,
                         ) -> Tuple[Array, dict, dict]:
    """Plan-mode serving step: layers unrolled so per-(layer, module) static
    booleans remove skipped modules from the compiled HLO (LazyDiT's compute
    saving, visible in cost analysis — DESIGN.md §3 'plan' mode).

    plan_step: (n_layers, 2) bool array for THIS decode step (attn, ffn).
    Skipped attention still writes KV (layers.attention_kv_write)."""
    specs = build_layer_specs(cfg, window_override=window_override)
    prefix, period, nrep, suffix = factor_stack(specs)
    x = params["embed"][tokens]
    B, S = x.shape[0], x.shape[1]
    pos = index + jnp.arange(S)
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(pos[None, None, :],
                               (len(cfg.mrope_sections), B, S))
    cos, sin = _rope_tables(cfg, pos)
    shared = params.get("shared_attn")

    def at(tree, i):
        return jax.tree.map(lambda a: a[i], tree)

    # enumerate (layer_params, spec, cache, lazy, writeback_fn)
    new_cache = jax.tree.map(lambda a: a, cache)
    new_lazy = jax.tree.map(lambda a: a, lazy_cache)
    li = 0
    plan_step = np.asarray(plan_step)

    def run(p, spec, x, c, lz, plan):
        return apply_block(p, cfg, spec, x, cos=cos, sin=sin, cache=c,
                           decode_index=index, shared_attn=shared,
                           lazy_cache=lz, lazy_mode="plan",
                           plan=(bool(plan[0]), bool(plan[1])))

    for i, spec in enumerate(prefix):
        x, nc, nlz, _, _ = run(params["prefix"][i], spec, x,
                               cache["prefix"][i], lazy_cache["prefix"][i],
                               plan_step[li])
        new_cache["prefix"] = tuple(nc if j == i else new_cache["prefix"][j]
                                    for j in range(len(prefix)))
        new_lazy["prefix"] = tuple(nlz if j == i else new_lazy["prefix"][j]
                                   for j in range(len(prefix)))
        li += 1

    if nrep:
        pc = [list() for _ in period]
        plz = [list() for _ in period]
        for r in range(nrep):
            for j, spec in enumerate(period):
                x, nc, nlz, _, _ = run(at(params["period"][j], r), spec, x,
                                       at(cache["period"][j], r),
                                       at(lazy_cache["period"][j], r),
                                       plan_step[li])
                pc[j].append(nc)
                plz[j].append(nlz)
                li += 1
        new_cache["period"] = tuple(
            jax.tree.map(lambda *xs: jnp.stack(xs), *pc[j])
            for j in range(len(period)))
        new_lazy["period"] = tuple(
            jax.tree.map(lambda *xs: jnp.stack(xs), *plz[j])
            for j in range(len(period)))

    for i, spec in enumerate(suffix):
        x, nc, nlz, _, _ = run(params["suffix"][i], spec, x,
                               cache["suffix"][i], lazy_cache["suffix"][i],
                               plan_step[li])
        new_cache["suffix"] = tuple(nc if j == i else new_cache["suffix"][j]
                                    for j in range(len(suffix)))
        new_lazy["suffix"] = tuple(nlz if j == i else new_lazy["suffix"][j]
                                   for j in range(len(suffix)))
        li += 1

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return logits, new_cache, new_lazy


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params)
               if hasattr(x, "size"))
