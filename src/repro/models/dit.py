"""DiT (Peebles & Xie 2023) with LazyDiT gates — the paper's model family.

adaLN-zero blocks; a lazy probe sits before each MHSA and each pointwise
feedforward module and reads the *modulated* input Z = scale∘LN(x) + shift,
exactly the paper's cut point ("input scale, input shift, output gate and
residual connections remain unchanged").

The lazy cache stores the raw module outputs F(Z) (pre-output-gate); the
sampler threads it across diffusion steps.

Kernel backend (DESIGN.md §Kernels): every skip/reuse select below routes
through ``core.lazy.lazy_execute``, so selecting ``--kernels pallas``
transparently rewires this model — traced plan bits become runtime
``lax.cond`` early-exits (and, on compiled-Pallas targets, the
scalar-prefetched ``flash_attention_lazy`` kernel behind
``layers.attention_apply``), and masked-mode probes run the fused
gate+select kernel.  Nothing in this file branches on the backend.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lazy as lazy_lib
from repro.models import layers as L

Array = jax.Array

# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def timestep_embedding(t: Array, dim: int, max_period: float = 10000.0) -> Array:
    """Sinusoidal timestep embedding, f32.  t: (B,) float or int."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def pos_embed_2d(n_side: int, dim: int) -> np.ndarray:
    """Fixed 2-D sincos position embedding (DiT uses this, not learned)."""
    def emb_1d(pos, d):
        omega = np.arange(d // 2, dtype=np.float64) / (d / 2.0)
        omega = 1.0 / 10000 ** omega
        out = np.einsum("p,d->pd", pos, omega)
        return np.concatenate([np.sin(out), np.cos(out)], axis=1)

    grid = np.arange(n_side, dtype=np.float64)
    gy, gx = np.meshgrid(grid, grid, indexing="ij")
    e = np.concatenate([emb_1d(gy.reshape(-1), dim // 2),
                        emb_1d(gx.reshape(-1), dim // 2)], axis=1)
    return e.astype(np.float32)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_dit(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    p2c = cfg.dit_patch ** 2 * cfg.dit_in_channels
    n_side = cfg.dit_input_size // cfg.dit_patch
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    params = {
        "patch_embed": {"w": L.dense_init(ks[0], p2c, d, dt),
                        "b": jnp.zeros((d,), dt)},
        "pos_embed": jnp.asarray(pos_embed_2d(n_side, d), dt),
        "t_mlp": {"w1": L.dense_init(ks[1], 256, d, dt),
                  "b1": jnp.zeros((d,), dt),
                  "w2": L.dense_init(ks[2], d, d, dt),
                  "b2": jnp.zeros((d,), dt)},
        # +1 slot: the CFG null label
        "y_embed": L.embed_init(ks[3], cfg.dit_n_classes + 1, d, dt),
        "final": {
            "mod": {"w": jnp.zeros((d, 2 * d), dt), "b": jnp.zeros((2 * d,), dt)},
            "w": jnp.zeros((d, cfg.dit_patch ** 2 * cfg.dit_in_channels * 2), dt),
            "b": jnp.zeros((cfg.dit_patch ** 2 * cfg.dit_in_channels * 2,), dt),
        },
    }

    def init_dit_block(bk):
        bks = jax.random.split(bk, 4)
        blk = {
            "attn": L.init_attention(bks[0], cfg),
            # DiT uses a plain GELU MLP (fc1 -> gelu -> fc2), not a gated one
            "mlp": {"w1": L.dense_init(bks[1], d, cfg.d_ff, dt),
                    "b1": jnp.zeros((cfg.d_ff,), dt),
                    "w2": L.dense_init(jax.random.fold_in(bks[1], 1),
                                       cfg.d_ff, d, dt),
                    "b2": jnp.zeros((d,), dt)},
            # adaLN-zero: modulation projection zero-init (output gates start 0)
            "mod": {"w": jnp.zeros((d, 6 * d), dt), "b": jnp.zeros((6 * d,), dt)},
        }
        if cfg.lazy.enabled:
            if cfg.lazy.gate_attn:
                blk["g_attn"] = lazy_lib.init_lazy_gate(bks[2], d)
            if cfg.lazy.gate_ffn:
                blk["g_ffn"] = lazy_lib.init_lazy_gate(bks[3], d)
        return blk

    bkeys = jax.random.split(ks[4], cfg.n_layers)
    params["blocks"] = jax.vmap(init_dit_block)(bkeys)
    return params


# ---------------------------------------------------------------------------
# Patching
# ---------------------------------------------------------------------------


def patchify(x: Array, patch: int) -> Array:
    """(B, H, W, C) -> (B, N, patch*patch*C)."""
    B, H, W, C = x.shape
    p = patch
    x = x.reshape(B, H // p, p, W // p, p, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // p) * (W // p), p * p * C)


def unpatchify(x: Array, patch: int, n_side: int, channels: int) -> Array:
    B, N, _ = x.shape
    p = patch
    x = x.reshape(B, n_side, n_side, p, p, channels)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, n_side * p, n_side * p, channels)


def _modulate(x: Array, shift: Array, scale: Array) -> Array:
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block_apply(blk, cfg: ModelConfig, x: Array, c: Array, *,
                 lazy_cache: Optional[dict], lazy_mode: str,
                 plan: Tuple = (False, False),
                 prime: bool = False,
                 fresh: Optional[Array] = None,
                 policy=None):
    """One DiT block.  ``prime=True`` (first sampling step, host loop): run
    every module but record outputs into the lazy cache.  Returns
    (x, new_lazy, scores).

    ``plan`` entries are static bools (host loop: skipped modules vanish
    from the compiled HLO) or traced boolean scalars (fused trajectory
    executor: per-step plan rows arrive as scanned device values and apply
    as where-selects, core.lazy.select_cached).  ``fresh`` is the traced
    first-step analogue of the static ``prime`` flag — a just-initialized
    lazy cache is never served (DESIGN.md §Trajectory).

    ``policy`` (repro.cache.CachePolicy) is the skip-decision authority
    when given — it supplies the lazy-execution mode and threshold; the
    bare ``lazy_mode`` arg is the legacy alias path."""
    if policy is not None:
        lazy_mode = policy.exec_mode
    mod = jax.nn.silu(c) @ blk["mod"]["w"] + blk["mod"]["b"]       # (B, 6D)
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)

    new_lazy = dict(lazy_cache) if lazy_cache else {}
    scores = {}

    def gated(name: str, gate_key: str, z: Array, fn, plan_skip):
        cache_y = None
        if lazy_cache is not None and not prime:
            cache_y = lazy_cache.get(name)
        out = lazy_lib.lazy_execute(
            fn, z, gate=blk.get(gate_key), cache_y=cache_y, mode=lazy_mode,
            threshold=cfg.lazy.threshold,
            plan_skip=False if prime else plan_skip,
            fresh=fresh, policy=policy)
        if lazy_cache is not None:
            new_lazy[name] = out.new_cache
        if out.score is not None:
            scores[name] = out.score
        return out.y

    z1 = _modulate(L.layernorm_apply({}, x, 1e-6), sh1, sc1)       # paper's Z
    y = gated("attn", "g_attn", z1,
              lambda z: L.attention_apply(blk["attn"], cfg, z, cos=None,
                                          sin=None, window=0, causal=False)[0],
              plan[0])
    x = x + g1[:, None, :] * y

    z2 = _modulate(L.layernorm_apply({}, x, 1e-6), sh2, sc2)

    def dit_mlp(z):
        h = jax.nn.gelu(z @ blk["mlp"]["w1"] + blk["mlp"]["b1"])
        return h @ blk["mlp"]["w2"] + blk["mlp"]["b2"]

    y = gated("ffn", "g_ffn", z2, dit_mlp, plan[1])
    x = x + g2[:, None, :] * y
    return x, new_lazy, scores


def dit_forward(params: dict, cfg: ModelConfig, x: Array, t: Array, y: Array, *,
                lazy_cache: Optional[dict] = None,
                lazy_mode: str = "off",
                plan_row=None,
                first_step: bool = False,
                fresh: Optional[Array] = None,
                policy=None,
                ) -> Tuple[Array, Optional[dict], Dict[str, Array]]:
    """One denoiser evaluation.

    x: (B, H, W, C) latent; t: (B,) timesteps; y: (B,) labels
    (cfg.dit_n_classes = null token for CFG-unconditional rows).

    lazy_cache: {"attn": (L,B,N,D), "ffn": (L,B,N,D)} previous-step module
    outputs, or None on the first sampling step.
    plan_row: (L, 2) booleans for 'plan' mode (unrolled layers) — a host
    array compiles skips out of the HLO (the host debug loop), a traced
    device array applies them as where-selects (the fused trajectory
    executor, which scans rows over steps at ONE compile).
    first_step: static first-step flag (host loop: prime the cache).
    fresh: traced first-step flag (fused executor: the scan body can't
    branch on the step, so a just-initialized cache is masked instead).
    policy: cache policy (repro.cache) supplying the execution mode and
    threshold; ``lazy_mode`` is the legacy alias when absent.
    Returns (eps_and_sigma (B,H,W,2C), new_lazy_cache, scores (L,B) per module).
    """
    if policy is not None:
        lazy_mode = policy.exec_mode
    p = cfg.dit_patch
    n_side = cfg.dit_input_size // p
    tok = patchify(x, p).astype(jnp.dtype(cfg.dtype))
    h = tok @ params["patch_embed"]["w"] + params["patch_embed"]["b"]
    h = h + params["pos_embed"][None]

    te = timestep_embedding(t, 256).astype(h.dtype)
    te = jax.nn.silu(te @ params["t_mlp"]["w1"] + params["t_mlp"]["b1"])
    te = te @ params["t_mlp"]["w2"] + params["t_mlp"]["b2"]
    c = te + params["y_embed"][y]

    nL = cfg.n_layers
    use_plan = lazy_mode == "plan" and plan_row is not None
    traced_plan = use_plan and isinstance(plan_row, jax.Array)
    unroll = use_plan or lazy_cache is not None or cfg.lazy.enabled

    if unroll:
        new_lazy = {"attn": [], "ffn": []}
        sc_attn, sc_ffn = [], []
        B = h.shape[0]
        for l in range(nL):
            blk = jax.tree.map(lambda a: a[l], params["blocks"])
            lc = (None if lazy_cache is None else
                  {"attn": lazy_cache["attn"][l], "ffn": lazy_cache["ffn"][l]})
            if traced_plan:
                plan = (plan_row[l, 0], plan_row[l, 1])
            elif use_plan:
                plan = (bool(plan_row[l][0]), bool(plan_row[l][1]))
            else:
                plan = (False, False)
            h, nlz, sc = _block_apply(blk, cfg, h, c, lazy_cache=lc,
                                      lazy_mode=lazy_mode, plan=plan,
                                      prime=first_step, fresh=fresh,
                                      policy=policy)
            if lazy_cache is not None:
                new_lazy["attn"].append(nlz["attn"])
                new_lazy["ffn"].append(nlz["ffn"])
            sc_attn.append(sc.get("attn", jnp.zeros((B,), jnp.float32)))
            sc_ffn.append(sc.get("ffn", jnp.zeros((B,), jnp.float32)))
        out_lazy = (None if lazy_cache is None else
                    {"attn": jnp.stack(new_lazy["attn"]),
                     "ffn": jnp.stack(new_lazy["ffn"])})
        scores = {"attn": jnp.stack(sc_attn), "ffn": jnp.stack(sc_ffn)}
    else:
        def body(h, blk):
            h, _, _ = _block_apply(blk, cfg, h, c, lazy_cache=None,
                                   lazy_mode="off")
            return h, None

        h, _ = jax.lax.scan(body, h, params["blocks"])
        out_lazy, scores = None, {}

    mod = jax.nn.silu(c) @ params["final"]["mod"]["w"] + params["final"]["mod"]["b"]
    sh, sc_ = jnp.split(mod, 2, axis=-1)
    h = _modulate(L.layernorm_apply({}, h, 1e-6), sh, sc_)
    h = h @ params["final"]["w"] + params["final"]["b"]
    out = unpatchify(h, p, n_side, cfg.dit_in_channels * 2)
    return out, out_lazy, scores


def init_dit_lazy_cache(cfg: ModelConfig, batch: int) -> dict:
    n_tok = (cfg.dit_input_size // cfg.dit_patch) ** 2
    z = jnp.zeros((cfg.n_layers, batch, n_tok, cfg.d_model), jnp.dtype(cfg.dtype))
    return {"attn": z, "ffn": z}


def split_eps(out: Array, channels: int) -> Tuple[Array, Array]:
    """DiT predicts (eps, sigma); DDIM uses eps."""
    return out[..., :channels], out[..., channels:]
