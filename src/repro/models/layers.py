"""Layer primitives for all assigned architectures.

Pure-functional pytree modules: every layer is an ``init_*(key, ...) -> params``
plus an ``*_apply(params, x, ...) -> y`` pair.  No global state; params are
nested dicts of jnp arrays so they stack cleanly under ``jax.vmap`` for
scan-over-layers and shard cleanly under pjit.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, SSMConfig, XLSTMConfig
from repro.dist import ctx
from repro.kernels import backend as kernel_backend
from repro.kernels.flash_attention import ops as flash_ops

Array = jax.Array

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> Array:
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype, elementwise: bool = True) -> dict:
    if elementwise:
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {}


def layernorm_apply(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    """Inverse frequencies, f32, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def rope_cos_sin(positions: Array, head_dim: int, theta: float,
                 mrope_sections: Tuple[int, ...] = ()) -> Tuple[Array, Array]:
    """cos/sin tables.

    positions: (..., S) int32 for plain rope, or (3, ..., S) for M-RoPE
    (temporal / height / width position streams, Qwen2-VL arXiv:2409.12191).
    Returns cos, sin of shape (..., S, head_dim // 2) in f32.
    """
    inv = rope_freqs(head_dim, theta)                      # (hd/2,)
    if mrope_sections:
        assert positions.ndim >= 2 and positions.shape[0] == len(mrope_sections)
        ang_parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            p = positions[i].astype(jnp.float32)[..., None]          # (...,S,1)
            ang_parts.append(p * inv[start:start + sec])
            start += sec
        ang = jnp.concatenate(ang_parts, axis=-1)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv          # (...,S,hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA, sliding window, logit softcap)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _softcap(x: Array, cap: float) -> Array:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _sdpa_block(q: Array, k: Array, v: Array, *, window: int, softcap: float,
                qpos: Array, kpos: Array, causal: bool = True) -> Array:
    """One query-block of causal attention. q: (B,Sq,H,hd) k: (B,Sk,KV,hd),
    v: (B,Sk,KV,vd) — v head dim may differ (MLA); qpos (Sq,), kpos (Sk,)
    absolute positions."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    rep = H // KV
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    qg = qf.reshape(B, Sq, KV, rep, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k.astype(jnp.float32))
    scores = _softcap(scores, softcap)
    qp, kp = qpos[:, None], kpos[None, :]
    mask = (kp >= 0) & (qp >= 0)                        # unwritten ring / pad slots
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    # ADDITIVE mask: `add` carries no residuals through the backward pass,
    # so remat'd scans don't stack (Sq,Sk) preds across iterations the way a
    # `select` would (a 100x activation-memory difference at 32k context).
    bias = jnp.where(mask, 0.0, -1e30)                  # (Sq, Sk) f32, small
    probs = jax.nn.softmax(scores + bias[None, None, None], axis=-1)
    # rows with no valid key (fully masked) -> zero output, not NaN
    rowvalid = jnp.any(mask, axis=-1).astype(jnp.float32)      # (Sq,)
    probs = probs * rowvalid[None, None, None, :, None]
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, vd).astype(q.dtype)


SDPA_Q_BLOCK = 512   # query-chunk length for long-sequence attention


def sdpa(q: Array, k: Array, v: Array, *, causal: bool, window: int,
         softcap: float, q_offset: Array | int = 0,
         kv_positions: Optional[Array] = None) -> Array:
    """Scaled dot-product attention, GQA-aware, f32 softmax.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).
    Long query sequences are processed in SDPA_Q_BLOCK chunks via lax.scan so
    the score matrix transient is (B, H, blk, Sk) instead of (B, H, Sq, Sk) —
    the jnp analogue of the Pallas flash kernel's HBM footprint (the Pallas
    path additionally tiles Sk through VMEM; see kernels/flash_attention).

    ``q_offset``: absolute position of q[0] (decode: current index).
    ``kv_positions``: (Sk,) absolute positions of cache slots (ring buffers);
    defaults to arange(Sk).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    kpos = kv_positions if kv_positions is not None else jnp.arange(Sk)
    if Sq <= SDPA_Q_BLOCK:
        qpos = jnp.arange(Sq) + q_offset
        return _sdpa_block(q, k, v, window=window, softcap=softcap,
                           qpos=qpos, kpos=kpos, causal=causal)
    blk = SDPA_Q_BLOCK
    nb = (Sq + blk - 1) // blk
    pad = nb * blk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qpos_all = jnp.arange(nb * blk) + q_offset
    # padded tail gets position -1 -> fully masked -> zero rows (sliced off)
    qpos_all = jnp.where(jnp.arange(nb * blk) < Sq, qpos_all, -1)
    q_blocks = qp.reshape(B, nb, blk, H, hd).transpose(1, 0, 2, 3, 4)
    qpos_blocks = qpos_all.reshape(nb, blk)

    # flash-style backward: recompute probs per block instead of saving the
    # (blk, Sk) probability tiles as scan residuals (f32 probs for a 32k
    # context would otherwise dominate activation memory)
    @jax.checkpoint
    def body(_, inp):
        qb, qposb = inp
        ob = _sdpa_block(qb, k, v, window=window, softcap=softcap,
                         qpos=qposb, kpos=kpos, causal=causal)
        return None, ob

    _, out = lax.scan(body, None, (q_blocks, qpos_blocks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nb * blk, H, v.shape[-1])
    return out[:, :Sq]


def attention_apply(params: dict, cfg: ModelConfig, x: Array, *,
                    cos: Array, sin: Array, window: int,
                    cache: Optional[dict] = None,
                    decode_index: Optional[Array] = None,
                    causal: bool = True,
                    ) -> Tuple[Array, Optional[dict]]:
    """GQA attention. Full-sequence causal when cache is None, else one-step
    decode against (and updating) the KV cache.

    cache: {"k": (B, W, KV, hd), "v": ..., "pos": (W,) int32 slot positions}.
    Ring-buffered when W < full context (sliding-window archs).
    """
    B, S, D = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.use_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = ctx.constrain(q.reshape(B, S, h, hd), "batch", None, "model", None)
    k = ctx.constrain(k.reshape(B, S, kv, hd), "batch", None, "model", None)
    v = ctx.constrain(v.reshape(B, S, kv, hd), "batch", None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_type != "none":
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cache is None:
        if (kernel_backend.get_backend() == "pallas"
                and not kernel_backend.resolve_interpret()):
            # compiled-Pallas target: the full-sequence hot-spot runs the
            # blocked flash kernel (masked k-blocks pruned).  Interpret
            # hosts keep the XLA sdpa — an interpreted grid loop is slower
            # than the fused einsum and wins nothing.
            out = flash_ops.gqa_flash_attention(
                q, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap)
        else:
            out = sdpa(q, k, v, causal=causal, window=window,
                       softcap=cfg.attn_logit_softcap)
        new_cache = None
    elif S == 1:
        W = cache["k"].shape[1]
        slot = (decode_index % W).astype(jnp.int32)
        ck = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        cpos = lax.dynamic_update_slice(
            cache["pos"], decode_index[None].astype(jnp.int32), (slot,))
        out = sdpa(q, ck, cv, causal=True, window=window,
                   softcap=cfg.attn_logit_softcap, q_offset=decode_index,
                   kv_positions=cpos)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        # prefill into a fresh cache (positions 0..S-1); ring-truncates to the
        # last W tokens for sliding-window caches.
        W = cache["k"].shape[1]
        Wl = min(W, S)
        pos_last = jnp.arange(S - Wl, S)
        slots = (pos_last % W).astype(jnp.int32)
        ck = cache["k"].at[:, slots].set(k[:, -Wl:])
        cv = cache["v"].at[:, slots].set(v[:, -Wl:])
        cpos = cache["pos"].at[slots].set(pos_last.astype(jnp.int32))
        out = sdpa(q, k, v, causal=True, window=window,
                   softcap=cfg.attn_logit_softcap)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    out = ctx.constrain(out, "batch", None, "model", None)
    y = out.reshape(B, S, h * hd) @ params["wo"]
    return y, new_cache


def attention_kv_write(params: dict, cfg: ModelConfig, x: Array, *,
                       cos: Array, sin: Array, cache: dict,
                       decode_index: Array) -> dict:
    """KV-projection + cache write only (no attention compute).

    Used when a lazy *plan* skips the attention module during AR decode: the
    module's output is served from the lazy cache, but this position's k/v
    must still be recorded or later steps would never see it (cost: the two
    small kv projections, ~2·D·KV·hd FLOPs vs the full module)."""
    B, S, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.use_bias:
        k, v = k + params["bk"], v + params["bv"]
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_type != "none":
        k = apply_rope(k, cos, sin)
    W = cache["k"].shape[1]
    slot = (decode_index % W).astype(jnp.int32)
    return {
        "k": lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0)),
        "v": lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0)),
        "pos": lax.dynamic_update_slice(
            cache["pos"], decode_index[None].astype(jnp.int32), (slot,)),
    }


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         window: int) -> dict:
    W = min(max_len, window) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, W, kv, hd), dt),
        "v": jnp.zeros((batch, W, kv, hd), dt),
        "pos": jnp.full((W,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_d = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        # q: full-rank (lite model has no q lora)
        "wq": dense_init(ks[0], d, h * qk_d, dt),
        # joint kv compression + shared rope key
        "w_dkv": dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "w_uk": dense_init(ks[2], m.kv_lora_rank, h * m.qk_nope_head_dim, dt),
        "w_uv": dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim, dt),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dt),
    }
    return p


def mla_apply(params: dict, cfg: ModelConfig, x: Array, *,
              cos: Array, sin: Array, window: int,
              cache: Optional[dict] = None,
              decode_index: Optional[Array] = None,
              ) -> Tuple[Array, Optional[dict]]:
    """MLA with latent-KV cache: caches (c_kv, k_rope) only."""
    m: MLAConfig = cfg.mla
    B, S, D = x.shape
    h = cfg.n_heads
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = ctx.constrain((x @ params["wq"]).reshape(B, S, h, nd + rd),
                      "batch", None, "model", None)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, cos, sin)

    dkv = x @ params["w_dkv"]
    c_kv = rmsnorm_apply(params["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., m.kv_lora_rank:][:, :, None, :], cos, sin)  # (B,S,1,rd)

    if cache is not None and S == 1:
        W = cache["c_kv"].shape[1]
        slot = (decode_index % W).astype(jnp.int32)
        c_kv = lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, slot, 0))
        k_rope_c = lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, slot, 0, 0))
        cpos = lax.dynamic_update_slice(
            cache["pos"], decode_index[None].astype(jnp.int32), (slot,))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope_c, "pos": cpos}
        k_rope = k_rope_c
        kv_positions = cpos
        q_offset = decode_index
    elif cache is not None:
        # prefill from position 0 (see attention_apply)
        W = cache["c_kv"].shape[1]
        Wl = min(W, S)
        pos_last = jnp.arange(S - Wl, S)
        slots = (pos_last % W).astype(jnp.int32)
        new_cache = {
            "c_kv": cache["c_kv"].at[:, slots].set(c_kv[:, -Wl:]),
            "k_rope": cache["k_rope"].at[:, slots].set(k_rope[:, -Wl:]),
            "pos": cache["pos"].at[slots].set(pos_last.astype(jnp.int32)),
        }
        kv_positions, q_offset = None, 0
    else:
        new_cache, kv_positions, q_offset = None, None, 0

    Sk = c_kv.shape[1]
    k_nope = ctx.constrain((c_kv @ params["w_uk"]).reshape(B, Sk, h, nd),
                           "batch", None, "model", None)
    val = ctx.constrain((c_kv @ params["w_uv"]).reshape(B, Sk, h, vd),
                        "batch", None, "model", None)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, Sk, h, rd))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    out = sdpa(qq, k, val, causal=True, window=window,
               softcap=cfg.attn_logit_softcap, q_offset=q_offset,
               kv_positions=kv_positions)
    out = ctx.constrain(out, "batch", None, "model", None)
    y = out.reshape(B, S, h * vd) @ params["wo"]
    return y, new_cache


def mla_kv_write(params: dict, cfg: ModelConfig, x: Array, *,
                 cos: Array, sin: Array, cache: dict,
                 decode_index: Array) -> dict:
    """Latent-KV cache write only (plan-skipped MLA module; see
    attention_kv_write)."""
    m: MLAConfig = cfg.mla
    dkv = x @ params["w_dkv"]
    c_kv = rmsnorm_apply(params["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., m.kv_lora_rank:][:, :, None, :], cos, sin)
    W = cache["c_kv"].shape[1]
    slot = (decode_index % W).astype(jnp.int32)
    return {
        "c_kv": lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, slot, 0)),
        "k_rope": lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, slot, 0, 0)),
        "pos": lax.dynamic_update_slice(
            cache["pos"], decode_index[None].astype(jnp.int32), (slot,)),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, window: int) -> dict:
    m: MLAConfig = cfg.mla
    W = min(max_len, window) if window else max_len
    dt = jnp.dtype(cfg.dtype)
    return {
        "c_kv": jnp.zeros((batch, W, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, W, 1, m.qk_rope_head_dim), dt),
        "pos": jnp.full((W,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Feedforward (gated) and MoE
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(dtype)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dt),
        "w_up": dense_init(ks[1], d, d_ff, dt),
        "w_down": dense_init(ks[2], d_ff, d, dt),
    }


def _act(name: str, x: Array) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp_apply(params: dict, x: Array, act: str = "silu") -> Array:
    h = _act(act, x @ params["w_gate"]) * (x @ params["w_up"])
    if h.ndim == 3:
        h = ctx.constrain(h, "batch", None, "model")
    return h @ params["w_down"]


def init_moe(key, cfg: ModelConfig) -> dict:
    mo: MoEConfig = cfg.moe
    d = cfg.d_model
    dff = mo.d_ff_expert or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    expert_keys = jax.random.split(ks[0], mo.n_experts)
    experts = jax.vmap(lambda k: init_mlp(k, d, dff, dt))(expert_keys)
    p = {"router": dense_init(ks[1], d, mo.n_experts, dt), "experts": experts}
    if mo.n_shared_experts:
        p["shared"] = init_mlp(ks[2], d, dff * mo.n_shared_experts, dt)
    return p


def moe_apply_dense_ref(params: dict, cfg: ModelConfig, x: Array,
                        act: str = "silu") -> Tuple[Array, Array]:
    """Reference oracle: computes *every* expert for every token and combines
    with router weights (no capacity drops).  O(T·E) compute — tests only."""
    mo: MoEConfig = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, mo.top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    wfull = jnp.zeros_like(probs)
    wfull = jax.vmap(lambda w, g, i: w.at[i].set(g))(wfull, gate_vals, gate_idx)
    h_all = jax.vmap(lambda p: mlp_apply(p, xt, act))(params["experts"])  # (E,T,D)
    y = jnp.einsum("etd,te->td", h_all, wfull.astype(xt.dtype))
    if mo.n_shared_experts:
        y = y + mlp_apply(params["shared"], xt, act)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], mo.n_experts), axis=0)
    aux = jnp.sum(frac_tokens * jnp.mean(probs, 0)) * mo.n_experts \
        * mo.router_aux_weight
    return y.reshape(B, S, D), aux.astype(jnp.float32)


def moe_apply_shard_map(params: dict, cfg: ModelConfig, x: Array,
                        act: str = "silu") -> Tuple[Array, Array]:
    """Megatron-style LOCAL MoE dispatch (§Perf hillclimb B).

    Under pjit's global view, capacity dispatch builds GLOBAL (E, C, D)
    buffers; scattering dp-sharded tokens into them leaves partial sums that
    GSPMD resolves with (E, C, F)-sized all-reduces (measured: 4.7-18 TB per
    step on mixtral train_4k).  shard_map makes the dispatch per-data-shard:
    local tokens -> local capacity buffers -> TP expert matmuls -> one psum
    over the model axis.  Weight FSDP gathers happen once at the boundary.
    """
    from jax.sharding import PartitionSpec as P
    mo: MoEConfig = cfg.moe
    B, S, D = x.shape
    mesh = ctx._STATE["mesh"]
    dp = ctx._STATE["dp"]
    tp = ctx._STATE["model"]

    def local(xt, router, experts, shared):
        with ctx.disabled():
            return _local_impl(xt, router, experts, shared)

    def _local_impl(xt, router, experts, shared):
        T, _ = xt.shape                       # local tokens
        E, K = mo.n_experts, mo.top_k
        C = max(1, int(math.ceil(T * K / E * mo.capacity_factor)))
        logits = (xt @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
        eid = gate_idx.T.reshape(-1)
        wk = gate_vals.T.reshape(-1)
        order = jnp.argsort(eid, stable=True)
        eid_s = eid[order]
        tok_s = (order % T).astype(jnp.int32)
        w_s = wk[order]
        counts = jnp.bincount(eid, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(K * T, dtype=jnp.int32) - starts[eid_s].astype(jnp.int32)
        ok = pos < C
        dest = jnp.where(ok, eid_s * C + pos, E * C - 1)
        src = jnp.where(ok[:, None], xt[tok_s], 0)
        buf = jnp.zeros((E * C, D), xt.dtype).at[dest].add(src)
        h = jax.vmap(lambda p, xe: mlp_apply(p, xe, act))(
            experts, buf.reshape(E, C, D))            # F locally TP-sliced
        h_flat = h.reshape(E * C, D)
        contrib = w_s[:, None].astype(xt.dtype) * h_flat[dest]
        contrib = jnp.where(ok[:, None], contrib, 0)
        y = jnp.zeros((T, D), xt.dtype).at[tok_s].add(contrib)
        if mo.n_shared_experts:
            y = y + mlp_apply(shared, xt, act)
        y = lax.psum(y, tp)                           # TP partial sums
        frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
        aux = jnp.sum(frac_tokens * jnp.mean(probs, 0)) * E \
            * mo.router_aux_weight
        aux = lax.pmean(aux.astype(jnp.float32), dp)
        return y, aux

    shared = params.get("shared")
    if shared is None:
        shared = {}
    expert_specs = {"w_gate": P(None, None, tp), "w_up": P(None, None, tp),
                    "w_down": P(None, tp, None)}
    shared_specs = ({"w_gate": P(None, tp), "w_up": P(None, tp),
                     "w_down": P(tp, None)} if shared else {})
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), expert_specs, shared_specs),
        out_specs=(P(dp, None), P()),
        check_vma=False)
    xt = x.reshape(B * S, D)
    y, aux = fn(xt, params["router"], params["experts"], shared)
    return y.reshape(B, S, D), aux


def moe_apply(params: dict, cfg: ModelConfig, x: Array,
              act: str = "silu") -> Tuple[Array, Array]:
    """Sort-based capacity MoE dispatch (production path).

    Tokens are argsorted by expert id and scattered into a per-expert
    (E, C, D) buffer — O(T·K) memory instead of the (T, E, C) dispatch
    tensor of the Mesh-TF formulation.  Capacity overflow drops the lowest-
    priority (higher k) assignments, matching standard TPU MoE stacks.
    Expert weights are tensor-parallel over the ``model`` mesh axis
    (d_ff_expert sharded); see dist/sharding.py.

    Returns (y, aux_loss).
    """
    if ctx.opt("moe_shard_map") and ctx.active():
        return moe_apply_shard_map(params, cfg, x, act)
    mo: MoEConfig = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mo.n_experts, mo.top_k
    C = max(1, int(math.ceil(T * K / E * mo.capacity_factor)))

    xt = x.reshape(T, D)
    if ctx.opt("moe_token_dp"):
        # §Perf hillclimb B: pin dispatch tokens to the data axes so the
        # sort/scatter pipeline never reshards the (seq-parallel) token dim
        # across the TP axis (GSPMD otherwise emits collective-permutes of
        # the full token buffer per layer).
        xt = ctx.constrain(xt, "batch", None)
    logits = (xt @ params["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, K)                      # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # k-major flatten: all first choices sort ahead of second choices, so
    # capacity drops hit the lowest-weight assignments first.
    eid = gate_idx.T.reshape(-1)                                   # (K*T,)
    wk = gate_vals.T.reshape(-1)                                   # (K*T,)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = (order % T).astype(jnp.int32)
    w_s = wk[order]
    # position within expert group
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts                           # (E,)
    pos = jnp.arange(K * T, dtype=jnp.int32) - starts[eid_s].astype(jnp.int32)
    ok = pos < C
    # overflow handled by ZEROED scatter-adds into the last slot rather than
    # a +1 slot: (E*C, D) keeps a shardable leading dim (an odd E*C+1 buffer
    # forces GSPMD to replicate the whole dispatch — §Perf hillclimb B).
    dest = jnp.where(ok, eid_s * C + pos, E * C - 1)
    src = jnp.where(ok[:, None], xt[tok_s], 0)
    buf = jnp.zeros((E * C, D), xt.dtype).at[dest].add(src)
    if ctx.opt("moe_token_dp"):
        buf = ctx.constrain(buf, "batch", None)    # capacity over data axes
    h = jax.vmap(lambda p, xe: mlp_apply(p, xe, act))(
        params["experts"], buf.reshape(E, C, D))
    h_flat = h.reshape(E * C, D)
    if ctx.opt("moe_token_dp"):
        h_flat = ctx.constrain(h_flat, "batch", None)
    contrib = w_s[:, None].astype(xt.dtype) * h_flat[dest]
    contrib = jnp.where(ok[:, None], contrib, 0)
    y = jnp.zeros((T, D), xt.dtype).at[tok_s].add(contrib)
    if ctx.opt("moe_token_dp"):
        y = ctx.constrain(y, "batch", None)

    if mo.n_shared_experts:
        y = y + mlp_apply(params["shared"], xt, act)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * E * mo.router_aux_weight
    return y.reshape(B, S, D), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block — arXiv:2405.21060 style, used by zamba2
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
    d_proj = 2 * d_inner + 2 * s.state_dim + n_heads
    return {
        "w_in": dense_init(ks[0], d, d_proj, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, d_inner + 2 * s.state_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dt),
        "w_out": dense_init(ks[2], d_inner, d, dt),
    }


def _segsum(x: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k], causal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh: Array, dt_h: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int, init_state: Optional[Array] = None,
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan (Mamba2).

    xh: (B, S, H, P) inputs; dt_h: (B, S, H) softplus'd step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B, S, N) shared across heads.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    r = lambda a, sh: a.reshape(sh)
    x_ = r(xh, (Bsz, nc, Q, H, P))
    dt_ = r(dt_h, (Bsz, nc, Q, H))
    B_ = r(Bm, (Bsz, nc, Q, N))
    C_ = r(Cm, (Bsz, nc, Q, N))

    dA = dt_ * A                                                # (b,c,q,h)
    dA_cs = jnp.cumsum(dA, axis=2)
    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))              # (b,c,h,q,q)
    xdt = x_ * dt_[..., None]
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", C_, B_, L, xdt)
    # chunk-final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)         # (b,c,q,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", B_, decay_states * dt_, x_)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                   # (b,c,h)

    def scan_fn(carry, inp):
        st, dec = inp                                           # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                       # emit state *before* chunk

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), xh.dtype)
    final, prev_states = lax.scan(
        scan_fn, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (b,c,h,p,n)
    # state -> output within chunk
    state_decay = jnp.exp(dA_cs)                                # (b,c,q,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", C_, prev_states, state_decay)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


def mamba2_apply(params: dict, cfg: ModelConfig, x: Array, *,
                 cache: Optional[dict] = None,
                 ) -> Tuple[Array, Optional[dict]]:
    """Mamba2 block: full-seq (chunked scan) or single-step (recurrent)."""
    s: SSMConfig = cfg.ssm
    B, S, D = x.shape
    d_inner = s.expand * D
    H = d_inner // s.head_dim
    N, P = s.state_dim, s.head_dim

    proj = x @ params["w_in"]
    z, xbc_dt = proj[..., :d_inner], proj[..., d_inner:]
    xbc, dt_raw = xbc_dt[..., : d_inner + 2 * N], xbc_dt[..., d_inner + 2 * N:]

    cw = params["conv_w"].astype(jnp.float32)                   # (W, d_conv)
    Wc = cw.shape[0]
    if cache is None:
        # causal depthwise conv over sequence
        pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (Wc - 1, 0), (0, 0)))
        xbc_c = sum(pad[:, i:i + S] * cw[i] for i in range(Wc))
        new_conv = None
    else:
        buf = jnp.concatenate([cache["conv"], xbc.astype(jnp.float32)], axis=1)
        xbc_c = sum(buf[:, i:i + S] * cw[i] for i in range(Wc))
        new_conv = buf[:, -(Wc - 1):]
    xbc_c = ctx.constrain(jax.nn.silu(xbc_c).astype(x.dtype),
                          "batch", None, "model")

    xs = ctx.constrain(xbc_c[..., :d_inner].reshape(B, S, H, P),
                       "batch", None, "model", None)
    Bm = xbc_c[..., d_inner:d_inner + N]
    Cm = xbc_c[..., d_inner + N:]
    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                                # (H,) negative

    if cache is None or S > 1:
        init = cache["state"] if cache is not None else None
        Q = min(s.chunk, S)
        pad = (-S) % Q
        if pad:
            # dt=0 padding: decay exp(0)=1 and zero state contribution
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        else:
            xs_p, dt_p, Bm_p, Cm_p = xs, dt_h, Bm, Cm
        y, final = ssd_chunked(xs_p.astype(jnp.float32), dt_p, A,
                               Bm_p.astype(jnp.float32),
                               Cm_p.astype(jnp.float32), Q, init_state=init)
        y = y[:, :S]
        new_cache = None if cache is None else {"state": final,
                                                "conv": new_conv}
    else:
        st = cache["state"]                                      # (B,H,P,N) f32
        dA = jnp.exp(dt_h[:, 0] * A)                             # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_h[:, 0],
                         xs[:, 0].astype(jnp.float32), Bm[:, 0].astype(jnp.float32))
        st = st * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), st)[:, None]
        new_cache = {"state": st, "conv": new_conv}
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm_apply(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["w_out"], new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int) -> dict:
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return {
        "state": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, d_inner + 2 * s.state_dim),
                          jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) & sLSTM (scalar)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    d_in = int(xc.proj_factor * d)
    h = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_in, dt),              # x branch + z gate
        "conv_w": (jax.random.normal(ks[1], (xc.conv_width, d_in), jnp.float32)
                   * 0.1).astype(dt),
        "wq": dense_init(ks[2], d_in, d_in, dt),
        "wk": dense_init(ks[3], d_in, d_in, dt),
        "wv": dense_init(ks[4], d_in, d_in, dt),
        "w_i": dense_init(ks[5], d_in, h, dt, scale=0.1),
        "w_f": dense_init(ks[6], d_in, h, dt, scale=0.1),
        "f_bias": jnp.linspace(3.0, 6.0, h).astype(jnp.float32),
        "norm": init_rmsnorm(d_in, dt),
        "w_down": dense_init(ks[7], d_in, d, dt),
    }


def mlstm_parallel_ref(q: Array, k: Array, v: Array, i_pre: Array,
                       f_pre: Array) -> Array:
    """Stabilized *quadratic* parallel mLSTM — reference oracle only
    (materializes (B,S,S,H); use mlstm_chunked in the model path).

    q,k,v: (B, S, H, hd); i_pre/f_pre: (B, S, H) pre-activations (f32).
    """
    B, S, H, hd = q.shape
    logf = jax.nn.log_sigmoid(f_pre)                              # (B,S,H)
    fcum = jnp.cumsum(logf, axis=1)
    # log decay matrix: D[t,s] = fcum[t] - fcum[s] + i[s], s<=t
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + i_pre[:, None, :, :]
    mask = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    dmat = jnp.where(mask, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                      # (B,S,1,H)
    m = jnp.maximum(m, 0.0)
    dexp = jnp.exp(dmat - m)                                      # (B,S,S,H)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,S,H)
    y = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    return (y / norm[..., None]).astype(q.dtype)


MLSTM_CHUNK = 256


def mlstm_chunked(q: Array, k: Array, v: Array, i_pre: Array, f_pre: Array,
                  chunk: int = MLSTM_CHUNK, init_state=None,
                  return_state: bool = False):
    """Chunkwise-recurrent stabilized mLSTM (linear in S).

    Carries (C, n, m) matrix-memory state across chunks of length Q; intra-
    chunk uses the quadratic form on (Q, Q) tiles only.  Matches
    mlstm_parallel_ref to numerical precision.
    """
    B, S, H, hd = q.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    r5 = lambda a: a.reshape(B, nc, Q, H, hd).astype(jnp.float32)
    r4 = lambda a: a.reshape(B, nc, Q, H).astype(jnp.float32)
    qc, kc, vc = r5(q), r5(k), r5(v)
    ic, fc = r4(i_pre), r4(f_pre)
    logf = jax.nn.log_sigmoid(fc)
    a = jnp.cumsum(logf, axis=2)                       # in-chunk fcum  (B,nc,Q,H)
    # For s in chunk: exponent of source s contribution at target t is
    #   fcum_t - fcum_s + i_s = a_t - a_s + i_s = a_t + b_s,  b_s := i_s - a_s.
    b = ic - a

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(carry, inp):
        C, n, m = carry                                 # (B,H,hd,hd),(B,H,hd),(B,H)
        qb, kb, vb, ab, bb = inp                        # (B,Q,H,hd)... (B,Q,H)
        # intra-chunk log weights: ab_t + bb_s  (s <= t)
        dmat = ab[:, :, None, :] + bb[:, None, :, :]    # (B,Q,Q,H)
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)                 # (B,Q,H)
        m_inter = ab + m[:, None, :]                    # carry stabilizer
        m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), 0.0)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * (hd ** -0.5)
        w = scores * dexp
        y_intra = jnp.einsum("btsh,bshd->bthd", w, vb)
        inter_scale = jnp.exp(ab + m[:, None, :] - m_t)  # (B,Q,H)
        y_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * inter_scale[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qb, n) * inter_scale
        y = y_intra + y_inter
        den = jnp.sum(w, axis=2) + n_inter               # q·n, (B,Q,H)
        nrm = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y = y / nrm[..., None]
        # ---- state update for next chunk
        ab_e = ab[:, -1:, :]                            # L_end (B,1,H)
        state_exp = ab_e + bb                           # (B,Q,H): L_end + b_s
        m_state = jnp.max(state_exp, axis=1)            # (B,H)
        m_new = jnp.maximum(m + ab_e[:, 0], m_state)
        decay = jnp.exp(m + ab_e[:, 0] - m_new)
        src = jnp.exp(state_exp - m_new[:, None, :])    # (B,Q,H)
        kw = kb * (hd ** -0.5) * src[..., None]
        C_new = C * decay[..., None, None] + jnp.einsum("bshd,bshe->bhde", kw, vb)
        n_new = n * decay[..., None] + jnp.sum(kw, axis=1)
        return (C_new, n_new, m_new), y

    if init_state is None:
        init_state = (jnp.zeros((B, H, hd, hd), jnp.float32),
                      jnp.zeros((B, H, hd), jnp.float32),
                      jnp.full((B, H), -jnp.inf, jnp.float32))
    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), a.transpose(1, 0, 2, 3),
          b.transpose(1, 0, 2, 3))
    final, ys = lax.scan(body, init_state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    y = y.astype(q.dtype)
    return (y, final) if return_state else y


def mlstm_apply(params: dict, cfg: ModelConfig, x: Array, *,
                cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    xc: XLSTMConfig = cfg.xlstm
    B, S, D = x.shape
    d_in = int(xc.proj_factor * D)
    H = cfg.n_heads
    hd = d_in // H

    up = x @ params["w_up"]
    xb, z = up[..., :d_in], up[..., d_in:]
    cw = params["conv_w"].astype(jnp.float32)
    Wc = cw.shape[0]
    if cache is None:
        pad = jnp.pad(xb.astype(jnp.float32), ((0, 0), (Wc - 1, 0), (0, 0)))
        new_conv = None
    else:
        pad = jnp.concatenate([cache["conv"], xb.astype(jnp.float32)], axis=1)
        new_conv = pad[:, -(Wc - 1):]
    xc_ = jax.nn.silu(sum(pad[:, i:i + S] * cw[i] for i in range(Wc))).astype(x.dtype)

    # few heads (xlstm: 4) -> TP lands on the per-head channel dim; the
    # 'none' option keeps the recurrent chunk math replicated across TP
    # (§Perf hillclimb C: the sharded (hd,hd) state outer products emit a
    # collective per chunk per layer otherwise).
    ml_tp = "model" if ctx.opt("mlstm_shard", "hd") == "hd" else None
    q = ctx.constrain((xc_ @ params["wq"]).reshape(B, S, H, hd),
                      "batch", None, None, ml_tp)
    k = ctx.constrain((xc_ @ params["wk"]).reshape(B, S, H, hd),
                      "batch", None, None, ml_tp)
    v = ctx.constrain((xb @ params["wv"]).reshape(B, S, H, hd),
                      "batch", None, None, ml_tp)
    i_pre = (xc_ @ params["w_i"]).astype(jnp.float32)
    f_pre = (xc_ @ params["w_f"]).astype(jnp.float32) + params["f_bias"]

    if cache is None or S > 1:
        if cache is None:
            y = mlstm_chunked(q, k, v, i_pre, f_pre,
                              chunk=min(xc.chunk, S))
            new_cache = None
        else:
            # prefill: pad to a chunk multiple with no-op steps
            # (i -> -inf: zero contribution; f -> +inf: no decay)
            Q = min(xc.chunk, max(S, 1))
            pad = (-S) % Q
            pd4 = ((0, 0), (0, pad), (0, 0), (0, 0))
            pd3 = ((0, 0), (0, pad), (0, 0))
            qp = jnp.pad(q, pd4)
            kp = jnp.pad(k, pd4)
            vp = jnp.pad(v, pd4)
            ip = jnp.pad(i_pre, pd3, constant_values=-1e9)
            fp = jnp.pad(f_pre, pd3, constant_values=1e9)
            init = (cache["C"], cache["n"],
                    jnp.where(jnp.isfinite(cache["m"]), cache["m"], -jnp.inf))
            y, (Cf, nf, mf) = mlstm_chunked(qp, kp, vp, ip, fp, chunk=Q,
                                            init_state=init, return_state=True)
            y = y[:, :S]
            new_cache = {"C": Cf, "n": nf, "m": mf, "conv": new_conv}
    else:
        # recurrent step with max-stabilizer state m
        C, n, mstab = cache["C"], cache["n"], cache["m"]          # f32
        logf = jax.nn.log_sigmoid(f_pre[:, 0])                    # (B,H)
        i0 = i_pre[:, 0]
        m_new = jnp.maximum(logf + mstab, i0)
        fa = jnp.exp(logf + mstab - m_new)
        ia = jnp.exp(i0 - m_new)
        k0 = k[:, 0].astype(jnp.float32) * (hd ** -0.5)
        v0 = v[:, 0].astype(jnp.float32)
        C = C * fa[..., None, None] + ia[..., None, None] * (
            k0[..., :, None] * v0[..., None, :])                  # (B,H,hd,hd)
        n = n * fa[..., None] + ia[..., None] * k0
        q0 = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", q0, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q0, n)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None].astype(x.dtype)       # (B,1,H,hd)
        new_cache = {"C": C, "n": n, "m": m_new, "conv": new_conv}

    y = y.reshape(B, S, d_in)
    y = rmsnorm_apply(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["w_down"], new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d_in = int(xc.proj_factor * cfg.d_model)
    H = cfg.n_heads
    hd = d_in // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        # -inf: empty-state stabilizer (no mass recorded yet)
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, xc.conv_width - 1, d_in), jnp.float32),
    }


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    # input projections for 4 gates + block-diagonal (head-wise) recurrence
    return {
        "w_x": dense_init(ks[0], d, 4 * d, dt),
        "r": (jax.random.normal(ks[1], (h, 4, d // h, d // h), jnp.float32)
              * (1.0 / math.sqrt(d // h))).astype(dt),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "norm": init_rmsnorm(d, dt),
        "w_down": dense_init(ks[2], 2 * d, d, dt),
        "w_up": dense_init(jax.random.split(key, 4)[3], d, 2 * d, dt),
    }


def _slstm_cell(params, h_hd, gates_x, state):
    """One sLSTM step.  gates_x: (B, 4D) PRE-PROJECTED input gates — the
    input matmul is hoisted out of the sequential scan (one big sharded
    matmul for all timesteps instead of 4096 tiny ones, each of which emits
    TP collectives; §Perf hillclimb C).  state: dict of (B, D) f32."""
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]
    B = gates_x.shape[0]
    D = gates_x.shape[1] // 4
    nh, hd = h_hd
    hp = hprev.reshape(B, nh, hd)
    rec = jnp.einsum("bhd,hgde->bghe", hp.astype(params["r"].dtype),
                     params["r"]).astype(jnp.float32).reshape(B, 4 * D)
    g = gates_x.astype(jnp.float32) + rec
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    fi = fi + params["f_bias"]
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, ii)
    ia = jnp.exp(ii - m_new)
    fa = jnp.exp(logf + m - m_new)
    c_new = fa * c + ia * z
    n_new = fa * n + ia
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(params: dict, cfg: ModelConfig, x: Array, *,
                cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    B, S, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    if cache is None:
        state = {k: jnp.zeros((B, D), jnp.float32) for k in ("c", "n", "h", "m")}
    else:
        state = cache

    # hoisted input projections (one sharded matmul instead of one per
    # timestep).  NOTE §Perf hillclimb C: forcing these replicated over TP
    # was tried and REFUTED (+60% memory term); the remaining per-step
    # collectives need a VMEM-resident Pallas scan (see EXPERIMENTS.md).
    gx_all = x @ params["w_x"]

    def step(st, gx_t):
        st2 = _slstm_cell(params, (nh, hd), gx_t, st)
        return st2, st2["h"]

    if S == 1 and cache is not None:
        state = _slstm_cell(params, (nh, hd), gx_all[:, 0], state)
        hs = state["h"][:, None]
        new_cache = state
    else:
        state, hs = lax.scan(step, state, gx_all.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
        new_cache = state if cache is not None else None

    y = rmsnorm_apply(params["norm"], hs.astype(x.dtype), cfg.norm_eps)
    up = y @ params["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = jnp.concatenate([jax.nn.gelu(a) * b, y], axis=-1) @ params["w_down"]
    return y, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    return {k: jnp.zeros((batch, cfg.d_model), jnp.float32)
            for k in ("c", "n", "h", "m")}
