"""CI docs-check: documented CLIs must parse, intra-repo links must resolve.

Two loud tripwires so user docs cannot rot silently:

  1. every CLI surface the README documents answers ``--help`` with exit
     code 0 (a renamed flag set, a broken import, or a deleted module
     fails the job), and each is actually mentioned in README.md so the
     list here and the docs stay in sync;
  2. every relative markdown link in the user-facing docs (README.md,
     docs/*.md) points at a file that exists, and anchored links into
     markdown targets point at a real heading.

    python -m tools.check_docs            # run both checks (CI step)

Stdlib only; run from the repo root.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parent.parent

# every CLI surface README.md documents; --help must exit 0 for each
DOCUMENTED_CLIS = (
    "repro.launch.serve",
    "repro.launch.dryrun",
    "repro.launch.obs",
    "repro.launch.train",
    "benchmarks.run",
    "benchmarks.check_regression",
    "benchmarks.bench_kernels",
)

# user-facing docs whose links are validated (DESIGN/ROADMAP are
# internal working documents; README and docs/ are the public surface)
DOC_FILES = ("README.md", "docs/*.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of a markdown heading."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(md_path: Path) -> set:
    out = set()
    for line in md_path.read_text().splitlines():
        if line.startswith("#"):
            out.add(_slug(line.lstrip("#")))
    return out


def check_links() -> List[str]:
    problems = []
    files: List[Path] = []
    for pat in DOC_FILES:
        files.extend(sorted(REPO.glob(pat)))
    if not files:
        return ["no doc files matched DOC_FILES — docs were deleted?"]
    for md in files:
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part \
                else md.resolve()
            if not dest.exists():
                problems.append(f"{md.relative_to(REPO)}: broken link "
                                f"-> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if _slug(anchor) not in _anchors(dest):
                    problems.append(
                        f"{md.relative_to(REPO)}: anchor #{anchor} not "
                        f"found in {dest.name}")
    return problems


def check_clis() -> List[str]:
    problems = []
    readme = (REPO / "README.md").read_text()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", ".", env.get("PYTHONPATH", "")) if p)
    for mod in DOCUMENTED_CLIS:
        if mod not in readme:
            problems.append(f"README.md does not mention documented CLI "
                            f"`python -m {mod}`")
        try:
            r = subprocess.run(
                [sys.executable, "-m", mod, "--help"], cwd=REPO, env=env,
                capture_output=True, text=True, timeout=180)
        except subprocess.TimeoutExpired:
            problems.append(f"{mod} --help: timed out")
            continue
        if r.returncode != 0:
            tail = (r.stderr or r.stdout).strip().splitlines()[-3:]
            problems.append(f"{mod} --help: exit {r.returncode}: "
                            + " | ".join(tail))
    return problems


def main() -> int:
    problems = check_links() + check_clis()
    if problems:
        print(f"docs-check FAILED ({len(problems)} problems):")
        for p in problems:
            print(f"  {p}")
        return 1
    n_files = sum(len(list(REPO.glob(pat))) for pat in DOC_FILES)
    print(f"docs-check OK: {len(DOCUMENTED_CLIS)} CLIs answer --help, "
          f"links resolve across {n_files} doc files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
