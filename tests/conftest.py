import os
import sys

# make `import repro` work without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# keep XLA from grabbing threads it doesn't have; tests see ONE device
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
