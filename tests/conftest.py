import os
import sys

# make `import repro` work without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# ...and `import benchmarks` (tests reuse its compile-count probe)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# keep XLA from grabbing threads it doesn't have; tests see ONE device
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# determinism off-TPU: no x64 surprises, no TF32-style downcasts
os.environ.setdefault("JAX_ENABLE_X64", "0")

try:
    import hypothesis  # noqa: F401
except ImportError:  # container has no hypothesis wheel — use the fallback
    from _hypothesis_fallback import install as _install_hypothesis
    _install_hypothesis()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute integration tests (subprocess "
        "compiles); run by default, deselect with -m 'not slow'")
    # force host-platform defaults BEFORE any backend initializes so the
    # suite is bit-deterministic on CPU regardless of the machine's
    # accelerators or env: f32 matmuls must not take a fast-path precision.
    import jax
    jax.config.update("jax_platforms", "cpu")
    jax.config.update("jax_default_matmul_precision", "highest")
