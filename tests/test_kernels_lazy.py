"""Skip-aware kernels (ISSUE PR 9): plan-aware flash attention, fused
gate+select, fused DDIM update — oracle parity across dtypes and
non-multiple-of-block shapes, BIT-exact cache serving on skip, the kernel
backend switch (repro.kernels.backend), and end-to-end backend parity of
the sampler (pallas vs xla on CPU, where both realize the same graph)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backend as kb
from repro.kernels.ddim_update.kernel import ddim_update as ddim_update_kernel
from repro.kernels.ddim_update.ops import ddim_update as ddim_update_op
from repro.kernels.ddim_update.ref import ddim_update_ref
from repro.kernels.flash_attention.kernel import flash_attention_lazy
from repro.kernels.flash_attention.ops import lazy_gqa_flash_attention
from repro.kernels.flash_attention.ref import attention_lazy_ref
from repro.kernels.lazy_gate.kernel import lazy_gate_select
from repro.kernels.lazy_gate.ops import lazy_gate_select as lazy_gate_select_op
from repro.kernels.lazy_gate.ref import lazy_gate_select_ref


def _qkvc(key, B, H, Sq, Sk, d, dtype):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(dtype)
    q = jax.random.normal(ks[0], (B, H, Sq, d), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, H, Sk, d), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, H, Sk, d), jnp.float32).astype(dt)
    c = jax.random.normal(ks[3], (B, H, Sq, d), jnp.float32).astype(dt)
    return q, k, v, c


# ---------------------------------------------------------------------------
# plan-aware flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("Sq,Sk,causal,window,softcap", [
    (128, 128, False, 0, 0.0),       # DiT shape: bidirectional, block-exact
    (100, 200, True, 0, 0.0),        # odd shapes (padding path)
    (130, 190, False, 0, 0.0),       # odd shapes, bidirectional
    (128, 128, True, 64, 0.0),       # sliding window (k-block pruning)
    (128, 128, True, 512, 0.0),      # window > Sk
    (128, 128, False, 0, 30.0),      # softcap
])
def test_flash_lazy_matches_ref(dtype, Sq, Sk, causal, window, softcap):
    B, H, d = 3, 2, 64
    q, k, v, c = _qkvc(jax.random.PRNGKey(0), B, H, Sq, Sk, d, dtype)
    skip = jnp.array([True, False, True])
    got = flash_attention_lazy(q, k, v, c, skip, causal=causal,
                               window=window, softcap=softcap,
                               interpret=True, block_q=64, block_k=64)
    want = attention_lazy_ref(q, k, v, c, skip, causal=causal,
                              window=window, softcap=softcap)
    tol = 3e-2 if dtype == "bfloat16" else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    # the skip-set examples are served BIT-exactly, not approximately
    assert np.array_equal(np.asarray(got[0]), np.asarray(c[0]))
    assert np.array_equal(np.asarray(got[2]), np.asarray(c[2]))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_lazy_all_skip_serves_cache_bitexact(dtype):
    B, H, S, d = 2, 2, 100, 32
    q, k, v, c = _qkvc(jax.random.PRNGKey(1), B, H, S, S, d, dtype)
    got = flash_attention_lazy(q, k, v, c, jnp.ones((B,), bool),
                               interpret=True, block_q=64, block_k=64)
    assert np.array_equal(np.asarray(got), np.asarray(c))
    # no-skip degenerates to dense attention
    got = flash_attention_lazy(q, k, v, c, jnp.zeros((B,), bool),
                               interpret=True, block_q=64, block_k=64)
    want = attention_lazy_ref(q, k, v, c, jnp.zeros((B,), bool))
    tol = 3e-2 if dtype == "bfloat16" else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_lazy_gqa_ops_dispatch_cpu():
    """The ops wrapper on CPU hoists the skip to lax.cond: all-skip serves
    the cache bit-exactly, mixed skips match the where-select oracle."""
    B, S, H, KV, hd = 3, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    c = jax.random.normal(ks[3], (B, S, H, hd))
    out = lazy_gqa_flash_attention(q, k, v, c, jnp.ones((B,), bool))
    assert np.array_equal(np.asarray(out), np.asarray(c))
    skip = jnp.array([True, False, True])
    out = lazy_gqa_flash_attention(q, k, v, c, skip)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), H // KV, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), H // KV, axis=1)
    want = attention_lazy_ref(q.transpose(0, 2, 1, 3), kt, vt,
                              c.transpose(0, 2, 1, 3), skip)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               atol=3e-5, rtol=3e-5)
    assert np.array_equal(np.asarray(out[0]), np.asarray(c[0]))


# ---------------------------------------------------------------------------
# fused lazy-gate + select
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("N", [64, 200, 260])
def test_gate_select_kernel_matches_ref(dtype, N):
    B, D = 3, 48
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    z = jax.random.normal(ks[0], (B, N, D), jnp.float32).astype(dt)
    w = jax.random.normal(ks[1], (D, 1), jnp.float32) * 0.2
    b = jax.random.normal(ks[2], (1,), jnp.float32)
    y_new = jax.random.normal(ks[3], (B, N, D), jnp.float32).astype(dt)
    cache_y = jax.random.normal(ks[4], (B, N, D), jnp.float32).astype(dt)
    got_y, got_s = lazy_gate_select(z, w, b, y_new, cache_y, interpret=True,
                                    block_n=64)
    want_y, want_s = lazy_gate_select_ref(z, w, b, y_new, cache_y)
    tol = 3e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=tol, rtol=tol)
    # selection is categorical: whichever side is chosen arrives bit-exact
    skipped = np.asarray(want_s) > 0.5
    for i in range(B):
        src = cache_y[i] if skipped[i] else y_new[i]
        assert np.array_equal(np.asarray(got_y[i]), np.asarray(src)), (
            f"example {i} (skip={skipped[i]}) was not served bit-exactly")


def test_gate_select_fresh_mask_forces_compute():
    """fresh=1 rows must NOT serve the cache even above threshold."""
    B, N, D = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    z = jax.random.normal(ks[0], (B, N, D))
    w = jnp.ones((D, 1)) * 10.0          # saturate the gate: score ~ 1
    b = jnp.zeros((1,))
    y_new = jax.random.normal(ks[1], (B, N, D))
    cache_y = jax.random.normal(ks[2], (B, N, D))
    fresh = jnp.array([1, 0], jnp.int32)
    for impl in (
        lambda: lazy_gate_select(z, jnp.abs(w), b, y_new, cache_y, fresh,
                                 interpret=True, block_n=64),
        lambda: lazy_gate_select_ref(z, jnp.abs(w), b, y_new, cache_y, fresh),
        lambda: lazy_gate_select_op(z, jnp.abs(w), b, y_new, cache_y, fresh),
    ):
        y, s = impl()
        assert np.array_equal(np.asarray(y[0]), np.asarray(y_new[0]))


def test_gate_select_ref_matches_core_lazy():
    """The fused oracle is op-for-op the core.lazy composition
    (gate_score -> threshold -> select_cached) — the CPU bit-exactness
    anchor for the pallas backend's masked mode."""
    from repro.core.lazy import gate_score, select_cached
    B, N, D = 3, 80, 40
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    z = jax.random.normal(ks[0], (B, N, D))
    w = jax.random.normal(ks[1], (D, 1)) * 0.3
    b = jax.random.normal(ks[2], (1,))
    y_new = jax.random.normal(ks[3], (B, N, D))
    cache_y = jax.random.normal(ks[4], (B, N, D))
    got_y, got_s = lazy_gate_select_ref(z, w, b, y_new, cache_y,
                                        threshold=0.5)
    want_s = gate_score({"w": w, "b": b}, z)
    want_y = select_cached(want_s > 0.5, y_new, cache_y)
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    assert np.array_equal(np.asarray(got_y), np.asarray(want_y))


# ---------------------------------------------------------------------------
# fused DDIM update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("eta", [0.0, 0.5])
@pytest.mark.parametrize("shape", [(2, 10, 10, 3), (3, 16, 16, 4)])
def test_ddim_update_kernel_matches_ref(dtype, eta, shape):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    z = jax.random.normal(ks[0], shape).astype(dt)
    eps = jax.random.normal(ks[1], shape).astype(dt)
    noise = jax.random.normal(ks[2], shape).astype(dt) if eta > 0 else None
    B = shape[0]
    a_t = jnp.linspace(0.5, 0.8, B)
    a_p = jnp.linspace(0.7, 0.95, B)
    got = ddim_update_kernel(z, eps, a_t, a_p, noise, eta=eta,
                             interpret=True, block_m=128)
    # the ref computes in f32 and returns f32; the kernel rounds back to
    # the latent dtype, so bf16 parity is at bf16 resolution
    want = ddim_update_ref(z, eps, a_t, a_p, noise, eta=eta)
    tol = 3e-2 if dtype == "bfloat16" else 2e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_ddim_update_ref_matches_sampler_step():
    """The oracle IS sampling/ddim.ddim_step's update on gathered alphas."""
    from repro.sampling import ddim
    sched = ddim.linear_schedule(50)
    B, shape = 2, (2, 8, 8, 4)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    z = jax.random.normal(ks[0], shape)
    eps = jax.random.normal(ks[1], shape)
    noise = jax.random.normal(ks[2], shape)
    t = jnp.array([40, 40])
    t_prev = jnp.array([30, 30])
    for eta, n in ((0.0, None), (0.5, noise)):
        want = ddim.ddim_step(sched, z, eps, t, t_prev, eta=eta, noise=n)
        got = ddim_update_ref(z, eps, sched.alphas_cumprod[t],
                              sched.alphas_cumprod[t_prev], n, eta=eta)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)
    # ops-level dispatch on CPU serves the ref expression tree verbatim
    # (compare jit-to-jit: the op is jitted, and eager-vs-jit differs at
    # ulp scale because XLA fuses/reorders the arithmetic)
    got = ddim_update_op(z, eps, sched.alphas_cumprod[t],
                         sched.alphas_cumprod[t_prev], noise, eta=0.5)
    want = jax.jit(lambda *a: ddim_update_ref(*a, eta=0.5))(
        z, eps, sched.alphas_cumprod[t], sched.alphas_cumprod[t_prev], noise)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# the backend switch
# ---------------------------------------------------------------------------


def test_backend_switch_roundtrip():
    assert kb.get_backend() in kb.BACKENDS
    prev = kb.get_backend()
    with kb.use_backend("pallas"):
        assert kb.get_backend() == "pallas"
        with kb.use_backend("xla"):
            assert kb.get_backend() == "xla"
        assert kb.get_backend() == "pallas"
    assert kb.get_backend() == prev
    with pytest.raises(ValueError):
        kb.set_backend("triton")


def test_resolve_interpret_precedence(monkeypatch):
    # explicit argument beats everything
    assert kb.resolve_interpret(True) is True
    assert kb.resolve_interpret(False) is False
    # env override beats auto-detection
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert kb.resolve_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert kb.resolve_interpret() is True
    # auto-detect: this suite pins the CPU backend -> interpret
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert kb.resolve_interpret() is True


def test_env_seeds_backend(monkeypatch):
    monkeypatch.setitem(kb._state, "backend", None)
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert kb.get_backend() == "pallas"
    monkeypatch.setitem(kb._state, "backend", None)
    monkeypatch.setenv("REPRO_KERNELS", "bogus")
    with pytest.raises(ValueError):
        kb.get_backend()
    monkeypatch.setitem(kb._state, "backend", None)
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    assert kb.get_backend() == "xla"


def test_sampler_cache_key_includes_backend():
    """Flipping --kernels must never serve the other backend's executable."""
    from repro import cache as cache_lib
    from repro.configs.base import ModelConfig
    from repro.sampling.trajectory import _sampler_cache_key
    cfg = ModelConfig(name="k", family="dit", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, dit_patch=2,
                      dit_input_size=8, dit_in_channels=4, dit_n_classes=4,
                      rope_type="none", dtype="float32")
    pol = cache_lib.get_policy("none")
    with kb.use_backend("xla"):
        k_xla = _sampler_cache_key(cfg, pol, 4, 1.5, 0.0, None, False)
    with kb.use_backend("pallas"):
        k_pl = _sampler_cache_key(cfg, pol, 4, 1.5, 0.0, None, False)
    assert k_xla != k_pl
    assert "xla" in k_xla and "pallas" in k_pl


# ---------------------------------------------------------------------------
# end-to-end: the pallas backend against the xla baseline
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_dit():
    from repro.configs.base import LazyConfig, ModelConfig
    from repro.models import dit as dit_lib
    from repro.sampling import ddim
    cfg = ModelConfig(name="dit_kern", family="dit", n_layers=2, d_model=48,
                      n_heads=2, n_kv_heads=2, d_ff=96, dit_patch=2,
                      dit_input_size=8, dit_in_channels=4, dit_n_classes=6,
                      rope_type="none", dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    sched = ddim.linear_schedule(60)
    return cfg, params, sched


@pytest.mark.parametrize("variant", ["static_router", "lazy_gate", "eta"])
def test_backend_end_to_end_parity(tiny_dit, variant):
    """On CPU the pallas backend realizes the SAME graph semantics via
    cond-hoisting / the fused-select oracle, so sampling is bit-exact
    against the xla baseline for the plan path, the masked gate path, and
    the stochastic (eta > 0) DDIM update."""
    from repro import cache as cache_lib
    from repro.sampling import ddim
    cfg, params, sched = tiny_dit
    labels = jnp.arange(2) % cfg.dit_n_classes
    kw = dict(key=jax.random.PRNGKey(9), labels=labels, n_steps=4,
              cfg_scale=1.5)
    if variant == "static_router":
        kw["policy"] = cache_lib.get_policy("static_router", ratio=0.5)
    elif variant == "lazy_gate":
        kw["policy"] = cache_lib.get_policy("lazy_gate", threshold=0.1)
    else:
        kw["eta"] = 0.5
    outs = {}
    for name in ("xla", "pallas"):
        with kb.use_backend(name):
            x, _ = ddim.ddim_sample(params, cfg, sched, **kw)
            outs[name] = np.asarray(jax.block_until_ready(x))
    assert np.all(np.isfinite(outs["xla"]))
    assert np.array_equal(outs["xla"], outs["pallas"]), (
        f"{variant}: pallas backend diverged from the xla baseline "
        f"(max abs {np.abs(outs['xla'] - outs['pallas']).max():.3e})")


def test_backend_env_flag_matches_cli_contract():
    """REPRO_KERNELS is the env twin of --kernels (launch/serve, launch/obs):
    both route through backend.set_backend."""
    assert os.environ.get("REPRO_KERNELS", "") in ("", "xla", "pallas")
