"""SLO-aware admission: per-request policy selection, shed-at-admission,
priority preemption with bit-identical resume, policy-bank parity."""
import functools

import jax
import numpy as np
import pytest

from repro import cache as cache_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.data.synthetic import (SLORequestSpec, request_trace,
                                  slo_request_trace)
from repro.models import transformer as tf
from repro.serving.admission import (SHED_OVERLOAD, SHED_UNSATISFIABLE,
                                     AdmissionController,
                                     default_policy_bank, quality_budget_ok)
from repro.serving.engine import ContinuousBatchingEngine


def tiny(**kw):
    base = dict(n_layers=3, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                d_ff=64, vocab_size=61, dtype="float32",
                lazy=LazyConfig(enabled=True, mode="masked"))
    base.update(kw)
    return ModelConfig(**base)


@functools.lru_cache(maxsize=2)
def fixture():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def slo_engine(cfg, params, *, n_slots=2, max_len=32, **adm_kw):
    return ContinuousBatchingEngine(
        cfg, params, n_slots=n_slots, max_len=max_len,
        policy_bank=default_policy_bank(lazy_ratio=0.5, seed=0),
        admission=AdmissionController(**adm_kw))


def slo_req(rid, arrival, *, prompt_len=4, max_new=5, slo=1e4,
            max_skip=1.0, priority=0, vocab=61):
    prompt = np.random.default_rng(rid).integers(
        0, vocab, prompt_len).astype(np.int32)
    return SLORequestSpec(rid=rid, arrival=arrival, prompt=prompt,
                          max_new=max_new, slo_latency_s=slo,
                          max_skip_ratio=max_skip, priority=priority)


# ---------------------------------------------------------------------------
# Controller unit behavior (no engine)
# ---------------------------------------------------------------------------


def bound_controller(**kw):
    ctrl = AdmissionController(**kw)
    ctrl.bind({"quality": 0.0, "balanced": 0.25, "latency": 0.5}, n_slots=2)
    return ctrl


def test_decide_before_bind_raises():
    with pytest.raises(RuntimeError, match="bind"):
        AdmissionController().decide(slo_req(0, 0.0))


def test_quality_budget_restricts_classes():
    ctrl = bound_controller()
    d = ctrl.decide(slo_req(0, 0.0, max_skip=0.3))
    assert d.admitted and d.policy_class in ("quality", "balanced")
    # no class fits a negative budget -> unsatisfiable, never queued
    d = ctrl.decide(slo_req(1, 0.0, max_skip=-1.0))
    assert not d.admitted and d.reason == SHED_UNSATISFIABLE


def test_tight_deadline_selects_high_skip_class():
    ctrl = bound_controller()
    loose = ctrl.decide(slo_req(0, 0.0, max_new=8, slo=1e4, max_skip=0.9))
    assert loose.policy_class == "quality"      # best quality wins when idle
    # a deadline only the high-skip class can make under queueing pressure
    est_fast = ctrl.est_service_s(4, 8, 0.5)
    est_best = ctrl.est_service_s(4, 8, 0.0)
    slo = (est_fast + 1.0) / ctrl.slack
    tight = ctrl.decide(slo_req(1, 0.0, max_new=8, slo=slo, max_skip=0.9),
                        queue_wait_s=0.0)
    assert tight.admitted
    assert est_best * ctrl.slack > 0  # sanity: estimates are positive
    assert tight.est_service_s <= slo


def test_overload_shed_vs_serve_anyway():
    strict = bound_controller()
    req = slo_req(0, 0.0, max_new=6, slo=20.0, max_skip=0.9)
    d = strict.decide(req, queue_wait_s=1e3)
    assert not d.admitted and d.reason == SHED_OVERLOAD
    lenient = bound_controller(shed_on_overload=False)
    d2 = lenient.decide(req, queue_wait_s=1e3)
    assert d2.admitted and d2.policy_class == "latency"


def test_quality_budget_ok_helper():
    ratios = {"quality": 0.0, "latency": 0.5}
    assert quality_budget_ok(ratios, "quality", 0.05)
    assert not quality_budget_ok(ratios, "latency", 0.05)
    assert quality_budget_ok(ratios, "latency", 0.5)


# ---------------------------------------------------------------------------
# Engine integration: shed at admission, not after queueing
# ---------------------------------------------------------------------------


def test_unsatisfiable_slo_sheds_at_admission():
    """A deadline no bank class can make on an IDLE pool is refused the
    moment the request arrives: it never queues, never holds a slot, and
    its shed timestamp equals its arrival."""
    cfg, params = fixture()
    eng = slo_engine(cfg, params)
    doomed = slo_req(0, arrival=1.5, max_new=8, slo=0.5, max_skip=0.9)
    ok = slo_req(1, arrival=2.0, max_new=4, slo=1e4, max_skip=0.9)
    res = eng.run([doomed, ok])
    met = res.metrics
    assert 0 in met.shed and 0 not in met.requests
    assert met.shed[0]["reason"] == SHED_UNSATISFIABLE
    assert met.shed[0]["t"] == pytest.approx(1.5)     # at arrival, no queue
    assert 1 in met.requests and met.requests[1]["done"] is not None
    assert 0 not in res.outputs


def test_admitted_requests_get_bank_classes():
    cfg, params = fixture()
    eng = slo_engine(cfg, params)
    trace = slo_request_trace(8, cfg.vocab_size, seed=0,
                              mean_interarrival=2.0,
                              short_prompt=(4, 4), long_prompt=(8, 8),
                              short_output=(3, 5), long_output=(6, 8))
    met = eng.run(trace).metrics
    assert met.requests, "nothing admitted"
    for row in met.requests.values():
        assert row["policy_class"] in eng.bank_ratios
    for row in met.shed.values():
        assert row["reason"] in (SHED_UNSATISFIABLE, SHED_OVERLOAD)
    # per-class breakdown covers exactly the classes seen
    seen = ({r["policy_class"] for r in met.requests.values()}
            | {s["policy_class"] for s in met.shed.values()})
    assert set(met.class_summary()) == seen


# ---------------------------------------------------------------------------
# Preemption: bit-identical continuation
# ---------------------------------------------------------------------------


def test_preempted_request_resumes_bit_identical():
    """A priority-2 arrival evicts the only active slot; the victim's KV +
    lazy caches and traced policy state are snapshotted, the slot is
    reused, and on resume the victim's remaining tokens continue exactly
    where they left off — its full output equals an uninterrupted run."""
    cfg, params = fixture()
    victim = slo_req(0, arrival=0.0, max_new=8, slo=1e4, max_skip=0.6,
                     priority=0)
    preemptor = slo_req(1, arrival=3.0, prompt_len=4, max_new=3, slo=1e4,
                        max_skip=0.9, priority=2)

    solo = slo_engine(cfg, params, n_slots=1).run([victim])
    assert solo.metrics.summary()["n_preemptions"] == 0

    both = slo_engine(cfg, params, n_slots=1).run([victim, preemptor])
    met = both.metrics
    assert met.summary()["n_preemptions"] >= 1
    assert met.requests[0]["n_preempted"] >= 1
    assert met.requests[0]["done"] is not None
    assert met.requests[1]["done"] is not None
    np.testing.assert_array_equal(both.outputs[0], solo.outputs[0])
    # the preemptor jumped the queue: it finished before the victim
    assert met.requests[1]["done"] < met.requests[0]["done"]


# ---------------------------------------------------------------------------
# Determinism + policy-bank parity
# ---------------------------------------------------------------------------


def test_policy_selection_deterministic_under_seeded_trace():
    """Two fresh engines over the same seeded SLO trace make identical
    admission decisions (class per rid, shed set) and emit identical
    tokens — selection is a pure function of (request, queue estimate)."""
    cfg, params = fixture()
    trace = slo_request_trace(10, cfg.vocab_size, seed=7,
                              mean_interarrival=1.0,
                              short_prompt=(4, 4), long_prompt=(8, 8),
                              short_output=(3, 5), long_output=(6, 8))
    runs = []
    for _ in range(2):
        res = slo_engine(cfg, params).run(
            [SLORequestSpec(**vars(r)) for r in trace])
        met = res.metrics
        runs.append((
            {rid: row["policy_class"] for rid, row in met.requests.items()},
            {rid: row["reason"] for rid, row in met.shed.items()},
            {rid: out.tolist() for rid, out in res.outputs.items()},
        ))
    assert runs[0] == runs[1]
    assert runs[0][0], "nothing admitted"


def test_bank_single_class_matches_fixed_policy_engine():
    """A one-class bank must serve byte-identical tokens to the plain
    fixed-policy engine running that same policy — the lcm-tiled bank is
    exact, not an approximation (engine._compile_bank)."""
    cfg, params = fixture()
    trace = tuple(request_trace(5, cfg.vocab_size, seed=3,
                                mean_interarrival=0.4,
                                short_prompt=(3, 3), long_prompt=(6, 6),
                                short_output=(3, 5), long_output=(6, 8)))
    fixed = ContinuousBatchingEngine(
        cfg, params, n_slots=2, max_len=32,
        policy=cache_lib.get_policy("static_router", ratio=0.5, seed=0))
    banked = ContinuousBatchingEngine(
        cfg, params, n_slots=2, max_len=32,
        policy_bank={"only": cache_lib.get_policy("static_router",
                                                  ratio=0.5, seed=0)})
    res_f = fixed.run(trace)
    res_b = banked.run(trace)
    assert banked.bank_ratios["only"] == pytest.approx(fixed.plan_ratio)
    assert set(res_f.outputs) == set(res_b.outputs)
    for rid in res_f.outputs:
        np.testing.assert_array_equal(res_f.outputs[rid], res_b.outputs[rid])
    s_f, s_b = res_f.metrics.summary(), res_b.metrics.summary()
    assert s_b["realized_lazy_ratio"] == pytest.approx(
        s_f["realized_lazy_ratio"])


def test_bank_requires_admission_to_have_bank():
    cfg, params = fixture()
    with pytest.raises(ValueError, match="requires a policy_bank"):
        ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                 admission=AdmissionController())
