"""Serving engine: prefill parity, greedy generation, lazy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LazyConfig, ModelConfig, SSMConfig
from repro.models import transformer as tf
from repro.serving.engine import Engine


def tiny(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("name,cfg", [
    ("dense", tiny()),
    ("swa", tiny(attn_window_pattern=(4,))),
    ("mamba2", tiny(block_pattern=("mamba2",),
                    ssm=SSMConfig(state_dim=16, head_dim=16, chunk=4))),
])
def test_prefill_matches_stepwise(name, cfg):
    """One-shot prefill then decode must equal token-by-token decode."""
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    B, P = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)

    # stepwise
    cache = tf.init_decode_cache(cfg, B, max_len=16)
    for i in range(P):
        lg_step, cache, _, _ = tf.decode_step(params, cfg, toks[:, i:i + 1],
                                              jnp.int32(i), cache)
    # one-shot prefill
    cache2 = tf.init_decode_cache(cfg, B, max_len=16)
    lg_pre, cache2, _, _ = tf.decode_step(params, cfg, toks, jnp.int32(0), cache2)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1]), np.asarray(lg_step[:, 0]),
                               rtol=2e-2, atol=2e-2)
    # and the caches must continue identically
    nxt = jnp.argmax(lg_pre[:, -1:], axis=-1).astype(jnp.int32)
    a, _, _, _ = tf.decode_step(params, cfg, nxt, jnp.int32(P), cache)
    b, _, _, _ = tf.decode_step(params, cfg, nxt, jnp.int32(P), cache2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)


def test_engine_greedy_generation():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=32)
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4)).astype(np.int32)
    res = eng.generate(prompt, n_new=5)
    assert res.tokens.shape == (2, 9)
    assert res.realized_lazy_ratio == 0.0


def test_engine_lazy_masked_decode():
    cfg = tiny(lazy=LazyConfig(enabled=True, mode="masked"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=32, lazy_mode="masked")
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4)).astype(np.int32)
    res = eng.generate(prompt, n_new=6)
    assert res.tokens.shape == (2, 10)
    assert res.scores is not None and res.scores.shape[0] == 5
    assert np.all((res.scores >= 0) & (res.scores <= 1))


def test_engine_single_token_prompt_goes_through_prefill():
    """P == 1 must use the same prefill path as P > 1: position 0 is
    written, and generation matches a manual stepwise decode."""
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.array([[5], [41]], np.int32)
    res = Engine(cfg, params, max_len=16).generate(prompt, n_new=4)

    cache = tf.init_decode_cache(cfg, 2, max_len=16)
    lg, cache, _, _ = tf.decode_step(params, cfg, jnp.asarray(prompt),
                                     jnp.int32(0), cache)
    nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
    expect = [prompt]
    for i in range(4):
        lg, cache, _, _ = tf.decode_step(params, cfg, nxt[:, None],
                                         jnp.int32(1 + i), cache)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        expect.append(np.asarray(nxt)[:, None])
    np.testing.assert_array_equal(res.tokens, np.concatenate(expect, axis=1))


def test_engine_validates_prompt_early():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="integer"):
        eng.generate(np.zeros((2, 4), np.float32), n_new=2)
    with pytest.raises(ValueError, match="shape"):
        eng.generate(np.zeros(4, np.int32), n_new=2)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(np.zeros((2, 4), np.int32), n_new=100)
    with pytest.raises(ValueError, match="at least one token"):
        eng.generate(np.zeros((2, 0), np.int32), n_new=2)


def test_engine_plan_mode():
    """Plan mode threads LazyPlan rows as traced selects: tokens stay
    parity-exact when the plan never skips, and the realized ratio reflects
    the plan when it does."""
    from repro.core import lazy as lazy_lib
    cfg = tiny(lazy=LazyConfig(enabled=True, mode="plan"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 4)).astype(np.int32)
    n_new = 6
    empty = lazy_lib.uniform_plan(n_new, cfg.n_layers, 2, 0.0)
    res_off = Engine(cfg, params, max_len=32, lazy_mode="off").generate(
        prompt, n_new=n_new)
    res_p0 = Engine(cfg, params, max_len=32, lazy_mode="plan",
                    plan=empty).generate(prompt, n_new=n_new)
    np.testing.assert_array_equal(res_off.tokens, res_p0.tokens)
    assert res_p0.realized_lazy_ratio == 0.0

    half = lazy_lib.uniform_plan(n_new, cfg.n_layers, 2, 0.5, seed=1)
    res_p5 = Engine(cfg, params, max_len=32, lazy_mode="plan",
                    plan=half).generate(prompt, n_new=n_new)
    assert res_p5.tokens.shape == (2, 4 + n_new)
    assert 0.1 < res_p5.realized_lazy_ratio < 0.7
    with pytest.raises(ValueError, match="requires a plan"):
        Engine(cfg, params, max_len=32, lazy_mode="plan")


def test_masked_mode_with_diligent_gates_matches_off():
    """Untrained probes (init bias -2 -> s≈0.12 < 0.5) must never skip:
    masked-mode generation equals off-mode token-for-token."""
    from repro.configs.base import LazyConfig
    cfg = tiny(lazy=LazyConfig(enabled=True, mode="masked"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size,
                                               (2, 5)).astype(np.int32)
    res_off = Engine(cfg, params, max_len=32, lazy_mode="off").generate(
        prompt, n_new=8)
    res_m = Engine(cfg, params, max_len=32, lazy_mode="masked").generate(
        prompt, n_new=8)
    np.testing.assert_array_equal(res_off.tokens, res_m.tokens)
    assert res_m.realized_lazy_ratio == 0.0
