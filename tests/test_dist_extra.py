"""Regressions for dist-layer edges beyond the seed's test_dist.py:
reduce-scatter ring accounting, async collective payloads, and stacked
(period) cache leaves whose n_repeats dim collides with the batch size."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import hlo as hlo_lib
from repro.dist import sharding as sh


def test_reduce_scatter_seconds_match_all_gather():
    """A reduce-scatter's tallied bytes are the 1/n-size result; its ring
    time must equal the all-gather of the same full buffer, not be n×
    cheaper."""
    n = 4
    ag = {"all-gather": {"bytes": 32768, "count": 1}}       # full result
    rs = {"reduce-scatter": {"bytes": 32768 // n, "count": 1}}  # shard result
    bw = 1e9
    t_ag = hlo_lib.collective_seconds(ag, n, bw)
    t_rs = hlo_lib.collective_seconds(rs, n, bw)
    np.testing.assert_allclose(t_rs, t_ag, rtol=1e-12)
    # all-reduce = reduce-scatter + all-gather
    ar = {"all-reduce": {"bytes": 32768, "count": 1}}
    np.testing.assert_allclose(hlo_lib.collective_seconds(ar, n, bw),
                               t_ag + t_rs, rtol=1e-12)


def test_async_collective_payload_matches_sync():
    """-start ops carry an (operands, result) tuple shape; only the result
    counts, so async and sync forms of one program tally identically."""
    sync = """
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  ROOT %ag = f32[16,128]{1,0} all-gather(%p0), dimensions={0}
}
"""
    asyn = """
ENTRY %main (p0: f32[4,128]) -> f32[16,128] {
  %p0 = f32[4,128]{1,0} parameter(0)
  %ags = (f32[4,128]{1,0}, f32[16,128]{1,0}) all-gather-start(%p0), dimensions={0}
  ROOT %agd = f32[16,128]{1,0} all-gather-done(%ags)
}
"""
    a = hlo_lib.collective_bytes(sync)["all-gather"]
    b = hlo_lib.collective_bytes(asyn)["all-gather"]
    assert a == b == {"bytes": 16 * 128 * 4, "count": 1}


def test_cache_shardings_stacked_nrep_equal_to_batch():
    """Period caches carry a leading n_repeats dim; when n_repeats == B the
    batch dim must still resolve by POSITION (dim 1 under 'period'), and
    heads mode must land on the heads dim, not the window."""
    B = 4
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    nrep, W, KV, hd = B, 8, 2, 16       # adversarial: nrep == batch
    cache = {
        "prefix": ({"attn": {"k": jax.ShapeDtypeStruct((B, W, KV, hd), jnp.float32),
                             "pos": jax.ShapeDtypeStruct((W,), jnp.int32)}},),
        "period": ({"attn": {"k": jax.ShapeDtypeStruct((nrep, B, W, KV, hd), jnp.float32),
                             "pos": jax.ShapeDtypeStruct((nrep, W), jnp.int32)}},),
        "suffix": (),
    }
    shd = sh.cache_shardings(cache, mesh, B, shard_heads=True)
    pk = shd["prefix"][0]["attn"]["k"].spec
    assert pk[0] == ("data",) and pk[2] == "model", pk
    sk = shd["period"][0]["attn"]["k"].spec
    assert sk[0] is None, "n_repeats dim must not be sharded as batch"
    assert sk[1] == ("data",), "batch is dim 1 under period"
    assert sk[3] == "model", "heads mode must hit the heads dim"
    # pos vectors replicated even when a dim size collides with B
    assert all(e is None for e in shd["period"][0]["attn"]["pos"].spec)
