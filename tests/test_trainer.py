"""Lazy-learning trainer + learned-schedule harness (train/trainer,
train/learned): gradient masking BEFORE global-norm clipping, the
frozen-leaf AdamW contract, recipe direction (lazy loss down, diffusion
loss bounded, base weights bit-exact), mid-recipe checkpoint resume, and
trained-schedule distillation round-tripping through the fused executor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import get_policy
from repro.cache.schedule import ScheduleArtifact
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.models import dit as dit_lib
from repro.sampling import ddim, trajectory
from repro.train import learned, optim, trainer


def dit_tiny(**kw):
    base = dict(name="dit_tiny", family="dit", n_layers=3, d_model=64,
                n_heads=4, n_kv_heads=4, d_ff=128, dit_patch=2,
                dit_input_size=8, dit_in_channels=4, dit_n_classes=10,
                rope_type="none", dtype="float32",
                lazy=LazyConfig(enabled=True, mode="soft",
                                rho_attn=1e-2, rho_ffn=1e-2))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = dit_tiny()
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    sched = ddim.linear_schedule(100)
    return cfg, params, sched


def split_leaves(params):
    """(gate_leaves, base_leaves) as {path: np.ndarray}."""
    mask = trainer.gate_mask(params)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_m = jax.tree_util.tree_leaves(mask)
    gates, base = {}, {}
    for (path, leaf), m in zip(flat_p, flat_m):
        (gates if m else base)[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return gates, base


# ---------------------------------------------------------------------------
# satellite: grads masked to the gate subtree BEFORE global-norm clipping
# ---------------------------------------------------------------------------


def test_mask_grads_zeroes_only_frozen_leaves(setup):
    _, params, _ = setup
    mask = trainer.gate_mask(params)
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    masked = trainer.mask_grads(grads, mask)
    for g, m in zip(jax.tree_util.tree_leaves(masked),
                    jax.tree_util.tree_leaves(mask)):
        if m:
            np.testing.assert_array_equal(np.asarray(g), 1.0)
        else:
            np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_clip_after_masking_sees_only_gate_norm():
    """The bug this PR fixes: clipping the raw tree let the frozen trunk's
    gradient norm scale the probe updates down.  After masking, the
    global norm IS the gate norm — a huge frozen-leaf gradient must not
    shrink a small gate gradient at all."""
    grads = {"blk": {"w": jnp.full((64, 64), 1e3),       # frozen, huge
                     "g_attn": {"w": jnp.full((4,), 0.3)}}}
    mask = trainer.gate_mask(grads)
    masked = trainer.mask_grads(grads, mask)
    clipped, gnorm = optim.clip_by_global_norm(masked, 1.0)
    np.testing.assert_allclose(float(gnorm), 0.3 * 2.0, rtol=1e-6)
    # gate norm 0.6 < 1.0 -> the gate gradient passes through UNSCALED
    np.testing.assert_allclose(np.asarray(clipped["blk"]["g_attn"]["w"]),
                               0.3, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(clipped["blk"]["w"]), 0.0)


# ---------------------------------------------------------------------------
# satellite: frozen leaves are bit-identical through adamw_update
# ---------------------------------------------------------------------------


def test_adamw_frozen_leaves_bit_identical_with_zero_moments(setup):
    """Regression: a masked AdamW step must leave frozen leaves
    BIT-identical with their moments exactly zero — weight decay, bias
    correction, and the moment EMAs must all be dead on masked leaves,
    even when (hypothetically) nonzero gradients reach them."""
    _, params, _ = setup
    mask = trainer.gate_mask(params)
    opt = optim.adamw_init(params)
    grads = jax.tree.map(lambda p: jnp.full_like(p, 0.37), params)
    p = params
    for _ in range(3):
        p, opt = optim.adamw_update(opt, grads, p, lr=1e-2,
                                    weight_decay=0.01, mask=mask)
    _, base0 = split_leaves(params)
    gates1, base1 = split_leaves(p)
    assert gates1  # the mask found the probes at all
    for k in base0:
        np.testing.assert_array_equal(
            base0[k], base1[k], err_msg=f"frozen leaf {k} moved")
    flat_mu = jax.tree_util.tree_flatten_with_path(opt.mu)[0]
    flat_nu = jax.tree_util.tree_leaves(opt.nu)
    flat_m = jax.tree_util.tree_leaves(mask)
    for (path, mu), nu, m in zip(flat_mu, flat_nu, flat_m):
        if not m:
            np.testing.assert_array_equal(
                np.asarray(mu), 0.0,
                err_msg=f"frozen mu {jax.tree_util.keystr(path)} nonzero")
            np.testing.assert_array_equal(np.asarray(nu), 0.0)


# ---------------------------------------------------------------------------
# satellite: explicit rho mapping in the lazy loss
# ---------------------------------------------------------------------------


def test_lazy_loss_unknown_kind_raises():
    s = jnp.full((2, 3), 0.5)
    with pytest.raises(ValueError, match="unknown gated-module kind"):
        lazy_lib.lazy_loss({"attn": s, "cross_attn": s}, 1e-2, 1e-2)


def test_lazy_loss_explicit_rho_per_kind():
    s = jnp.full((2, 3), 0.75)            # sum_l (1 - s) = 0.5 per kind
    got = float(lazy_lib.lazy_loss({"attn": s, "ffn": s, "block": s},
                                   0.1, 0.2, rho_block=0.4))
    np.testing.assert_allclose(got, 0.5 * (0.1 + 0.2 + 0.4), rtol=1e-6)
    # block defaults to rho_ffn when no rho_block is given
    got2 = float(lazy_lib.lazy_loss({"block": s}, 0.1, 0.2))
    np.testing.assert_allclose(got2, 0.5 * 0.2, rtol=1e-6)


# ---------------------------------------------------------------------------
# the lazy recipe: direction + frozen trunk
# ---------------------------------------------------------------------------


def test_lazy_recipe_trains_gates_only(setup):
    cfg, params, sched = setup
    p1, opt1, hist = learned.train_lazy_gates(
        params, cfg, sched, steps=20, batch=8, lr=5e-2, n_sample_steps=6,
        seed=0)
    first, last = hist[0], hist[-1]
    # laziness is learned: the lazy loss drops, scores rise...
    assert last["lazy_loss"] < first["lazy_loss"]
    assert last["s_attn"] > first["s_attn"]
    # ...with the diffusion term bounded (the probes may not wreck eps)
    assert np.isfinite(last["loss"])
    assert last["diffusion_loss"] < 4.0 * max(first["diffusion_loss"], 1e-3)
    # and the frozen trunk is BIT-exact
    _, base0 = split_leaves(params)
    gates1, base1 = split_leaves(p1)
    for k in base0:
        np.testing.assert_array_equal(
            base0[k], base1[k], err_msg=f"base weight {k} moved")
    # while the probes actually moved
    gates0, _ = split_leaves(params)
    assert any(not np.array_equal(gates0[k], gates1[k]) for k in gates0)


def test_lazy_recipe_checkpoint_resume_bit_exact(setup, tmp_path):
    cfg, params, sched = setup
    ck = str(tmp_path / "lazy.npz")
    # straight 8-step run
    pa, oa, _ = learned.train_lazy_gates(
        params, cfg, sched, steps=8, batch=4, lr=1e-2, n_sample_steps=6,
        seed=3)
    # interrupted at step 4, checkpointed, restored, continued to 8
    learned.train_lazy_gates(
        params, cfg, sched, steps=4, batch=4, lr=1e-2, n_sample_steps=6,
        seed=3, ckpt_path=ck, ckpt_every=4)
    p_r, opt_r, nxt = learned.restore_train_state(ck, params)
    assert nxt == 4
    pb, ob, _ = learned.train_lazy_gates(
        p_r, cfg, sched, steps=8, batch=4, lr=1e-2, n_sample_steps=6,
        seed=3, opt_state=opt_r, start_step=nxt)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pa, pb)
    # optimizer state (moments + step counter) resumes bit-exactly too
    assert int(oa.step) == int(ob.step)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), oa.mu, ob.mu)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), oa.nu, ob.nu)


# ---------------------------------------------------------------------------
# distillation: trained gates -> artifact -> fused executor, with parity
# ---------------------------------------------------------------------------


def test_distilled_schedule_roundtrips_through_fused_sampler(setup, tmp_path):
    cfg, params, sched = setup
    p1, _, _ = learned.train_lazy_gates(
        params, cfg, sched, steps=10, batch=8, lr=5e-2, n_sample_steps=5,
        seed=1)
    labels = jnp.array([0, 1])
    art = learned.distill_gate_schedule(
        p1, cfg, sched, key=jax.random.PRNGKey(2), labels=labels,
        n_steps=5, target_ratio=0.4)
    assert not art.skip[0].any() and not art.skip[-1].any()
    assert art.lazy_ratio > 0.0
    # JSON round trip preserves the artifact exactly
    path = str(tmp_path / "sched.json")
    art.save(path)
    art2 = ScheduleArtifact.load(path)
    np.testing.assert_array_equal(art.skip, art2.skip)
    np.testing.assert_allclose(art.scores, art2.scores)
    # the learned policy serves the plan through BOTH executors, bit-exact
    pol = get_policy("learned", path=path)
    kw = dict(key=jax.random.PRNGKey(4), labels=labels, n_steps=5,
              cfg_scale=1.5)
    ref, _ = ddim.ddim_sample_reference(params, cfg, sched, policy=pol, **kw)
    fused, aux = trajectory.sample_trajectory(params, cfg, sched,
                                              policy=pol, **kw)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))
    np.testing.assert_allclose(aux["realized_skip_ratio"], art.lazy_ratio,
                               atol=1e-6)


def test_learned_policy_resamples_to_other_horizons():
    rng = np.random.default_rng(0)
    art = ScheduleArtifact(
        kind="lazy_gate", arch="dit_tiny", n_steps=6, n_layers=3,
        modules=("attn", "ffn"),
        scores=rng.uniform(0, 1, (6, 3, 2)),
        skip=lazy_lib.plan_from_scores(
            rng.uniform(0, 1, (6, 3, 2)), 0.5).skip,
        target_ratio=0.4)
    pol = get_policy("learned", artifact=art)
    for T in (4, 9):
        plan = pol.compile_plan(T, 3, 2)
        assert plan.skip.shape == (T, 3, 2)
        assert not plan.skip[0].any()


# ---------------------------------------------------------------------------
# learned router: differentiable gates through the relaxed trajectory
# ---------------------------------------------------------------------------


def test_mix_cached_hardening_recovers_select():
    rng = np.random.default_rng(1)
    y_new = jnp.asarray(rng.normal(size=(2, 5, 8)).astype(np.float32))
    cache = jnp.asarray(rng.normal(size=(2, 5, 8)).astype(np.float32))
    for w in (0.0, 1.0):
        mixed = lazy_lib.mix_cached(jnp.float32(w), y_new, cache)
        selected = lazy_lib.select_cached(jnp.bool_(w > 0.5), y_new, cache)
        np.testing.assert_array_equal(np.asarray(mixed),
                                      np.asarray(selected))
    # and the relaxation is differentiable in the gate weight
    g = jax.grad(lambda w: jnp.sum(lazy_lib.mix_cached(w, y_new, cache)))(
        jnp.float32(0.5))
    assert np.isfinite(float(g)) and float(g) != 0.0


def test_router_trains_and_distills(setup):
    cfg, params, sched = setup
    theta, hist = learned.train_router(
        params, cfg, sched, n_steps=4, target_ratio=0.4, steps=2, batch=2,
        lr=5e-2, cfg_scale=1.5)
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert all(np.isfinite(h["gnorm"]) and h["gnorm"] > 0.0 for h in hist)
    assert not np.array_equal(
        np.asarray(theta), np.asarray(learned.init_router_logits(4, 3)))
    art = learned.distill_router_schedule(theta, cfg, target_ratio=0.4)
    assert art.kind == "router"
    assert not art.skip[0].any() and not art.skip[-1].any()
    assert art.lazy_ratio > 0.0
    # router-quota shape: layers share the per-step budget to within the
    # one-module slack the globally-rotating refresh holes introduce
    per_layer = art.skip.sum(axis=2)                   # (T, L)
    assert (per_layer.max(axis=1) - per_layer.min(axis=1) <= 1).all()


# ---------------------------------------------------------------------------
# checkpoint extras
# ---------------------------------------------------------------------------


def test_save_restore_train_state_roundtrip(setup, tmp_path):
    _, params, _ = setup
    opt = optim.adamw_init(params)
    path = str(tmp_path / "state.npz")
    learned.save_train_state(path, params, opt, step=7)
    p2, opt2, nxt = learned.restore_train_state(path, params)
    assert nxt == 7 and int(opt2.step) == 0
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    assert os.path.exists(path)
