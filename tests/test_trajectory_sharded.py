"""Mesh-sharded fused trajectory executor (dist.ctx.mesh +
sampling/trajectory): per-example bit-exact parity across data=1/2/8
meshes, the compile-once-per-mesh contract, eta > 0 stochastic DDIM on
the reserved per-step keys, sharded-HLO accounting (dist/hlo), and the
continuous-batching engine's sharded slot pool + traced per-slot policy
state.

Mesh tests skip when the process has fewer devices than the mesh needs —
the multi-device CI leg (XLA_FLAGS=--xla_force_host_platform_device_count=8)
runs them against a real 8-device mesh; a subprocess smoke keeps ONE
sharded parity check alive even in the single-device suite."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache as cache_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import LatentImageDataset
from repro.dist import ctx, hlo as hlo_lib
from repro.models import dit as dit_lib
from repro.sampling import ddim, trajectory
from repro.train import optim, trainer

T, L, M = 5, 3, 2
# divides every tested data-axis size AND keeps >= 2 forward rows per
# shard even without CFG: a one-example shard hits XLA CPU's
# degenerate-dim GEMM path, which rounds ~1 ulp differently (the
# documented boundary of the bit-exactness contract, DESIGN.md
# §Trajectory)
BATCH = 16


def need_devices(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="dit_shard", family="dit", n_layers=L, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, dit_patch=2,
                      dit_input_size=8, dit_in_channels=4, dit_n_classes=10,
                      rope_type="none", dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    sched = ddim.linear_schedule(100)
    # brief pretraining so adaLN-zero gates are nonzero and skips reach
    # the sample (otherwise every parity check is vacuous)
    it = LatentImageDataset(cfg, seed=0).batches(8, seed=1)
    opt = optim.adamw_init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(10):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, _ = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    return cfg, params, sched


def make_policy(name):
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "lazy_gate":
        return cache_lib.get_policy("lazy_gate", threshold=0.1)
    if name == "plan":
        return cache_lib.get_policy(
            "plan", plan=lazy_lib.uniform_plan(T, L, M, 0.5, seed=0).skip)
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=0.5)
    raise ValueError(name)


def sample_kw(name, cfg_scale=1.5, eta=0.0):
    return dict(key=jax.random.PRNGKey(3),
                labels=jnp.arange(BATCH) % 10, n_steps=T,
                cfg_scale=cfg_scale, eta=eta, policy=make_policy(name))


# ---------------------------------------------------------------------------
# per-example bit-exact parity across mesh sizes
# ---------------------------------------------------------------------------


@need_devices(8)
@pytest.mark.parametrize("cfg_scale", [1.0, 1.5], ids=["cfg_off", "cfg_on"])
@pytest.mark.parametrize("name", ["stride", "lazy_gate", "plan",
                                  "static_router"])
def test_mesh_parity_bit_exact(setup, name, cfg_scale):
    """data=1, 2, 8 meshes all reproduce the no-mesh single-device sample
    bit-for-bit, per example — plan rows are batch-invariant, so sharding
    the batch must not change any decision or any bit."""
    cfg, params, sched = setup
    kw = sample_kw(name, cfg_scale=cfg_scale)
    base, aux = trajectory.sample_trajectory(params, cfg, sched, **kw)
    base = np.asarray(base)
    if name != "none":
        assert aux["realized_skip_ratio"] > 0.0, "vacuous parity: no skips"
    for n_data in (1, 2, 8):
        with ctx.mesh(data=n_data):
            got, aux_m = trajectory.sample_trajectory(params, cfg, sched,
                                                      **kw)
        np.testing.assert_array_equal(
            np.asarray(got), base,
            err_msg=f"{name} data={n_data} broke per-example bit-exactness")
        assert aux_m["realized_skip_ratio"] == pytest.approx(
            aux["realized_skip_ratio"]), \
            f"{name} data={n_data} changed the realized skip accounting"


@need_devices(8)
def test_mesh_parity_eta_stochastic(setup):
    """eta > 0 noise is keyed per example (ddim.per_example_keys), so the
    stochastic sampler is ALSO mesh-invariant bit-for-bit."""
    cfg, params, sched = setup
    kw = sample_kw("stride", eta=0.5)
    base, _ = trajectory.sample_trajectory(params, cfg, sched, **kw)
    with ctx.mesh(data=8):
        got, _ = trajectory.sample_trajectory(params, cfg, sched, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@need_devices(8)
def test_latents_actually_shard(setup):
    """The parity must not be trivial: under data=8 the returned latents
    carry a data-axis sharding with 8 shards."""
    cfg, params, sched = setup
    with ctx.mesh(data=8) as mesh:
        got, _ = trajectory.sample_trajectory(params, cfg, sched,
                                              **sample_kw("stride"))
        assert got.sharding.spec[0] == ("data",)
        assert len(got.sharding.device_set) == 8
        assert mesh.shape["data"] == 8


# ---------------------------------------------------------------------------
# compile-once per (config, policy, steps, guidance, eta, mesh)
# ---------------------------------------------------------------------------


@need_devices(8)
def test_single_compile_per_mesh(setup):
    cfg, params, sched = setup
    from benchmarks.bench_trajectory import compile_counter
    kw = sample_kw("stride")
    trajectory.build_sampler.cache_clear()
    with ctx.mesh(data=8):
        trajectory.sample_trajectory(params, cfg, sched, **kw)
        fn = trajectory.build_sampler(cfg, kw["policy"], T, 1.5, batch=BATCH)
        assert fn._cache_size() == 1
        # warm resample on the same mesh: zero new backend compiles
        with compile_counter() as c:
            trajectory.sample_trajectory(params, cfg, sched, **kw)
        assert c["n"] == 0, f"warm sharded sample compiled {c['n']} times"
        assert fn._cache_size() == 1
    # re-entering an equivalent mesh context must hit the same executable
    with ctx.mesh(data=8):
        with compile_counter() as c:
            trajectory.sample_trajectory(params, cfg, sched, **kw)
        assert c["n"] == 0, "equivalent mesh context retraced the sampler"


# ---------------------------------------------------------------------------
# sharded-HLO accounting (dist/hlo partitions + per-device vs global)
# ---------------------------------------------------------------------------


@need_devices(8)
def test_sharded_hlo_accounting(setup):
    """The compiled sharded scan reports partitions=8, ~1/8 the per-device
    FLOPs of the single-device program (the modeled >=4x batch-throughput
    scaling the bench asserts), and global FLOPs within 10% of the
    single-device total."""
    cfg, params, sched = setup
    pol = make_policy("static_router")
    labels = jnp.arange(BATCH) % 10
    flops = {}
    for n_data in (1, 8):
        trajectory.build_sampler.cache_clear()
        with ctx.mesh(data=n_data):
            fn = trajectory.build_sampler(cfg, pol, T, 1.5, batch=BATCH)
            args = trajectory.prepare_inputs(
                cfg, sched, pol, key=jax.random.PRNGKey(3), labels=labels,
                n_steps=T)
            mod = hlo_lib.sharded_totals(
                fn.lower(params, *args).compile().as_text())
        assert mod["partitions"] == n_data
        flops[n_data] = mod
    scaling = flops[1]["flops"] / flops[8]["flops"]
    assert scaling >= 4.0, f"modeled throughput scaling only {scaling:.2f}x"
    assert flops[8]["flops_global"] == pytest.approx(
        flops[1]["flops_global"], rel=0.10)
    # plan rows are replicated and CFG pairs are interleaved shard-local,
    # so the plan-mode scan body is COMMUNICATION-FREE — any collective
    # here means a layout regression (e.g. the old [z; z] concat, which
    # resharded every activation)
    assert not flops[8]["collective"], \
        f"plan-mode sharded scan grew collectives: {flops[8]['collective']}"


def test_module_partitions_parses_header_only():
    txt = ("HloModule jit_sample, entry_computation_layout={()->f32[]}, "
           "num_partitions=8\n\nENTRY %main () -> f32[] {\n"
           "  ROOT %c = f32[] constant(0), metadata={num_partitions=99}\n}\n")
    assert hlo_lib.module_partitions(txt) == 8
    assert hlo_lib.module_partitions("HloModule m\nENTRY %e () -> f32[] {\n"
                                     "}\n") == 1
    mod = hlo_lib.sharded_totals(txt)
    assert mod["partitions"] == 8
    assert mod["flops_global"] == mod["flops"] * 8


# ---------------------------------------------------------------------------
# mesh context plumbing (any device count)
# ---------------------------------------------------------------------------


def test_parse_mesh_spec():
    assert ctx.parse_mesh_spec("") == {"data": 1, "model": 1}
    assert ctx.parse_mesh_spec("data=8") == {"data": 8, "model": 1}
    assert ctx.parse_mesh_spec("data=4,model=2") == {"data": 4, "model": 2}
    for bad in ("dat=8", "data=0", "data=x", "8"):
        with pytest.raises(ValueError):
            ctx.parse_mesh_spec(bad)


def test_mesh_context_single_device():
    """data=1 meshes work on any host; the context activates and restores
    the thread-local state, and too-large meshes fail loudly."""
    assert ctx.current_mesh() is None
    with ctx.mesh(data=1) as m:
        assert ctx.current_mesh() is m
        assert ctx.mesh_cache_key() is not None
        with ctx.mesh(data=1):
            pass                         # nesting restores cleanly
        assert ctx.current_mesh() is m
    assert ctx.current_mesh() is None
    with pytest.raises(ValueError, match="devices"):
        ctx.build_mesh(data=10 ** 6)


def test_mesh_cache_key_stable_across_contexts():
    with ctx.mesh(data=1) as m1:
        k1 = ctx.mesh_cache_key()
    with ctx.mesh(data=1) as m2:
        k2 = ctx.mesh_cache_key()
    assert k1 == k2
    assert m1.axis_names == m2.axis_names


# ---------------------------------------------------------------------------
# sharded serving: slot pool over the data axis, traced per-slot state
# ---------------------------------------------------------------------------


@need_devices(8)
@pytest.mark.parametrize("mode", ["off", "plan"])
def test_sharded_serving_token_parity(mode):
    """The continuous-batching engine under mesh(data=8) — slot axis of
    every stacked tree (KV, lazy cache, traced policy state) sharded, one
    decode lane per device — serves every request the same greedy tokens
    as the unsharded engine."""
    from repro.data.synthetic import request_trace
    from repro.models import transformer as tf
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      head_dim=16, d_ff=64, vocab_size=61, dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    trace = list(request_trace(6, cfg.vocab_size, seed=3,
                               mean_interarrival=0.4,
                               short_prompt=(3, 3), long_prompt=(6, 6),
                               short_output=(3, 5), long_output=(6, 8)))
    plan = (lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
            if mode == "plan" else None)
    kw = dict(n_slots=8, max_len=32, lazy_mode=mode, plan=plan)
    base = ContinuousBatchingEngine(cfg, params, **kw).run(trace)
    with ctx.mesh(data=8) as mesh:
        eng = ContinuousBatchingEngine(cfg, params, **kw)
        sharded = eng.run(trace)
        # the pool must actually shard: 8 slots over 8 data shards
        leaf = jax.tree.leaves(eng._slot_state)[0]
        pool_sharded = len(leaf.sharding.device_set) == 8
    assert mesh.shape["data"] == 8
    assert pool_sharded, "slot-stacked state stayed on one device"
    for r in trace:
        np.testing.assert_array_equal(
            sharded.outputs[r.rid], base.outputs[r.rid],
            err_msg=f"rid={r.rid} mode={mode} diverged under the mesh")


# ---------------------------------------------------------------------------
# subprocess smoke: one sharded parity check even in the 1-device suite
# ---------------------------------------------------------------------------


_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
jax.config.update("jax_platforms", "cpu")
jax.config.update("jax_default_matmul_precision", "highest")
import jax.numpy as jnp
import numpy as np
from repro.configs.base import LazyConfig, ModelConfig
from repro.dist import ctx
from repro.models import dit as dit_lib
from repro.sampling import ddim, trajectory
from repro import cache as cache_lib

cfg = ModelConfig(name="dit_sub", family="dit", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, dit_patch=2,
                  dit_input_size=8, dit_in_channels=4, dit_n_classes=10,
                  rope_type="none", dtype="float32",
                  lazy=LazyConfig(enabled=True, mode="masked"))
params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
sched = ddim.linear_schedule(100)
kw = dict(key=jax.random.PRNGKey(3), labels=jnp.arange(8) % 10, n_steps=4,
          cfg_scale=1.5, policy=cache_lib.get_policy("stride", stride=2))
base, _ = trajectory.sample_trajectory(params, cfg, sched, **kw)
with ctx.mesh(data=8):
    got, _ = trajectory.sample_trajectory(params, cfg, sched, **kw)
print("RESULT " + json.dumps({
    "exact": bool(np.array_equal(np.asarray(base), np.asarray(got))),
    "n_dev": len(jax.devices()),
}))
"""


@pytest.mark.slow
def test_sharded_parity_subprocess_smoke():
    """8 fake devices need a fresh process (device count locks at first
    jax init) — this keeps one sharded bit-exactness check in the default
    single-device tier-1 run."""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.environ.get("PYTHONPATH", "")]))
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    res = json.loads(line[0][len("RESULT "):])
    assert res["n_dev"] == 8
    assert res["exact"], "sharded executor diverged from single-device"
