"""Fused trajectory executor (sampling/trajectory.py): bit-exact parity
with the host-loop reference for every registered policy × CFG on/off,
the single-compile contract (trace-cache + jax.monitoring probes), the
traceable policy-state pytree protocol, and the ddim_sample dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import LatentImageDataset
from repro.models import dit as dit_lib
from repro.sampling import ddim, trajectory
from repro.train import optim, trainer

T, L, M = 5, 3, 2       # sampling steps / layers / plan columns


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="dit_traj", family="dit", n_layers=L, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, dit_patch=2,
                      dit_input_size=8, dit_in_channels=4, dit_n_classes=10,
                      rope_type="none", dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    sched = ddim.linear_schedule(100)
    # brief pretraining matters: adaLN-zero inits every block's output gate
    # to 0, so on an UNTRAINED model module outputs (and therefore skips)
    # cannot reach the sample and every parity check would be vacuous
    it = LatentImageDataset(cfg, seed=0).batches(8, seed=1)
    opt = optim.adamw_init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(12):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, _ = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    return cfg, params, sched


def synth_dit_artifact(n_steps=T, n_layers=L, seed=0):
    rng = np.random.default_rng(seed)
    rel = rng.uniform(0.01, 1.0, (n_steps, n_layers, M))
    rel[0] = np.inf
    return calibrate_lib.CalibrationArtifact(
        kind="dit", arch="dit_traj", n_steps=n_steps, n_layers=n_layers,
        modules=("attn", "ffn"), rel_err=rel)


def make_policy(name):
    """All eight registered policies, parameterized so each actually skips
    (lazy_gate threshold below the untrained probes' ~0.12 scores)."""
    if name == "none":
        return cache_lib.get_policy("none")
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "lazy_gate":
        return cache_lib.get_policy("lazy_gate", threshold=0.1)
    if name == "smoothcache":
        art = synth_dit_artifact()
        return cache_lib.get_policy(
            "smoothcache", calibration=art,
            error_threshold=art.quantile_threshold(0.5))
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=0.5,
                                    calibration=synth_dit_artifact(seed=1))
    if name == "plan":
        return cache_lib.get_policy(
            "plan", plan=lazy_lib.uniform_plan(T, L, M, 0.5, seed=0).skip)
    if name == "delta":
        return cache_lib.get_policy("delta", ratio=0.5,
                                    calibration=synth_dit_artifact(seed=2))
    if name == "learned":
        rng = np.random.default_rng(3)
        art = cache_lib.distill_scores(
            "lazy_gate", "dit_traj", rng.uniform(0, 1, (T, L, M)),
            target_ratio=0.4)
        return cache_lib.get_policy("learned", artifact=art)
    raise ValueError(name)


ALL_POLICIES = ("none", "stride", "lazy_gate", "smoothcache",
                "static_router", "plan", "delta", "learned")


# ---------------------------------------------------------------------------
# bit-exact parity: fused scan == host-loop reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg_scale", [1.0, 1.5], ids=["cfg_off", "cfg_on"])
@pytest.mark.parametrize("name", ALL_POLICIES)
def test_fused_bit_exact_vs_host_reference(setup, name, cfg_scale):
    cfg, params, sched = setup
    pol = make_policy(name)
    kw = dict(key=jax.random.PRNGKey(3), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=cfg_scale)
    ref, _ = ddim.ddim_sample_reference(params, cfg, sched, policy=pol, **kw)
    fused, aux = trajectory.sample_trajectory(params, cfg, sched,
                                              policy=pol, **kw)
    assert np.array_equal(np.asarray(ref), np.asarray(fused)), \
        f"{name} (cfg_scale={cfg_scale}) fused != host reference"
    assert np.all(np.isfinite(np.asarray(fused)))
    if name in ("stride", "smoothcache", "static_router", "plan",
                "lazy_gate"):
        assert aux["realized_skip_ratio"] > 0.0, \
            f"{name} parity was vacuous: nothing was skipped"
    if name == "none":
        assert aux["realized_skip_ratio"] == 0.0


def test_legacy_lazy_mode_aliases_route_through_fused(setup):
    """ddim_sample's legacy (lazy_mode, plan) surface hits the fused path
    and still matches the reference loop."""
    cfg, params, sched = setup
    plan = lazy_lib.uniform_plan(T, L, M, 0.4, seed=2).skip
    kw = dict(key=jax.random.PRNGKey(5), labels=jnp.array([1, 2]),
              n_steps=T, cfg_scale=1.5)
    ref, _ = ddim.ddim_sample_reference(params, cfg, sched,
                                        lazy_mode="plan", plan=plan, **kw)
    got, aux = ddim.ddim_sample(params, cfg, sched, lazy_mode="plan",
                                plan=plan, **kw)
    assert np.array_equal(np.asarray(ref), np.asarray(got))
    assert "realized_skip_ratio" in aux          # fused-path aux


def test_collect_flags_force_host_reference(setup):
    """The debug collectors keep the host loop; default goes fused."""
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(5), labels=jnp.array([0, 1]),
              n_steps=4, cfg_scale=1.5)
    _, aux_dbg = ddim.ddim_sample(params, cfg, sched, lazy_mode="masked",
                                  collect_scores=True, **kw)
    assert len(aux_dbg["scores"]) == 4
    assert isinstance(aux_dbg["scores"][0]["attn"], np.ndarray)
    _, aux_fused = ddim.ddim_sample(params, cfg, sched, lazy_mode="masked",
                                    **kw)
    assert "scores" not in aux_fused and "policy_state" in aux_fused


# ---------------------------------------------------------------------------
# single-compile contract
# ---------------------------------------------------------------------------


def test_single_compile_across_calls_and_schedules(setup):
    """One trace-cache entry for the whole trajectory — repeated calls AND
    different schedules of the same shape reuse the compiled executable
    (plan rows are traced inputs, not static args)."""
    cfg, params, sched = setup
    pol = cache_lib.get_policy("stride", stride=2)
    trajectory.build_sampler.cache_clear()
    fn = trajectory.build_sampler(cfg, pol, T, 1.5)
    state0 = pol.init_traced_state(n_steps=T, n_layers=L, n_modules=M)
    key, labels = jax.random.PRNGKey(0), jnp.array([0, 1])
    ts, ts_prev = trajectory.timestep_arrays(sched.n_train_steps, T)
    z0 = jax.random.normal(key, (2, cfg.dit_input_size, cfg.dit_input_size,
                                 cfg.dit_in_channels), jnp.float32)

    plan_a = pol.device_plan(T, L, M)
    z_a, _ = fn(params, sched, ts, ts_prev, z0, key, labels, plan_a, state0)
    assert fn._cache_size() == 1
    # a DIFFERENT schedule (same shape): no retrace, different output
    plan_b = jnp.zeros_like(plan_a)
    z_b, _ = fn(params, sched, ts, ts_prev, z0, key, labels, plan_b, state0)
    assert fn._cache_size() == 1, "changing the schedule retraced the scan"
    assert not np.array_equal(np.asarray(z_a), np.asarray(z_b))

    # a second full sample through the public wrapper: zero new backend
    # compilations (the jax.monitoring probe the benchmark also uses)
    from benchmarks.bench_trajectory import compile_counter
    with compile_counter() as c:
        trajectory.sample_trajectory(params, cfg, sched, key=key,
                                     labels=labels, n_steps=T,
                                     cfg_scale=1.5, policy=pol)
    assert c["n"] == 0, f"warm fused sample compiled {c['n']} more times"
    assert fn._cache_size() == 1


def test_sampler_cache_survives_fresh_policy_instances(setup):
    """resolve() builds a NEW policy object per ddim_sample call for
    legacy/string args — the sampler cache must key on the policy's
    trace shape (class, exec_mode, threshold), not its identity, or
    every legacy-path call recompiles the whole trajectory."""
    cfg, params, sched = setup
    from benchmarks.bench_trajectory import compile_counter
    trajectory.build_sampler.cache_clear()
    # two equivalent instances share one compiled sampler
    a = cache_lib.get_policy("stride", stride=2)
    b = cache_lib.get_policy("stride", stride=2)
    assert trajectory.build_sampler(cfg, a, T, 1.5) \
        is trajectory.build_sampler(cfg, b, T, 1.5)
    # the legacy lazy_mode surface: a warm second call compiles nothing
    # even though each call resolves a fresh LazyGatePolicy
    kw = dict(key=jax.random.PRNGKey(1), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5)
    ddim.ddim_sample(params, cfg, sched, lazy_mode="masked", **kw)
    with compile_counter() as c:
        ddim.ddim_sample(params, cfg, sched, lazy_mode="masked", **kw)
    assert c["n"] == 0, \
        f"legacy-path resample recompiled {c['n']} times (cache miss)"


def test_host_reference_recompiles_per_call_fused_does_not(setup):
    """The motivation check: the host loop's per-step jit closes over the
    call's policy/config, so EVERY ddim_sample_reference call retraces
    and recompiles; the fused executor compiles once per (config, policy,
    horizon, guidance) and serves every later call from cache."""
    cfg, params, sched = setup
    pol = make_policy("static_router")
    kw = dict(key=jax.random.PRNGKey(0), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5)
    from benchmarks.bench_trajectory import compile_counter
    ddim.ddim_sample_reference(params, cfg, sched, policy=pol, **kw)  # warm
    with compile_counter() as host_warm:
        ddim.ddim_sample_reference(params, cfg, sched, policy=pol, **kw)
    trajectory.build_sampler.cache_clear()
    trajectory.sample_trajectory(params, cfg, sched, policy=pol, **kw)
    with compile_counter() as fused_warm:
        trajectory.sample_trajectory(params, cfg, sched, policy=pol, **kw)
    assert host_warm["n"] >= 1, "expected the host loop's per-call retrace"
    assert fused_warm["n"] == 0, \
        f"warm fused sample compiled {fused_warm['n']} times"


# ---------------------------------------------------------------------------
# traceable policy state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_traced_state_is_a_device_pytree(name):
    pol = make_policy(name)
    st = pol.init_traced_state(n_steps=T, n_layers=L, n_modules=M)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    assert leaves, f"{name}: traced state has no leaves"
    for leaf in leaves:
        assert isinstance(leaf, jax.Array), \
            f"{name}: non-device leaf {type(leaf).__name__} in traced state"
    # round-trip: flatten/unflatten preserves every leaf exactly
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), st, back)
    assert int(st["step"]) == 0


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_traced_state_rides_a_scan_carry(name):
    """update_traced_state must be a pure pytree transform: carry the state
    through a jitted lax.scan over the policy's own plan rows."""
    pol = make_policy(name)
    st = pol.init_traced_state(n_steps=T, n_layers=L, n_modules=M)
    plan = pol.device_plan(T, L, M)
    if plan is None:
        plan = jnp.zeros((T, L, M), bool)

    @jax.jit
    def roll(state, plan):
        def body(s, row):
            return pol.update_traced_state(s, plan_row=row), None
        return jax.lax.scan(body, state, plan)[0]

    out = roll(st, plan)
    assert int(out["step"]) == T
    assert jax.tree_util.tree_structure(out) \
        == jax.tree_util.tree_structure(st)


def test_smoothcache_threshold_state_through_scan():
    """The smoothcache-specific carry: threshold scalar survives the scan
    unchanged; run_len tracks realized consecutive reuses of its rows."""
    pol = make_policy("smoothcache")
    st = pol.init_traced_state(n_steps=T, n_layers=L, n_modules=M)
    assert float(st["threshold"]) == float(np.float32(pol.error_threshold))
    assert st["run_len"].shape == (L, M)
    plan = pol.device_plan(T, L, M)

    @jax.jit
    def roll(state, plan):
        def body(s, row):
            return pol.update_traced_state(s, plan_row=row), s["run_len"]
        return jax.lax.scan(body, state, plan)

    out, runs = roll(st, plan)
    assert float(out["threshold"]) == float(np.float32(pol.error_threshold))
    # replay the run-length recurrence on host and compare
    expect = np.zeros((L, M), int)
    skip = np.asarray(plan)
    for t in range(T):
        expect = np.where(skip[t], expect + 1, 0)
    np.testing.assert_array_equal(np.asarray(out["run_len"]), expect)
    assert int(out["step"]) == T
    # the guard the compiled plan enforces: no run exceeds max_skip_run
    assert int(np.asarray(runs).max()) <= pol.max_skip_run


# ---------------------------------------------------------------------------
# eta > 0 stochastic DDIM (reserved per-step keys)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["none", "stride"])
def test_eta_fused_matches_host_reference(setup, name):
    """Stochastic DDIM shares the key bookkeeping inside trajectory_step,
    so fused and host executors replay the identical noise stream."""
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(7), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5, eta=0.7, policy=make_policy(name))
    ref, _ = ddim.ddim_sample_reference(params, cfg, sched, **kw)
    fused, _ = trajectory.sample_trajectory(params, cfg, sched, **kw)
    assert np.array_equal(np.asarray(ref), np.asarray(fused))
    assert np.all(np.isfinite(np.asarray(fused)))


def test_eta_fixed_seed_reproducible_and_actually_stochastic(setup):
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(9), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5, policy=make_policy("none"))
    a, _ = ddim.ddim_sample(params, cfg, sched, eta=0.5, **kw)
    b, _ = ddim.ddim_sample(params, cfg, sched, eta=0.5, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg="fixed seed is not reproducible")
    det, _ = ddim.ddim_sample(params, cfg, sched, eta=0.0, **kw)
    assert not np.array_equal(np.asarray(a), np.asarray(det)), \
        "eta=0.5 produced the deterministic trajectory (noise ignored)"
    c, _ = ddim.ddim_sample(
        params, cfg, sched, eta=0.5,
        **{**kw, "key": jax.random.PRNGKey(10)})
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_eta_noise_is_per_example(setup):
    """Example i's noise depends only on (key, i, step): shuffling other
    batch rows must not change row i's sample — the invariance that makes
    the stochastic sampler mesh-shardable."""
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(11), n_steps=T, cfg_scale=1.0,
              eta=0.5, policy=make_policy("none"))
    x2, _ = ddim.ddim_sample(params, cfg, sched,
                             labels=jnp.array([3, 3]), **kw)
    # same label in row 0, batch size unchanged, row 1 differs -> row 0's
    # initial latent and noise keys are identical by construction
    x2b, _ = ddim.ddim_sample(params, cfg, sched,
                              labels=jnp.array([3, 5]), **kw)
    np.testing.assert_array_equal(np.asarray(x2[0]), np.asarray(x2b[0]))


def test_eta_final_step_adds_no_noise():
    """sigma(t_prev < 0) = 0: the emitted sample is never perturbed."""
    sched = ddim.linear_schedule(100)
    z = jnp.ones((2, 4, 4, 3))
    eps = jnp.full_like(z, 0.3)
    t = jnp.full((2,), 7)
    t_prev = jnp.full((2,), -1)
    base = ddim.ddim_step(sched, z, eps, t, t_prev)
    noisy = ddim.ddim_step(sched, z, eps, t, t_prev, eta=1.0,
                           noise=jnp.full_like(z, 100.0))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(noisy))


def test_eta_zero_default_signature_unchanged(setup):
    """eta defaults to 0 everywhere: the pre-eta call signature still
    routes through the fused path and matches the host reference."""
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(3), labels=jnp.array([0, 1]), n_steps=T)
    ref, _ = ddim.ddim_sample_reference(params, cfg, sched, **kw)
    got, aux = ddim.ddim_sample(params, cfg, sched, **kw)
    assert np.array_equal(np.asarray(ref), np.asarray(got))
    assert "realized_skip_ratio" in aux


def test_update_traced_state_carries_scores():
    pol = make_policy("lazy_gate")
    st = pol.init_traced_state(n_steps=T, n_layers=L, n_modules=M)
    assert float(st["threshold"]) == float(np.float32(pol.threshold))
    sc = jnp.full((L, M), 0.7, jnp.float32)
    st2 = pol.update_traced_state(st, scores=sc)
    np.testing.assert_array_equal(np.asarray(st2["scores"]), np.asarray(sc))
    assert int(st2["step"]) == 1
    # the original state object is untouched (pure transform)
    assert int(st["step"]) == 0
