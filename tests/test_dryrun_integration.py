"""Integration: the dry-run path (sharded lower+compile) on 8 fake host
devices in a subprocess (device count is locked at first jax init, so this
cannot run in the main test process)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs.base import LazyConfig, INPUT_SHAPES, InputShape
from repro.configs.registry import get_config
from repro.dist import ctx, sharding as sh, hlo as hlo_lib
from repro.launch import dryrun as dr

mesh = jax.make_mesh((2, 4), ("data", "model"))
results = {}
for arch in ("llama3_2_1b", "mixtral_8x22b", "zamba2_7b"):
    cfg = get_config(arch).reduced(d_model=128, n_heads=4, n_kv_heads=4,
                                   head_dim=32, vocab_size=256)
    cfg = cfg.replace(lazy=LazyConfig(enabled=False))
    for shape in (InputShape("t", 64, 8, "train"), InputShape("d", 64, 8, "decode")):
        with mesh, ctx.activation_sharding(mesh):
            fn, args = dr.build_step(cfg, shape, mesh, window_override=None)
            compiled = fn.lower(*args).compile()
        mod = hlo_lib.analyze_module(compiled.as_text())
        results[f"{arch}/{shape.kind}"] = {
            "flops": mod["flops"],
            "n_coll": sum(v["count"] for v in mod["collective"].values()),
        }
print("RESULT " + json.dumps(results))
"""


@pytest.mark.slow
def test_dryrun_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    assert len(results) == 6
    for k, v in results.items():
        assert v["flops"] > 0, k
        if "train" in k:
            # sharded training must communicate
            assert v["n_coll"] > 0, k
