"""Unit tests for core/lazy.py static-plan logic (DESIGN.md §3 'plan' mode):
target-ratio budgeting, the step-0 rule, and the forced-refresh rotation."""
import numpy as np
import pytest

from repro.core import lazy as lazy_lib


T, L, M = 20, 4, 2
PER = L * M


def scores(seed=0):
    return np.random.default_rng(seed).random((T, L, M))


# ---------------------------------------------------------------------------
# plan_with_target_ratio
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", [0.1, 0.25, 0.3, 0.5])
def test_target_ratio_hit_within_one_module(target):
    """Per-step skip counts land on the budget exactly; the global ratio is
    within one module-call-per-step of the target."""
    plan = lazy_lib.plan_with_target_ratio(scores(), target)
    budget = int(round(target * T * PER / (T - 1)))
    for t in range(1, T):
        assert plan.skip[t].sum() == min(budget, PER), t
    assert abs(plan.lazy_ratio - target) <= 1.0 / PER + 1e-9


def test_step_zero_never_skips():
    for target in (0.2, 0.5, 0.9):
        plan = lazy_lib.plan_with_target_ratio(scores(1), target)
        assert not plan.skip[0].any()
        plan_g = lazy_lib.plan_with_target_ratio(scores(1), target,
                                                 per_step=False)
        assert not plan_g.skip[0].any()


def test_refresh_rotation_forces_module_runs():
    """Module j may not skip on step t when j % REFRESH == t % REFRESH: no
    module's cache can go stale for the whole trajectory (the static-plan
    analogue of the paper's dynamic gates re-running modules)."""
    REFRESH = 4
    # adversarial scores: module 0 maximally attractive to skip everywhere
    s = scores(2)
    s[:, 0, 0] = 1.0
    plan = lazy_lib.plan_with_target_ratio(s, 0.5)
    flat = plan.skip.reshape(T, PER)
    for t in range(1, T):
        forced = np.arange(PER) % REFRESH == t % REFRESH
        assert not flat[t][forced].any(), t
    # module 0 must therefore run at least every REFRESH steps
    runs = ~flat[:, 0]
    assert runs.reshape(-1)[::1].any()
    longest_gap = 0
    gap = 0
    for r in runs:
        gap = 0 if r else gap + 1
        longest_gap = max(longest_gap, gap)
    assert longest_gap < REFRESH


def test_high_scores_preferred():
    """The budget goes to the highest-scoring (laziest) module calls."""
    s = np.full((T, L, M), 0.1)
    s[:, 1, 1] = 0.9
    plan = lazy_lib.plan_with_target_ratio(s, 1.0 / PER)
    # one skip per step; it must be the high-score module except on its
    # forced-refresh steps
    idx = 1 * M + 1
    for t in range(1, T):
        if idx % 4 == t % 4:
            continue
        assert plan.skip[t, 1, 1], t


def test_zero_and_degenerate_targets():
    assert lazy_lib.plan_with_target_ratio(scores(), 0.0).lazy_ratio == 0.0
    one_step = np.random.default_rng(0).random((1, L, M))
    assert not lazy_lib.plan_with_target_ratio(one_step, 0.9).skip.any()


def test_global_mode_ratio():
    plan = lazy_lib.plan_with_target_ratio(scores(3), 0.4, per_step=False)
    assert not plan.skip[0].any()
    assert abs(plan.lazy_ratio - 0.4) < 0.05


def test_global_mode_extreme_target_keeps_step0():
    """Regression: targets above (T-1)/T used to sweep the step-0 -inf
    sentinels into the skip set; duplicate scores used to over-skip."""
    plan = lazy_lib.plan_with_target_ratio(scores(5), 0.97, per_step=False)
    assert not plan.skip[0].any()
    assert plan.skip[1:].all()            # budget capped at the feasible set
    dup = np.full((T, L, M), 0.5)
    plan_d = lazy_lib.plan_with_target_ratio(dup, 0.25, per_step=False)
    assert not plan_d.skip[0].any()
    assert plan_d.skip.sum() == int(round(0.25 * T * PER))


# ---------------------------------------------------------------------------
# uniform_plan
# ---------------------------------------------------------------------------


def test_uniform_plan_seeded_and_step0():
    a = lazy_lib.uniform_plan(T, L, M, 0.5, seed=7)
    b = lazy_lib.uniform_plan(T, L, M, 0.5, seed=7)
    c = lazy_lib.uniform_plan(T, L, M, 0.5, seed=8)
    np.testing.assert_array_equal(a.skip, b.skip)
    assert not np.array_equal(a.skip, c.skip)
    assert not a.skip[0].any()
    assert a.skip.shape == (T, L, M)
    # ratio statistically near the request (step 0 forced diligent)
    expected = 0.5 * (T - 1) / T
    assert abs(a.lazy_ratio - expected) < 0.15


def test_plan_from_scores_threshold_and_step0():
    s = scores(4)
    plan = lazy_lib.plan_from_scores(s, threshold=0.6)
    assert not plan.skip[0].any()
    np.testing.assert_array_equal(plan.skip[1:], s[1:] > 0.6)
