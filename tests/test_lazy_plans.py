"""Unit tests for core/lazy.py static-plan logic (DESIGN.md §3 'plan' mode):
target-ratio budgeting, the step-0 rule, and the forced-refresh rotation."""
import numpy as np
import pytest

from repro.core import lazy as lazy_lib


T, L, M = 20, 4, 2
PER = L * M


def scores(seed=0):
    return np.random.default_rng(seed).random((T, L, M))


# ---------------------------------------------------------------------------
# plan_with_target_ratio
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", [0.1, 0.25, 0.3, 0.5])
def test_target_ratio_hit_within_one_module(target):
    """Per-step skip counts land on the budget exactly over the skippable
    steps (1..T-2); the global ratio is within one module-call-per-step of
    the target."""
    plan = lazy_lib.plan_with_target_ratio(scores(), target)
    budget = int(round(target * T * PER / (T - 2)))
    for t in range(1, T - 1):
        assert plan.skip[t].sum() == min(budget, PER), t
    assert abs(plan.lazy_ratio - target) <= 1.0 / PER + 1e-9


def test_first_and_last_steps_never_skip():
    """The paper's §3.2 observation: trajectory endpoints are least similar
    — the first and last sampling steps must always run fresh, in every
    budgeting mode."""
    for target in (0.2, 0.5, 0.9):
        for kw in ({}, {"per_step": False}, {"per_layer": True}):
            plan = lazy_lib.plan_with_target_ratio(scores(1), target, **kw)
            assert not plan.skip[0].any(), kw
            assert not plan.skip[-1].any(), kw


def test_refresh_rotation_forces_module_runs():
    """Module j may not skip on step t when j % REFRESH == t % REFRESH: no
    module's cache can go stale for the whole trajectory (the static-plan
    analogue of the paper's dynamic gates re-running modules)."""
    REFRESH = 4
    # adversarial scores: module 0 maximally attractive to skip everywhere
    s = scores(2)
    s[:, 0, 0] = 1.0
    plan = lazy_lib.plan_with_target_ratio(s, 0.5)
    flat = plan.skip.reshape(T, PER)
    for t in range(1, T - 1):
        forced = np.arange(PER) % REFRESH == t % REFRESH
        assert not flat[t][forced].any(), t
    # module 0 must therefore run at least every REFRESH steps
    runs = ~flat[:, 0]
    assert runs.reshape(-1)[::1].any()
    longest_gap = 0
    gap = 0
    for r in runs:
        gap = 0 if r else gap + 1
        longest_gap = max(longest_gap, gap)
    assert longest_gap < REFRESH


def test_high_scores_preferred():
    """The budget goes to the highest-scoring (laziest) module calls."""
    s = np.full((T, L, M), 0.1)
    s[:, 1, 1] = 0.9
    plan = lazy_lib.plan_with_target_ratio(s, 1.0 / PER)
    # one skip per step; it must be the high-score module except on its
    # forced-refresh steps
    idx = 1 * M + 1
    for t in range(1, T - 1):
        if idx % 4 == t % 4:
            continue
        assert plan.skip[t, 1, 1], t


def test_zero_and_degenerate_targets():
    assert lazy_lib.plan_with_target_ratio(scores(), 0.0).lazy_ratio == 0.0
    one_step = np.random.default_rng(0).random((1, L, M))
    assert not lazy_lib.plan_with_target_ratio(one_step, 0.9).skip.any()
    # T == 2: both steps are trajectory endpoints -> nothing may skip
    two_step = np.random.default_rng(0).random((2, L, M))
    assert not lazy_lib.plan_with_target_ratio(two_step, 0.9).skip.any()


def test_global_mode_ratio():
    plan = lazy_lib.plan_with_target_ratio(scores(3), 0.4, per_step=False)
    assert not plan.skip[0].any()
    assert not plan.skip[-1].any()
    assert abs(plan.lazy_ratio - 0.4) < 0.05


def test_global_mode_extreme_target_keeps_endpoints():
    """Regression: targets above (T-2)/T used to sweep the endpoint -inf
    sentinels into the skip set; duplicate scores used to over-skip."""
    plan = lazy_lib.plan_with_target_ratio(scores(5), 0.97, per_step=False)
    assert not plan.skip[0].any()
    assert not plan.skip[-1].any()
    assert plan.skip[1:-1].all()          # budget capped at the feasible set
    dup = np.full((T, L, M), 0.5)
    plan_d = lazy_lib.plan_with_target_ratio(dup, 0.25, per_step=False)
    assert not plan_d.skip[0].any()
    assert plan_d.skip.sum() == int(round(0.25 * T * PER))


# ---------------------------------------------------------------------------
# per-layer mode (the Learning-to-Cache-style router quota)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", [0.25, 0.5])
def test_per_layer_mode_uniform_quota(target):
    """Within a step every layer spends the same skip quota (up to its
    rotating forced-refresh hole), so no layer can hog the budget."""
    plan = lazy_lib.plan_with_target_ratio(scores(6), target, per_layer=True)
    for t in range(1, T - 1):
        counts = plan.skip[t].reshape(L, -1).sum(axis=-1)
        # the refresh hole may block at most one module of one layer
        assert counts.max() - counts.min() <= 1, t
    assert not plan.skip[0].any() and not plan.skip[-1].any()
    assert abs(plan.lazy_ratio - target) <= 1.0 / M + 1e-9


def test_per_layer_mode_small_targets_not_rounded_away():
    """Regression: an integer per-step quota quantizes ratios to ~1/M and
    rounded small targets down to an EMPTY plan — the Bresenham quota
    spread must hit them in aggregate."""
    for target in (0.1, 0.2):
        plan = lazy_lib.plan_with_target_ratio(scores(9), target,
                                               per_layer=True)
        assert plan.lazy_ratio > 0, target
        assert abs(plan.lazy_ratio - target) <= 0.5 / M + 1e-9, target


def test_per_layer_mode_respects_refresh_rotation():
    s = np.full((T, L, M), 0.9)
    plan = lazy_lib.plan_with_target_ratio(s, 1.0, per_layer=True)
    flat = plan.skip.reshape(T, PER)
    for t in range(1, T - 1):
        forced = np.arange(PER) % 4 == t % 4
        assert not flat[t][forced].any(), t


def test_per_layer_mode_prefers_high_scores_within_layer():
    s = np.full((T, L, M), 0.1)
    s[:, :, 1] = 0.9                       # module 1 of every layer laziest
    plan = lazy_lib.plan_with_target_ratio(s, 1.0 / M, per_layer=True)
    for t in range(1, T - 1):
        for l in range(L):
            gidx = l * M + 1
            if gidx % 4 == t % 4:          # its forced-refresh step
                continue
            assert plan.skip[t, l, 1], (t, l)


# ---------------------------------------------------------------------------
# uniform_plan
# ---------------------------------------------------------------------------


def test_uniform_plan_seeded_and_step0():
    a = lazy_lib.uniform_plan(T, L, M, 0.5, seed=7)
    b = lazy_lib.uniform_plan(T, L, M, 0.5, seed=7)
    c = lazy_lib.uniform_plan(T, L, M, 0.5, seed=8)
    np.testing.assert_array_equal(a.skip, b.skip)
    assert not np.array_equal(a.skip, c.skip)
    assert not a.skip[0].any()
    assert a.skip.shape == (T, L, M)
    # ratio statistically near the request (step 0 forced diligent)
    expected = 0.5 * (T - 1) / T
    assert abs(a.lazy_ratio - expected) < 0.15


def test_plan_from_scores_threshold_and_step0():
    s = scores(4)
    plan = lazy_lib.plan_from_scores(s, threshold=0.6)
    assert not plan.skip[0].any()
    np.testing.assert_array_equal(plan.skip[1:], s[1:] > 0.6)
