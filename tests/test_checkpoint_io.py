"""checkpoint/io.py: save/restore roundtrip on a reduced llama3_2_1b tree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.configs.registry import get_config
from repro.models import transformer as tf


def _params():
    cfg = get_config("llama3_2_1b").reduced()
    return cfg, tf.init_lm(jax.random.PRNGKey(0), cfg)


def test_roundtrip_preserves_structure_dtypes_values(tmp_path):
    cfg, params = _params()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params, extra={"step": 7})

    # restore into a template of zeros: every value must come from disk
    template = jax.tree.map(jnp.zeros_like, params)
    restored = restore_checkpoint(path, template)

    assert (jax.tree_util.tree_structure(restored)
            == jax.tree_util.tree_structure(params))
    orig_leaves = jax.tree_util.tree_leaves(params)
    rest_leaves = jax.tree_util.tree_leaves(restored)
    assert len(orig_leaves) == len(rest_leaves) > 0
    for a, b in zip(orig_leaves, rest_leaves):
        assert np.asarray(b).dtype == np.asarray(a).dtype
        assert np.asarray(b).shape == np.asarray(a).shape
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a))


def test_roundtrip_preserves_extra_entries(tmp_path):
    _, params = _params()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params, extra={"step": 7, "lr": 1e-3})
    data = np.load(path)
    assert int(data["__extra__/step"]) == 7
    assert float(data["__extra__/lr"]) == pytest.approx(1e-3)


def test_restore_rejects_shape_mismatch(tmp_path):
    cfg, params = _params()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params)
    bad_cfg = cfg.replace(d_ff=cfg.d_ff // 2)
    bad_template = tf.init_lm(jax.random.PRNGKey(1), bad_cfg)
    with pytest.raises(AssertionError):
        restore_checkpoint(path, bad_template)


def test_restore_applies_template_dtype(tmp_path):
    """Restore casts to the template leaf dtype (shard-aware restore keeps
    the caller's dtype policy, e.g. bf16 params from an f32 save)."""
    _, params = _params()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params)
    template = jax.tree.map(
        lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a,
        params)
    restored = restore_checkpoint(path, template)
    for t, r in zip(jax.tree_util.tree_leaves(template),
                    jax.tree_util.tree_leaves(restored)):
        assert np.asarray(r).dtype == np.asarray(t).dtype
