"""repro.cache policy subsystem: registry + legacy bridge, policy
schedules, calibration-artifact round-trips, executor parity through the
policy layer, and the slot-cache helpers under policy-state payloads."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.models import transformer as tf
from repro.serving.engine import Engine


T, L, M = 10, 3, 2


def synth_artifact(seed=0, n_steps=T, n_layers=L):
    rng = np.random.default_rng(seed)
    rel = rng.uniform(0.01, 1.0, (n_steps, n_layers, M))
    rel[0] = np.inf                       # step 0: no previous output
    return calibrate_lib.CalibrationArtifact(
        kind="lm", arch="synthetic", n_steps=n_steps, n_layers=n_layers,
        modules=("attn", "ffn_or_block"), rel_err=rel)


# ---------------------------------------------------------------------------
# registry + legacy bridge
# ---------------------------------------------------------------------------


def test_registry_contains_required_policies():
    names = cache_lib.available_policies()
    for required in ("none", "stride", "lazy_gate", "smoothcache",
                     "static_router", "plan"):
        assert required in names
    with pytest.raises(ValueError, match="unknown cache policy"):
        cache_lib.get_policy("does_not_exist")


def test_legacy_bridge_maps_flags_onto_policies():
    assert cache_lib.from_legacy("off").exec_mode == "off"
    gate = cache_lib.from_legacy("masked", threshold=0.7)
    assert gate.exec_mode == "masked" and gate.threshold == 0.7
    assert cache_lib.from_legacy("soft").exec_mode == "soft"
    plan = lazy_lib.uniform_plan(4, L, M, 0.5, seed=0)
    pol = cache_lib.from_legacy("plan", plan=plan)
    np.testing.assert_array_equal(pol.compile_plan(4, L, M).skip, plan.skip)
    with pytest.raises(ValueError, match="requires a plan"):
        cache_lib.from_legacy("plan")
    with pytest.raises(ValueError, match="must be one of"):
        cache_lib.from_legacy("bogus")
    # resolve(): explicit policy wins, names resolve, junk rejected
    assert cache_lib.resolve("stride").name == "stride"
    assert cache_lib.resolve(gate) is gate
    with pytest.raises(TypeError):
        cache_lib.resolve(42)
    # the name form must decide like the legacy alias: the executor's
    # threshold reaches a string-named lazy_gate, and "plan" takes the plan
    assert cache_lib.resolve("lazy_gate", threshold=0.8).threshold == 0.8
    np.testing.assert_array_equal(
        cache_lib.resolve("plan", plan=plan).compile_plan(4, L, M).skip,
        plan.skip)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_stride_schedule_and_endpoints():
    pol = cache_lib.get_policy("stride", stride=2)
    plan = pol.compile_plan(T, L, M)
    assert not plan.skip[0].any() and not plan.skip[-1].any()
    for t in range(1, T - 1):
        assert plan.skip[t].all() == (t % 2 != 0)
        assert pol.decide(t, 0, 0, state={"plan": plan}) == (t % 2 != 0)
    with pytest.raises(ValueError, match="stride"):
        cache_lib.get_policy("stride", stride=1)


def test_smoothcache_thresholds_calibrated_errors():
    art = synth_artifact()
    thr = art.quantile_threshold(0.5)
    pol = cache_lib.get_policy("smoothcache", calibration=art,
                               error_threshold=thr, max_skip_run=100)
    plan = pol.compile_plan(T, L, M)
    assert not plan.skip[0].any() and not plan.skip[-1].any()
    expect = (art.rel_err <= thr) & np.isfinite(art.rel_err)
    np.testing.assert_array_equal(plan.skip[1:-1], expect[1:-1])
    assert plan.lazy_ratio > 0


def test_smoothcache_max_skip_run_bounds_staleness():
    rel = np.full((T, L, M), 0.01)        # everything looks skippable
    rel[0] = np.inf
    art = calibrate_lib.CalibrationArtifact(
        kind="lm", arch="synthetic", n_steps=T, n_layers=L,
        modules=("attn", "ffn_or_block"), rel_err=rel)
    pol = cache_lib.get_policy("smoothcache", calibration=art,
                               error_threshold=0.5, max_skip_run=2)
    skip = pol.compile_plan(T, L, M).skip
    runs = 0
    for t in range(T):
        runs = runs + 1 if skip[t, 0, 0] else 0
        assert runs <= 2, t


def test_smoothcache_resamples_calibration_steps():
    art = synth_artifact(n_steps=6)
    pol = cache_lib.get_policy("smoothcache", calibration=art,
                               error_threshold=art.quantile_threshold(0.6))
    assert pol.compile_plan(12, L, M).skip.shape == (12, L, M)
    with pytest.raises(ValueError, match="calibration profile"):
        pol.compile_plan(12, L + 1, M)


def test_static_router_uniform_per_layer_quota():
    art = synth_artifact(1)
    pol = cache_lib.get_policy("static_router", ratio=0.5, calibration=art)
    plan = pol.compile_plan(T, L, M)
    for t in range(1, T - 1):
        counts = plan.skip[t].sum(axis=-1)
        # every layer spends the same per-step quota, up to the rotating
        # forced-refresh hole
        assert counts.max() - counts.min() <= 1, t
    assert plan.lazy_ratio > 0
    assert abs(plan.lazy_ratio - 0.5) <= 1.0 / M + 1e-9
    # seeded (calibration-free) variant is deterministic
    a = cache_lib.get_policy("static_router", ratio=0.5, seed=3)
    b = cache_lib.get_policy("static_router", ratio=0.5, seed=3)
    np.testing.assert_array_equal(a.compile_plan(T, L, M).skip,
                                  b.compile_plan(T, L, M).skip)


def test_decide_matches_compiled_plan():
    """decide() is the host-side reference of the compiled schedule."""
    art = synth_artifact(2)
    for pol in (cache_lib.get_policy("stride", stride=3),
                cache_lib.get_policy("smoothcache", calibration=art,
                                     error_threshold=0.4),
                cache_lib.get_policy("static_router", ratio=0.4,
                                     calibration=art)):
        state = pol.init_state(n_steps=T, n_layers=L, n_modules=M)
        plan = state["plan"]
        for t in range(T):
            for l in range(L):
                for m in range(M):
                    assert pol.decide(t, l, m, state=state) \
                        == bool(plan.skip[t, l, m]), (pol.name, t, l, m)


# ---------------------------------------------------------------------------
# calibration artifact
# ---------------------------------------------------------------------------


def test_calibration_artifact_json_roundtrip(tmp_path):
    art = synth_artifact()
    p = art.save(str(tmp_path / "calib.json"))
    back = calibrate_lib.CalibrationArtifact.load(p)
    assert back.kind == art.kind and back.modules == art.modules
    # +inf rows survive the null encoding
    assert np.isinf(back.rel_err[0]).all()
    np.testing.assert_allclose(back.rel_err[1:], art.rel_err[1:])
    with pytest.raises(ValueError, match="schema"):
        calibrate_lib.CalibrationArtifact.from_json({"schema": "nope"})


def test_calibrate_lm_profiles_every_gated_module():
    cfg = ModelConfig(n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
                      head_dim=8, d_ff=32, vocab_size=31, dtype="float32",
                      lazy=LazyConfig(enabled=False))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(3, dtype=np.int32)[None] % cfg.vocab_size
    art = calibrate_lib.calibrate_lm(params, cfg, prompt, 5)
    assert art.rel_err.shape == (5, cfg.n_layers, 2)
    assert np.isinf(art.rel_err[0]).all()          # step 0 unskippable
    assert np.isfinite(art.rel_err[1:]).all()      # every module profiled
    assert (art.rel_err[1:] >= 0).all()


# ---------------------------------------------------------------------------
# executor parity through the policy layer
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _lm_fixture():
    cfg = ModelConfig(n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
                      head_dim=8, d_ff=32, vocab_size=31, dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = tf.init_lm(jax.random.PRNGKey(1), cfg)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 3)).astype(np.int32)
    return cfg, params, prompt


def test_engine_none_policy_matches_off_exactly():
    cfg, params, prompt = _lm_fixture()
    off = Engine(cfg, params, max_len=24, lazy_mode="off").generate(prompt, 5)
    none = Engine(cfg, params, max_len=24, policy="none").generate(prompt, 5)
    np.testing.assert_array_equal(off.tokens, none.tokens)
    assert none.realized_lazy_ratio == 0.0


def test_engine_zero_ratio_lazy_gate_matches_off():
    """The acceptance contract: the lazy_gate path at skip ratio 0 is
    greedy-token exact against the baseline."""
    cfg, params, prompt = _lm_fixture()
    off = Engine(cfg, params, max_len=24, lazy_mode="off").generate(prompt, 5)
    pol = cache_lib.get_policy("lazy_gate", threshold=1.1)  # sigmoid < 1
    res = Engine(cfg, params, max_len=24, policy=pol).generate(prompt, 5)
    np.testing.assert_array_equal(off.tokens, res.tokens)
    assert res.realized_lazy_ratio == 0.0


def test_engine_static_policy_reports_plan_ratio():
    cfg, params, prompt = _lm_fixture()
    res = Engine(cfg, params, max_len=24,
                 policy=cache_lib.get_policy("stride", stride=2)
                 ).generate(prompt, 6)
    assert res.realized_lazy_ratio > 0.2
    assert res.tokens.shape == (2, 3 + 6)


def test_serving_rejects_soft_policy():
    cfg, params, _ = _lm_fixture()
    with pytest.raises(ValueError, match="soft"):
        Engine(cfg, params, policy=cache_lib.get_policy("lazy_gate",
                                                        soft=True))


def test_engine_plan_horizon_follows_odd_length_schedule():
    """Regression (engine horizon): a schedule whose length is NOT a
    divisor of the default 16-step horizon must be served verbatim and
    cycled at ITS OWN length — the old fixed horizon resampled a 7-step
    smoothcache calibration onto 16 rows (truncating/misaligning it)."""
    from repro.serving.engine import POLICY_PLAN_STEPS, ContinuousBatchingEngine

    cfg, params, _ = _lm_fixture()
    T_odd = 7
    art = synth_artifact(seed=3, n_steps=T_odd, n_layers=cfg.n_layers)
    pol = cache_lib.get_policy("smoothcache", calibration=art,
                               error_threshold=art.quantile_threshold(0.6))
    assert pol.plan_horizon(POLICY_PLAN_STEPS) == T_odd

    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   policy=pol)
    assert eng.plan_horizon == T_odd
    # the engine's device plan (the in-jit row source) serves the full
    # schedule, unresampled
    served = np.asarray(eng._device_plan)
    expect = pol.compile_plan(T_odd, cfg.n_layers, 2).skip
    np.testing.assert_array_equal(served, expect)
    # rows cycle with period 7, not 16 — both through the host plan_row
    # API and the engine's traced gather (plan[t % horizon])
    state = pol.init_state(n_steps=T_odd, n_layers=cfg.n_layers, n_modules=2)
    for t in range(3 * T_odd):
        np.testing.assert_array_equal(pol.plan_row(t, state),
                                      expect[t % T_odd])
        np.testing.assert_array_equal(served[t % eng.plan_horizon],
                                      expect[t % T_odd])

    # stride derives a stride-aligned horizon so cycled rows keep the
    # t % stride refresh rule congruent across cycle boundaries
    stride = cache_lib.get_policy("stride", stride=3)
    h = stride.plan_horizon(POLICY_PLAN_STEPS)
    assert h % 3 == 0 and h >= POLICY_PLAN_STEPS
    # an explicit plan keeps its own (odd) length
    plan5 = lazy_lib.uniform_plan(5, cfg.n_layers, 2, 0.5, seed=1)
    assert cache_lib.get_policy(
        "plan", plan=plan5.skip).plan_horizon(POLICY_PLAN_STEPS) == 5


# ---------------------------------------------------------------------------
# slot-cache helpers under policy-state payloads (continuous batching)
# ---------------------------------------------------------------------------


def _policy_payload(step: int, score: float):
    """A per-slot cache tree as the serving engine would stack it: lazy
    module outputs PLUS host-policy state riding along as array leaves."""
    return {
        "lazy": {"attn": jnp.full((1, 2, 4), score, jnp.float32),
                 "ffn": jnp.full((1, 2, 4), score + 1.0, jnp.float32)},
        "policy_state": {"step": jnp.full((1,), step, jnp.int32),
                         "scores": jnp.full((1, L, M), score, jnp.float32)},
    }


def test_slot_helpers_roundtrip_policy_state_payloads():
    n_slots = 3
    stacked = lazy_lib.stack_for_slots(_policy_payload(0, 0.0), n_slots)
    for leaf in jax.tree.leaves(stacked):
        assert leaf.shape[0] == n_slots
    # occupant A joins slot 1
    a = _policy_payload(step=5, score=0.25)
    stacked = lazy_lib.slot_cache_scatter(stacked, 1, a)
    got = lazy_lib.slot_cache_gather(stacked, 1)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), got, a)
    # neighbours untouched
    for other in (0, 2):
        neigh = lazy_lib.slot_cache_gather(stacked, other)
        assert float(neigh["policy_state"]["step"][0]) == 0
        assert float(neigh["lazy"]["attn"].max()) == 0.0


def test_slot_reset_then_join_mirrors_scheduler_reuse():
    """Eviction resets the slot; the next occupant's scatter repopulates
    it — at no point may occupant B observe occupant A's module outputs
    or policy state (the cross-request freshness guard)."""
    n_slots = 2
    stacked = lazy_lib.stack_for_slots(_policy_payload(0, 0.0), n_slots)
    a = _policy_payload(step=7, score=0.9)
    stacked = lazy_lib.slot_cache_scatter(stacked, 0, a)

    # A evicted -> reset: everything in slot 0 zeroed, slot 1 untouched
    stacked = lazy_lib.slot_cache_reset(stacked, 0)
    for leaf in jax.tree.leaves(lazy_lib.slot_cache_gather(stacked, 0)):
        assert float(jnp.abs(leaf).max()) == 0.0
    # B joins the reused slot with its own prefilled payload
    b = _policy_payload(step=1, score=0.5)
    stacked = lazy_lib.slot_cache_scatter(stacked, 0, b)
    got = lazy_lib.slot_cache_gather(stacked, 0)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), got, b)
    assert float(got["policy_state"]["step"][0]) == 1   # B's state, not A's


def test_slot_reset_is_idempotent_and_slot_local():
    stacked = lazy_lib.stack_for_slots(_policy_payload(3, 0.7), 3)
    once = lazy_lib.slot_cache_reset(stacked, 2)
    twice = lazy_lib.slot_cache_reset(once, 2)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 once, twice)
    # the other slots keep the original payload
    for i in (0, 1):
        got = lazy_lib.slot_cache_gather(twice, i)
        assert float(got["policy_state"]["step"][0]) == 3
