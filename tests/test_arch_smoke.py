"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each assigned family (2-8 layers, d_model<=256, <=4 experts) runs one
forward/train step and one decode step on CPU with finite outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ASSIGNED_ARCHS, DIT_ARCHS, get_config
from repro.data.synthetic import frontend_stub_embeddings
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.train import optim, trainer


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    if cfg.frontend_stub:
        embeds = jnp.asarray(frontend_stub_embeddings(rng, B, 4, cfg.frontend_dim))
        loss = trainer.lm_loss(params, cfg, tokens, embeds=embeds)
    else:
        opt = optim.adamw_init(params)
        params2, _, aux = trainer.lm_train_step(params, opt, cfg, tokens,
                                                jax.random.PRNGKey(1))
        loss = aux["loss"]
        # one step must change the weights
        before = jax.tree.leaves(params)[0]
        after = jax.tree.leaves(params2)[0]
        assert not np.array_equal(np.asarray(before), np.asarray(after))
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = tf.init_decode_cache(cfg, B, max_len=32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache, _, _ = tf.decode_step(params, cfg, tok, jnp.int32(0), cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    # second step with lazy masked mode
    lazy_cache = tf.init_lazy_decode_cache(cfg, B)
    logits, cache, lazy_cache, scores = tf.decode_step(
        params, cfg, tok, jnp.int32(1), cache, lazy_cache=lazy_cache,
        lazy_mode="masked", lazy_first_step=True)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    logits, _, _, scores = tf.decode_step(
        params, cfg, tok, jnp.int32(2), cache, lazy_cache=lazy_cache,
        lazy_mode="masked")
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert scores and all(np.all((np.asarray(v) >= 0) & (np.asarray(v) <= 1))
                          for v in scores.values())


@pytest.mark.parametrize("arch", DIT_ARCHS)
def test_reduced_dit_forward(arch):
    cfg = get_config(arch).reduced(dit_input_size=8, dit_n_classes=16)
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 8, 8, cfg.dit_in_channels))
    out, _, _ = dit_lib.dit_forward(params, cfg, x,
                                    jnp.array([1.0, 2.0]), jnp.array([0, 1]))
    assert out.shape == (B, 8, 8, 2 * cfg.dit_in_channels)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_exact_assigned_specs():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("zamba2_7b").ssm.state_dim == 64
    assert get_config("mixtral_8x22b").moe.n_experts == 8
    assert get_config("mixtral_8x22b").moe.top_k == 2
    assert get_config("deepseek_v2_lite_16b").mla.kv_lora_rank == 512
    assert get_config("deepseek_v2_lite_16b").moe.top_k == 6
    assert get_config("deepseek_v2_lite_16b").moe.n_shared_experts == 2


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1
