"""Decode/forward parity: step-by-step decode must reproduce the full-seq
forward logits for every block family.  This is the core correctness
invariant of the serving substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                SSMConfig, XLSTMConfig)
from repro.models import transformer as tf


def tiny(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": tiny(),
    "dense_window": tiny(attn_window_pattern=(4, 0)),
    "parallel": tiny(block_pattern=("parallel",), use_bias=False),
    "softcap": tiny(attn_logit_softcap=30.0, final_logit_softcap=20.0),
    "moe": tiny(block_pattern=("attn_moe",),
                moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                              capacity_factor=2.0)),
    "mla": tiny(mla=MLAConfig(kv_lora_rank=32, qk_rope_head_dim=8,
                              qk_nope_head_dim=16, v_head_dim=16)),
    "mamba2": tiny(block_pattern=("mamba2",),
                   ssm=SSMConfig(state_dim=16, head_dim=16, chunk=4)),
    "mlstm": tiny(block_pattern=("mlstm",), xlstm=XLSTMConfig()),
    "slstm": tiny(block_pattern=("slstm",), xlstm=XLSTMConfig()),
    "hybrid_shared": tiny(n_layers=4, block_pattern=("mamba2",),
                          shared_attn_every=2,
                          ssm=SSMConfig(state_dim=16, head_dim=16, chunk=4)),
    "xlstm_mix": tiny(n_layers=4, block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
                      xlstm=XLSTMConfig()),
    "tied": tiny(tie_embeddings=True),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    logits_full, _ = tf.forward(params, cfg, tokens=tokens)

    cache = tf.init_decode_cache(cfg, B, max_len=S)
    outs = []
    for i in range(S):
        lg, cache, _, _ = tf.decode_step(
            params, cfg, tokens[:, i:i + 1], jnp.int32(i), cache)
        outs.append(lg[:, 0])
    logits_step = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(logits_full), np.asarray(logits_step),
                               rtol=2e-2, atol=2e-2)


def test_factor_stack_patterns():
    from repro.models.transformer import LayerSpec, factor_stack
    a = LayerSpec("attn_ffn", 0, False)
    w = LayerSpec("attn_ffn", 4, False)
    m = LayerSpec("mamba2", 0, False)
    ms = LayerSpec("mamba2", 0, True)
    # uniform
    pre, per, n, suf = factor_stack((a,) * 10)
    assert (len(pre), per, n, suf) == (0, (a,), 10, ())
    # alternating (gemma2)
    pre, per, n, suf = factor_stack((w, a) * 5)
    assert per == (w, a) and n == 5 and not pre and not suf
    # dense-first (deepseek-v2)
    pre, per, n, suf = factor_stack((a,) + (m,) * 8)
    assert pre == (a,) and per == (m,) and n == 8
    # zamba2: shared attn every 6, 81 layers
    specs = tuple(ms if i % 6 == 0 else m for i in range(81))
    pre, per, n, suf = factor_stack(specs)
    assert len(per) * n + len(pre) + len(suf) == 81
    assert len(pre) + len(per) + len(suf) <= 10


def test_moe_matches_dense_ref_when_capacity_ample():
    cfg = CASES["moe"]
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model), jnp.float32)
    y1, _ = L.moe_apply(p, cfg, x)
    y2, _ = L.moe_apply_dense_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_matches_quadratic_ref():
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 32, 2, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    y_ref = L.mlstm_parallel_ref(q, k, v, i_pre, f_pre)
    y_chk = L.mlstm_chunked(q, k, v, i_pre, f_pre, chunk=8)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               rtol=1e-4, atol=1e-5)
