"""Continuous-batching serving: per-request token parity vs the static
Engine, FCFS scheduling, lazy-aware admission, eviction, metrics."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.tree_util import tree_flatten_with_path, tree_unflatten

from repro.configs.base import LazyConfig, ModelConfig, SSMConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import RequestSpec, request_trace
from repro.models import transformer as tf
from repro.serving.engine import ContinuousBatchingEngine, Engine
from repro.serving.scheduler import Scheduler


def tiny(**kw):
    base = dict(n_layers=3, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                d_ff=64, vocab_size=61, dtype="float32",
                lazy=LazyConfig(enabled=True, mode="masked"))
    base.update(kw)
    return ModelConfig(**base)


ARCHS = {
    "dense": {},
    # ring-buffer KV caches: per-slot pos vectors must stay isolated
    "swa": dict(attn_window_pattern=(4,)),
    # recurrent state instead of KV: per-slot SSM state must stay isolated
    "mamba2": dict(block_pattern=("mamba2",),
                   ssm=SSMConfig(state_dim=8, head_dim=16, chunk=4)),
}


def noisy_gates(params, bias=0.0, wscale=40.0):
    """Push probe scores to straddle the 0.5 threshold so masked mode
    actually skips on some (sample, step, module) calls."""
    flat, treedef = tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if any(k in ("g_attn", "g_ffn", "g_block") for k in keys):
            leaf = jnp.full_like(leaf, bias) if keys[-1] == "b" \
                else leaf * wscale
        out.append(leaf)
    return tree_unflatten(treedef, out)


@functools.lru_cache(maxsize=4)
def fixture(arch: str = "dense"):
    cfg = tiny(**ARCHS[arch])
    params = noisy_gates(tf.init_lm(jax.random.PRNGKey(0), cfg))
    # two prompt-length buckets bound the prefill retrace count
    trace = tuple(request_trace(
        5, cfg.vocab_size, seed=3, mean_interarrival=0.4,
        short_prompt=(3, 3), long_prompt=(6, 6),
        short_output=(3, 5), long_output=(6, 8)))
    return cfg, params, trace


# ---------------------------------------------------------------------------
# Token parity: continuous batching must not change any request's tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", ["off", "masked"])
def test_token_parity_vs_static_engine(arch, mode):
    """Every request decoded through the continuous-batching engine yields
    the same greedy tokens as the same request decoded alone through the
    static Engine — with a 2-slot pool so requests queue, slots are reused,
    and per-slot lazy/KV/recurrent caches must reset between occupants."""
    cfg, params, trace = fixture(arch)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   lazy_mode=mode)
    res = eng.run(trace)
    ref = Engine(cfg, params, max_len=32, lazy_mode=mode)
    for r in trace:
        expect = ref.generate(r.prompt[None], n_new=r.max_new).tokens[0]
        np.testing.assert_array_equal(
            res.outputs[r.rid], expect, err_msg=f"rid={r.rid} mode={mode}")
    if mode == "masked" and arch == "dense":
        # the noisy gates must have exercised the per-slot skip path
        assert res.metrics.realized_lazy_ratio() > 0.05


def test_token_parity_plan_mode():
    cfg, params, trace = fixture()
    plan = lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   lazy_mode="plan", plan=plan)
    res = eng.run(trace)
    ref = Engine(cfg, params, max_len=32, lazy_mode="plan", plan=plan)
    for r in trace:
        expect = ref.generate(r.prompt[None], n_new=r.max_new).tokens[0]
        np.testing.assert_array_equal(res.outputs[r.rid], expect,
                                      err_msg=f"rid={r.rid}")
    assert res.metrics.realized_lazy_ratio() > 0.2


# ---------------------------------------------------------------------------
# Scheduling policy
# ---------------------------------------------------------------------------


def test_fcfs_completion_order_single_slot():
    cfg, params, trace = fixture()
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32)
    res = eng.run(trace)
    done = [res.metrics.requests[r.rid]["done"] for r in trace]
    assert done == sorted(done), "1-slot FCFS must complete in arrival order"


def test_scheduler_join_on_free_slot_vs_batch_synchronous():
    reqs = [RequestSpec(i, 0.0, np.zeros(2, np.int32), 4) for i in range(3)]
    s = Scheduler(4)
    s.submit(reqs)
    # continuous: joins even while other slots are active
    assert len(s.admit(0.0, 2, [0.0, 0.0])) == 2
    sync = Scheduler(4, batch_synchronous=True)
    sync.submit(reqs)
    assert sync.admit(0.0, 2, [0.0, 0.0]) == []      # pool not drained
    assert len(sync.admit(0.0, 4, [])) == 3          # drained -> batch joins


def test_scheduler_not_yet_arrived_requests_wait():
    s = Scheduler(2)
    s.submit([RequestSpec(0, 5.0, np.zeros(2, np.int32), 4)])
    assert s.admit(1.0, 2, []) == []
    assert len(s.admit(5.0, 2, [])) == 1


def test_scheduler_lazy_aware_admission_packs_lazy_slots_denser():
    """Cost model: step = 0.2 + 0.8 * sum(1 - r_i) / n_slots.  Under a 0.6
    budget, 4 slots admit only 2 diligent requests but all 4 lazy ones —
    the planned skip budget buys admission headroom."""
    reqs = [RequestSpec(i, 0.0, np.zeros(2, np.int32), 4) for i in range(4)]
    diligent = Scheduler(4, cost_budget=0.6)
    diligent.submit(reqs)
    assert len(diligent.admit(0.0, 4, [], new_skip_ratio=0.0)) == 2
    lazy = Scheduler(4, cost_budget=0.6)
    lazy.submit(reqs)
    assert len(lazy.admit(0.0, 4, [], new_skip_ratio=0.5)) == 4
    assert diligent.estimate_step_cost([0.0, 0.0]) == pytest.approx(0.6)
    assert lazy.estimate_step_cost([0.5] * 4) == pytest.approx(0.6)


# ---------------------------------------------------------------------------
# Eviction
# ---------------------------------------------------------------------------


def test_scheduler_tiny_cost_budget_still_makes_progress():
    """A budget below the one-slot step cost must not starve an empty
    pool: the first admission always goes through."""
    cfg, params, trace = fixture()
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   cost_budget=0.1)
    res = eng.run(trace[:3])
    assert len(res.outputs) == 3
    s = Scheduler(1, cost_budget=0.1)
    s.submit([RequestSpec(i, 0.0, np.zeros(2, np.int32), 4)
              for i in range(2)])
    assert len(s.admit(0.0, 1, [])) == 1     # empty pool: progress
    assert s.admit(0.0, 1, [0.0]) == []      # occupied: budget binds


def test_plan_mode_skips_without_gate_params():
    """Plan skips come from the plan, not the probes: with lazy gates
    absent from params the plan must still apply, so the accounted ratio
    describes compute that was actually removed."""
    cfg = tiny(lazy=LazyConfig(enabled=False))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    assert "g_attn" not in str(jax.tree_util.tree_structure(params))
    cache = tf.init_decode_cache(cfg, 1, 16)
    lazy = tf.init_lazy_decode_cache(cfg, 1)
    tok = jnp.array([[3]], jnp.int32)
    _, cache, lazy, _ = tf.decode_step(
        params, cfg, tok, jnp.int32(0), cache, lazy_cache=lazy,
        lazy_mode="plan", lazy_first_step=True)
    out = {}
    for name, fill in (("run", False), ("skip", True)):
        row = jnp.full((cfg.n_layers, 2), fill)
        lg, _, _, _ = tf.decode_step(
            params, cfg, tok, jnp.int32(1), cache, lazy_cache=lazy,
            lazy_mode="plan", plan_row=row)
        out[name] = np.asarray(lg)
    # identical logits would mean the gate-less plan row was ignored
    assert not np.allclose(out["run"], out["skip"])


def _prefill_argmax(cfg, params, prompt):
    cache = tf.init_decode_cache(cfg, 1, 32)
    lg, _, _, _ = tf.decode_step(params, cfg, jnp.asarray(prompt[None]),
                                 jnp.int32(0), cache)
    return int(jnp.argmax(lg[:, -1], axis=-1)[0])


def test_eviction_on_eos_truncates_output():
    cfg, params, trace = fixture()
    base = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32)
    # the prefill argmax is the first decode INPUT, not an output; find a
    # request with a decode output that differs from it so admission-time
    # EOS does not fire and mid-stream eviction is what gets exercised
    for r in trace:
        ref = base.run([r]).outputs[r.rid]
        P = len(r.prompt)
        outs = ref[P:]
        assert len(outs) == r.max_new
        tok0 = _prefill_argmax(cfg, params, r.prompt)
        if any(int(t) != tok0 for t in outs):
            break
    else:
        pytest.skip("untrained model produced only repeats of tok0")
    eos = next(int(t) for t in outs if int(t) != tok0)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32,
                                   eos_id=eos)
    got = eng.run([r]).outputs[r.rid]
    k = int(np.argmax(outs == eos))         # first occurrence truncates
    np.testing.assert_array_equal(got, ref[:P + k + 1])


def test_first_token_eos_completes_at_admission():
    """A request whose prefill argmax IS the EOS yields an empty response
    instead of decoding max_new garbage tokens."""
    cfg, params, trace = fixture()
    r = trace[0]
    tok0 = _prefill_argmax(cfg, params, r.prompt)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32,
                                   eos_id=tok0)
    got = eng.run([r]).outputs[r.rid]
    np.testing.assert_array_equal(got, np.asarray(r.prompt, np.int32))


def test_run_rejects_malformed_trace_up_front():
    """A malformed request fails fast at submit, not mid-flight after
    other requests already completed."""
    cfg, params, trace = fixture()
    bad = RequestSpec(99, 10.0, np.zeros(40, np.int32), 4)   # > max_len
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32)
    with pytest.raises(ValueError, match="rid=99"):
        eng.run(list(trace) + [bad])


def test_soft_mode_fresh_slot_never_blends_zeroed_cache():
    gate = lazy_lib.init_lazy_gate(jax.random.PRNGKey(0), 8, init_bias=4.0)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 8))
    fn = lambda z: 2.0 * z
    zeros = jnp.zeros_like(z)
    out = lazy_lib.lazy_execute(fn, z, gate=gate, cache_y=zeros,
                                mode="soft", fresh=jnp.array([True, False]))
    np.testing.assert_allclose(np.asarray(out.y[0]), np.asarray(2.0 * z[0]),
                               rtol=1e-6)          # fresh: full run
    assert float(jnp.abs(out.y[1]).max()) \
        < float(jnp.abs(2.0 * z[1]).max())         # stale: blended


def test_eviction_on_max_len_truncates_output():
    cfg, params, _ = fixture()
    r = RequestSpec(0, 0.0,
                    np.arange(4, dtype=np.int32) % cfg.vocab_size, 100)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=8)
    out = eng.run([r]).outputs[r.rid]
    assert len(out) == 8                    # 4 prompt + 4 decoded, then evict


# ---------------------------------------------------------------------------
# Traced per-slot policy state (the fused-executor protocol, slot-stacked)
# ---------------------------------------------------------------------------


def test_traced_slot_state_resets_on_join():
    """A slot's traced policy state must reset when a new request joins
    (reset-then-join): with a 1-slot pool every request reuses the slot,
    so after the run the slot's traced step counter equals the LAST
    occupant's decode-step count — a cumulative counter would prove the
    state leaked across occupants."""
    cfg, params, trace = fixture()
    plan = lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32,
                                   lazy_mode="plan", plan=plan)
    res = eng.run(trace)
    assert len(res.outputs) == len(trace)
    done = [(res.metrics.requests[r.rid]["done"], r) for r in trace]
    last_req = max(done, key=lambda x: x[0])[1]
    produced = len(res.outputs[last_req.rid]) - len(last_req.prompt)
    state = jax.tree.map(np.asarray, eng._slot_state)
    assert int(state["step"][0]) == produced, \
        "slot state step counter leaked across occupants"
    # structure matches the policy's traced-state protocol, slot-stacked
    single = eng.policy.init_traced_state(
        n_steps=eng.plan_horizon, n_layers=cfg.n_layers, n_modules=2)
    assert set(state) == set(single)
    for k, v in single.items():
        assert state[k].shape == (1,) + np.asarray(v).shape


def test_traced_slot_state_survives_reset_then_join_parity():
    """Serving the same request before and after a slot turnover yields
    identical tokens — the traced state (and the rows it selects) cannot
    depend on the previous occupant."""
    cfg, params, trace = fixture()
    plan = lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
    r = trace[0]
    solo = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32,
                                    lazy_mode="plan", plan=plan)
    expect = solo.run([r]).outputs[r.rid]
    # same request arriving AFTER two other occupants churned the slot
    import dataclasses
    late = dataclasses.replace(r, rid=77, arrival=99.0)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=1, max_len=32,
                                   lazy_mode="plan", plan=plan)
    res = eng.run([trace[1], trace[2], late])
    np.testing.assert_array_equal(res.outputs[77], expect)


def test_step_decisions_run_under_jit():
    """The per-step decision path is fully jitted: after one engine step,
    no host-side plan_row calls happen per slot — the rows the engine
    accounts come straight from the jitted step's output.  Probe: a
    policy whose host-side plan_row explodes after construction still
    serves (rows come from the device plan, not plan_row)."""
    cfg, params, trace = fixture()
    plan = lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   lazy_mode="plan", plan=plan)

    def boom(step, state=None):
        raise AssertionError("host-side plan_row called during decode")

    eng.policy.plan_row = boom
    res = eng.run(trace[:3])
    assert len(res.outputs) == 3
    assert res.metrics.realized_lazy_ratio() > 0.2


# ---------------------------------------------------------------------------
# Trace generator + metrics
# ---------------------------------------------------------------------------


def test_request_trace_deterministic_and_mixed():
    a = request_trace(12, 97, seed=7)
    b = request_trace(12, 97, seed=7)
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival and ra.max_new == rb.max_new
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0
    assert len({len(r.prompt) for r in a}) > 1, "length mixture expected"
    c = request_trace(12, 97, seed=8)
    assert any(ra.arrival != rc.arrival for ra, rc in zip(a, c))


def test_metrics_summary_sanity():
    cfg, params, trace = fixture()
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                   lazy_mode="masked")
    s = eng.run(trace).metrics.summary()
    assert s["n_requests"] == len(trace)
    assert s["requests_per_s"] > 0 and s["tokens_per_s"] > 0
    assert s["latency_p95_s"] >= s["latency_p50_s"] > 0
    assert s["ttft_p50_s"] <= s["latency_p50_s"]
    assert 0.0 <= s["realized_lazy_ratio"] <= 1.0
    assert 0 < s["mean_active_slots"] <= 2


def test_continuous_throughput_at_least_static():
    cfg, params, trace = fixture()
    plan = lazy_lib.uniform_plan(8, cfg.n_layers, 2, 0.5, seed=1)
    out = {}
    for name, sync in (("cont", False), ("static", True)):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32,
                                       lazy_mode="plan", plan=plan,
                                       batch_synchronous=sync)
        out[name] = eng.run(trace).metrics.summary()["requests_per_s"]
    assert out["cont"] >= out["static"] - 1e-9
