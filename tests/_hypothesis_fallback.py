"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container that runs tier-1 has no hypothesis wheel; rather than skip
the property tests we run them over a deterministic pseudo-random sample
of the strategy space (seeded, so failures reproduce).  Only the tiny API
surface the suite uses is provided: ``given``, ``settings``, and
``strategies.integers/floats/booleans``.  Shrinking, the example database,
and health checks are intentionally absent.

Registered from conftest.py via ``install()`` ONLY when the real package
is missing, so environments with hypothesis keep full property testing.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sampler, boundary):
        self._sampler = sampler
        self._boundary = boundary   # deterministic edge examples, tried first

    def boundary(self):
        return list(self._boundary)

    def sample(self, rng):
        return self._sampler(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     [min_value, max_value])


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     [min_value, max_value])


def booleans():
    return _Strategy(lambda rng: rng.choice([False, True]), [False, True])


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xC0FFEE)
            # boundary examples first (hypothesis-style minimal cases), then
            # seeded random draws up to the example budget
            examples = [tuple(s.boundary()[0] for s in strats),
                        tuple(s.boundary()[-1] for s in strats)]
            while len(examples) < limit:
                examples.append(tuple(s.sample(rng) for s in strats))
            for values in examples[:limit]:
                try:
                    fn(*args, *values, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {values!r}: {e}"
                    ) from e
        # hide the strategy-filled params from pytest's fixture resolution
        # (real hypothesis does the same)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def install():
    """Register the fallback as ``hypothesis`` in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
