"""repro.obs.profile: the steady-state measurement harness, AOT compile
timing, memory watermarks, device-trace merge, and the zero-overhead
contract (profiling off must not change outputs, compiles, or the traced
program), plus the serving queue/prefill/decode phase decomposition."""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.bench_trajectory import compile_counter
from repro.configs.base import LazyConfig, ModelConfig
from repro.data.synthetic import request_trace
from repro.models import transformer as tf
from repro.obs import profile as profile_lib
from repro.obs import trace as trace_lib
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.metrics import ServingMetrics


# ---------------------------------------------------------------- measure


def test_measure_robust_stats_and_call_count():
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        return jnp.zeros(())

    m = profile_lib.measure(fn, iters=5, warmup=2)
    assert calls["n"] >= 7            # >= warmup + iters
    assert m.n_samples == 5
    assert 1 <= m.iters <= 5
    assert m.median_us >= 0 and m.mad_us >= 0
    assert m.warmup_iters >= 2
    assert m.rejected == m.n_samples - m.iters
    assert m.median_s == pytest.approx(m.median_us / 1e6)


def test_measure_warmup_zero_skips_warmup():
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        return np.zeros(())

    m = profile_lib.measure(fn, iters=3, warmup=0)
    assert calls["n"] == 3
    assert m.warmup_iters == 0


def test_measure_rejects_the_slow_tail():
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        # one sample sleeps ~50ms against a ~0ms baseline: far past the
        # median + max(5 scaled MADs, 1x median) cutoff
        if calls["n"] == 9:
            time.sleep(0.05)
        return np.zeros(())

    m = profile_lib.measure(fn, iters=7, warmup=2)
    assert m.rejected >= 1
    assert m.median_us < 25_000       # the sleep did not poison the median


# ------------------------------------------------------------ aot_compile


def test_aot_compile_times_lower_and_compile_separately():
    fn = jax.jit(lambda a: a * 2.0 + 1.0)
    x = jnp.arange(8.0)
    compiled, t = profile_lib.aot_compile(fn, x)
    assert t["lower_s"] >= 0 and t["compile_s"] >= 0
    np.testing.assert_array_equal(np.asarray(compiled(x)),
                                  np.asarray(fn(x)))


# ------------------------------------------------------- memory watermarks


def test_memory_watermarks_sees_live_arrays():
    keep = jnp.ones((256, 256), jnp.float32)   # 256KiB held live
    mw = profile_lib.memory_watermarks()
    assert mw["source"] in ("device.memory_stats", "jax.live_arrays")
    assert mw["total_bytes"] >= keep.nbytes
    assert mw["per_device"]
    # the fallback has no peak watermark: None, never a fabricated 0
    if mw["source"] == "jax.live_arrays":
        assert mw["peak_bytes"] is None
    del keep


# ------------------------------------------- zero-overhead contract (pins)


def _tiny_fn():
    return jax.jit(lambda a: jnp.sin(a) @ a), jnp.eye(4)


def test_measure_off_the_record_compiles_nothing_warm():
    fn, x = _tiny_fn()
    jax.block_until_ready(fn(x))      # warm the jit cache
    with compile_counter() as counts:
        profile_lib.measure(fn, x, iters=3, warmup=1)
    assert counts["n"] == 0


def test_device_trace_outputs_bit_identical_and_same_jaxpr():
    fn, x = _tiny_fn()
    baseline = np.asarray(jax.block_until_ready(fn(x)))
    jaxpr_outside = str(jax.make_jaxpr(lambda a: jnp.sin(a) @ a)(x))
    tracer = trace_lib.Tracer()
    with profile_lib.device_trace(tracer):
        inside = np.asarray(jax.block_until_ready(fn(x)))
        jaxpr_inside = str(jax.make_jaxpr(lambda a: jnp.sin(a) @ a)(x))
    np.testing.assert_array_equal(baseline, inside)
    assert jaxpr_inside == jaxpr_outside


# --------------------------------------------------- device-trace merging


def test_device_trace_merges_a_valid_chrome_timeline():
    tracer = trace_lib.Tracer()
    fn, x = _tiny_fn()
    with tracer.span("host_phase", cat="test"):
        with profile_lib.device_trace(tracer):
            jax.block_until_ready(fn(x))
    trace_lib.validate_chrome_trace(tracer.sorted_events())
    merged = [e for e in tracer.events
              if e["name"] == "device_trace_merged"]
    failed = [e for e in tracer.events
              if e["name"] == "device_trace_failed"]
    assert merged or failed           # the capture always annotates
    if failed or merged[0]["args"]["n_events"] == 0:
        pytest.skip("jax.profiler produced no device events here")
    dev = [e for e in tracer.events if e["pid"] == trace_lib.PID_DEVICE]
    assert any(e["ph"] == "X" for e in dev)
    names = [e for e in dev
             if e["ph"] == "M" and e["name"] == "process_name"]
    assert len(names) == 1
    assert names[0]["args"]["name"] == trace_lib.DEVICE_PROCESS_NAME
    # merged spans are rebased onto the tracer clock: non-negative ts,
    # and the export stays schema-valid (validated above)
    assert all(e["ts"] >= 0.0 for e in dev)


def test_merge_device_trace_empty_dir_is_a_noop(tmp_path):
    tracer = trace_lib.Tracer()
    n_before = len(tracer.events)
    assert profile_lib.merge_device_trace(tracer, str(tmp_path)) == 0
    assert len(tracer.events) == n_before


# ------------------------------------------------------------- trend file


def test_append_trend_appends_jsonl_rows(tmp_path):
    path = str(tmp_path / "PERF_x.jsonl")
    profile_lib.append_trend(path, {"a": 1})
    profile_lib.append_trend(path, {"a": 2})
    rows = [json.loads(line) for line in open(path)]
    assert rows == [{"a": 1}, {"a": 2}]


# ------------------------------------- serving phase decomposition (p50s)


def test_phase_decomposition_sums_to_latency_exactly():
    met = ServingMetrics(n_slots=2, modules_per_slot=4)
    # request 0: queued 1.0s, prefilled 0.5s, decoded 2.5s
    met.record_admit(0, arrival=0.0, now=1.5, prompt_len=4, prefill_s=0.5)
    met.record_completion(0, now=4.0, n_out=3)
    # request 1: admitted instantly
    met.record_admit(1, arrival=2.0, now=2.25, prompt_len=4,
                     prefill_s=0.25)
    met.record_completion(1, now=5.0, n_out=3)
    s = met.summary()
    for r in met.requests.values():
        queue = r["admit"] - r["prefill_s"] - r["arrival"]
        assert queue >= 0 and r["prefill_s"] >= 0
        assert queue + r["prefill_s"] + (r["done"] - r["admit"]) == \
            pytest.approx(r["done"] - r["arrival"])
    assert s["queue_p50_s"] == pytest.approx(0.5)   # median of 1.0, 0.0
    assert s["prefill_p50_s"] == pytest.approx(0.375)
    assert s["decode_p50_s"] == pytest.approx(2.625)
    # pointwise domination: every phase percentile <= the same latency
    # percentile (phases are nonneg parts of each request's latency)
    for q in (50, 95):
        for phase in ("queue", "prefill", "decode"):
            assert s[f"{phase}_p{q}_s"] <= s[f"latency_p{q}_s"] + 1e-9


def test_phase_percentiles_nan_when_no_completions():
    met = ServingMetrics(n_slots=2, modules_per_slot=4)
    s = met.summary()
    for k in ("queue_p50_s", "prefill_p50_s", "decode_p50_s",
              "queue_p95_s", "prefill_p95_s", "decode_p95_s"):
        assert np.isnan(s[k])


def test_engine_run_attributes_phases():
    cfg = ModelConfig(
        name="phase-test", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=97, dtype="float32",
        lazy=LazyConfig(enabled=True, mode="plan"))
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    trace = request_trace(6, cfg.vocab_size, seed=0, mean_interarrival=0.3,
                          short_prompt=(4, 4), long_prompt=(8, 8),
                          short_output=(2, 4), long_output=(4, 6))
    max_len = max(len(r.prompt) + r.max_new for r in trace) + 4
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=max_len)
    res = eng.run(trace)
    s = res.metrics.summary()
    assert s["n_requests"] > 0
    for r in res.metrics.requests.values():
        assert r["prefill_s"] > 0                       # prefill is charged
        queue = r["admit"] - r["prefill_s"] - r["arrival"]
        assert queue >= -1e-9
        if r["done"] is not None:
            total = queue + r["prefill_s"] + (r["done"] - r["admit"])
            assert total == pytest.approx(r["done"] - r["arrival"])
    for phase in ("queue", "prefill", "decode"):
        assert np.isfinite(s[f"{phase}_p50_s"])
        assert s[f"{phase}_p50_s"] <= s["latency_p50_s"] + 1e-9
