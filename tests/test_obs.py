"""repro.obs observability layer: telemetry bit-exactness for every
policy, the telemetry-off single-compile contract, Chrome-trace schema
validation, ServingMetrics NaN/goodput semantics, engine-side drift, and
the launch/obs.py report assembled in-process."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cache as cache_lib
from repro.cache import calibrate as calibrate_lib
from repro.configs.base import LazyConfig, ModelConfig
from repro.core import lazy as lazy_lib
from repro.data.synthetic import LatentImageDataset, request_trace
from repro.launch import obs as obs_cli
from repro.models import dit as dit_lib
from repro.models import transformer as tf
from repro.obs import report as report_lib
from repro.obs import telemetry as telemetry_lib
from repro.obs import trace as trace_lib
from repro.sampling import ddim, trajectory
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.metrics import ServingMetrics
from repro.train import optim, trainer

T, L, M = 5, 3, 2       # sampling steps / layers / plan columns


@pytest.fixture(scope="module")
def setup():
    """Briefly pretrained tiny DiT (same shape as test_trajectory's): on
    an untrained adaLN-zero model module outputs never reach the sample,
    so every skip/drift telemetry check would be vacuous."""
    cfg = ModelConfig(name="dit_obs", family="dit", n_layers=L, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, dit_patch=2,
                      dit_input_size=8, dit_in_channels=4, dit_n_classes=10,
                      rope_type="none", dtype="float32",
                      lazy=LazyConfig(enabled=True, mode="masked"))
    params = dit_lib.init_dit(jax.random.PRNGKey(0), cfg)
    sched = ddim.linear_schedule(100)
    it = LatentImageDataset(cfg, seed=0).batches(8, seed=1)
    opt = optim.adamw_init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(12):
        x0, y = next(it)
        key, k = jax.random.split(key)
        params, opt, _ = trainer.diffusion_train_step(
            params, opt, cfg, sched, jnp.asarray(x0), jnp.asarray(y), k,
            lr=2e-3)
    return cfg, params, sched


def synth_dit_artifact(n_steps=T, n_layers=L, seed=0):
    rng = np.random.default_rng(seed)
    rel = rng.uniform(0.01, 1.0, (n_steps, n_layers, M))
    rel[0] = np.inf
    return calibrate_lib.CalibrationArtifact(
        kind="dit", arch="dit_obs", n_steps=n_steps, n_layers=n_layers,
        modules=("attn", "ffn"), rel_err=rel)


def make_policy(name):
    if name == "none":
        return cache_lib.get_policy("none")
    if name == "stride":
        return cache_lib.get_policy("stride", stride=2)
    if name == "lazy_gate":
        return cache_lib.get_policy("lazy_gate", threshold=0.1)
    if name == "smoothcache":
        art = synth_dit_artifact()
        return cache_lib.get_policy(
            "smoothcache", calibration=art,
            error_threshold=art.quantile_threshold(0.5))
    if name == "static_router":
        return cache_lib.get_policy("static_router", ratio=0.5,
                                    calibration=synth_dit_artifact(seed=1))
    if name == "plan":
        return cache_lib.get_policy(
            "plan", plan=lazy_lib.uniform_plan(T, L, M, 0.5, seed=0).skip)
    if name == "delta":
        return cache_lib.get_policy("delta", ratio=0.5,
                                    calibration=synth_dit_artifact(seed=2))
    if name == "learned":
        rng = np.random.default_rng(3)
        art = cache_lib.distill_scores(
            "lazy_gate", "dit_obs", rng.uniform(0, 1, (T, L, M)),
            target_ratio=0.4)
        return cache_lib.get_policy("learned", artifact=art)
    raise ValueError(name)


ALL_POLICIES = ("none", "stride", "lazy_gate", "smoothcache",
                "static_router", "plan", "delta", "learned")


def _lm_cfg(n_layers=2, d_model=32):
    return ModelConfig(
        name="obs-serve", n_layers=n_layers, d_model=d_model, n_heads=4,
        n_kv_heads=2, head_dim=d_model // 4, d_ff=2 * d_model, vocab_size=97,
        dtype="float32", lazy=LazyConfig(enabled=True, mode="plan"))


# ---------------------------------------------------------------------------
# trajectory telemetry: bit-exactness + counter semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_telemetry_is_bit_exact_and_well_formed(setup, name):
    """Telemetry on vs off: identical output bits, identical realized
    skip ratio, and a well-formed drained pytree — executed + skipped
    partition every (step, layer, module) cell, drift is finite, and the
    counters reproduce the executor's own skip accounting."""
    cfg, params, sched = setup
    kw = dict(key=jax.random.PRNGKey(3), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5)
    off, aux_off = trajectory.sample_trajectory(
        params, cfg, sched, policy=make_policy(name), **kw)
    on, aux_on = trajectory.sample_trajectory(
        params, cfg, sched, policy=make_policy(name), telemetry=True, **kw)
    assert np.array_equal(np.asarray(off), np.asarray(on)), \
        f"{name}: telemetry changed the sampled bits"
    assert "telemetry" not in aux_off
    tele = aux_on["telemetry"]
    assert set(tele) == set(telemetry_lib.COUNTER_KEYS)
    for key in telemetry_lib.COUNTER_KEYS:
        assert tele[key].shape == (T, L, M), f"{name}/{key}"
        assert np.all(np.isfinite(tele[key])), f"{name}/{key} not finite"
    np.testing.assert_allclose(tele["executed"] + tele["skipped"],
                               np.ones((T, L, M)), atol=1e-6)
    # the counters must agree with the executor's n_skipped accounting
    summ = telemetry_lib.summarize(tele)
    assert summ["realized_skip_ratio"] == \
        pytest.approx(aux_on["realized_skip_ratio"], abs=1e-6)
    assert aux_on["realized_skip_ratio"] == \
        pytest.approx(aux_off["realized_skip_ratio"], abs=1e-9)
    # step 0 always primes the cache: nothing skipped, drift pinned
    assert float(tele["skipped"][0].sum()) == 0.0
    np.testing.assert_allclose(tele["drift_cos"][0], 1.0, atol=0)
    np.testing.assert_allclose(tele["drift_rel_l2"][0], 0.0, atol=0)


def test_plan_policy_telemetry_matches_device_plan(setup):
    """For a schedule policy the skipped counter IS the plan: device_plan
    rows with the first step zeroed (it primes the cache)."""
    cfg, params, sched = setup
    pol = make_policy("static_router")
    _, aux = trajectory.sample_trajectory(
        params, cfg, sched, key=jax.random.PRNGKey(3),
        labels=jnp.array([0, 1]), n_steps=T, cfg_scale=1.5, policy=pol,
        telemetry=True)
    expect = np.asarray(pol.device_plan(T, L, M), np.float32)
    expect[0] = 0.0
    np.testing.assert_array_equal(aux["telemetry"]["skipped"], expect)


def test_none_policy_drift_is_measurable_and_nonzero(setup):
    """The `none` baseline skips nothing but still reports consecutive-
    step drift (the cache is threaded write-only) — the reference curve
    the lazy policies are judged against."""
    cfg, params, sched = setup
    _, aux = trajectory.sample_trajectory(
        params, cfg, sched, key=jax.random.PRNGKey(3),
        labels=jnp.array([0, 1]), n_steps=T, cfg_scale=1.5,
        policy=make_policy("none"), telemetry=True)
    tele = aux["telemetry"]
    assert float(tele["skipped"].sum()) == 0.0
    rel_after_first = np.asarray(tele["drift_rel_l2"][1:])
    assert np.all(np.isfinite(rel_after_first))
    assert float(rel_after_first.mean()) > 0.0, \
        "none-policy drift is identically zero: the cache is not advancing"


def test_telemetry_off_is_the_default_sampler_and_compiles_nothing(setup):
    """The single-compile contract with telemetry off: the default build
    IS the telemetry=False build (same cached executable), a warm sample
    triggers zero new backend compiles, and the telemetry=True build is a
    distinct executable that never evicts it."""
    from benchmarks.bench_trajectory import compile_counter
    cfg, params, sched = setup
    pol = make_policy("stride")
    trajectory.build_sampler.cache_clear()
    default = trajectory.build_sampler(cfg, pol, T, 1.5)
    assert trajectory.build_sampler(cfg, pol, T, 1.5,
                                    telemetry=False) is default
    assert trajectory.build_sampler(cfg, pol, T, 1.5,
                                    telemetry=True) is not default

    kw = dict(key=jax.random.PRNGKey(1), labels=jnp.array([0, 1]),
              n_steps=T, cfg_scale=1.5, policy=pol)
    trajectory.sample_trajectory(params, cfg, sched, **kw)          # warm
    with compile_counter() as c:
        trajectory.sample_trajectory(params, cfg, sched, **kw)
    assert c["n"] == 0, \
        f"warm telemetry-off sample compiled {c['n']} more times"
    # toggling telemetry on and back off reuses both executables
    trajectory.sample_trajectory(params, cfg, sched, telemetry=True, **kw)
    with compile_counter() as c:
        trajectory.sample_trajectory(params, cfg, sched, **kw)
        trajectory.sample_trajectory(params, cfg, sched, telemetry=True,
                                     **kw)
    assert c["n"] == 0, "toggling telemetry retraced a cached sampler"


def test_telemetry_off_trace_carries_no_telemetry_ops(setup):
    """The HLO contract, checked at the jaxpr level: the telemetry-off
    trace contains none of telemetry's machinery (no drift barrier, a
    strictly smaller program) — the None carry entry contributes zero
    pytree leaves, so the off-build traces exactly as if the telemetry
    code path did not exist."""
    cfg, params, sched = setup
    pol = make_policy("static_router")

    def jaxpr_of(telemetry):
        fn = trajectory.build_sampler(cfg, pol, T, 1.5, telemetry=telemetry)
        args = trajectory.prepare_inputs(
            cfg, sched, pol, key=jax.random.PRNGKey(0),
            labels=jnp.array([0, 1]), n_steps=T)
        return str(jax.make_jaxpr(fn)(params, *args))

    off = jaxpr_of(False)
    on = jaxpr_of(True)
    # remat emits barriers of its own, so compare counts: only the ON
    # build adds the telemetry drift barrier on top of the baseline's
    assert on.count("optimization_barrier") > off.count(
        "optimization_barrier"), "telemetry added no drift barrier"
    tele_shape = f"f32[{T},{L},{M}]"
    assert tele_shape not in off, \
        f"telemetry-off trace carries a {tele_shape} counter buffer"
    assert tele_shape in on
    assert len(on) > len(off)


# ---------------------------------------------------------------------------
# structured tracing
# ---------------------------------------------------------------------------


def test_tracer_chrome_schema_and_roundtrip(tmp_path):
    tr = trace_lib.Tracer()
    with tr.span("outer", cat="test", args={"k": 1}):
        tr.instant("hit", args={"rid": 7})
    tr.counter("pool", {"active": 2.0, "queued": 1.0})
    tr.complete("svc", trace_lib.Tracer.service_us(1.5),
                trace_lib.Tracer.service_us(0.25),
                pid=trace_lib.PID_SERVICE, cat="serve")
    events = tr.sorted_events()
    trace_lib.validate_chrome_trace(events)        # must not raise
    # process-name metadata for all three fixed tracks
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["pid"] for e in meta} == {trace_lib.PID_HOST,
                                        trace_lib.PID_JAX,
                                        trace_lib.PID_SERVICE}
    # the service-clock event landed on the service track at 1.5e6 µs
    svc = next(e for e in events if e["name"] == "svc")
    assert svc["pid"] == trace_lib.PID_SERVICE and svc["ts"] == 1.5e6

    chrome = tr.to_chrome(str(tmp_path / "t.json"))
    with open(chrome) as f:
        payload = json.load(f)
    assert payload["traceEvents"] == events
    jsonl = tr.to_jsonl(str(tmp_path / "t.jsonl"))
    with open(jsonl) as f:
        lines = [json.loads(ln) for ln in f]
    assert lines == events


def test_tracer_captures_jax_compile_events():
    tr = trace_lib.Tracer()
    with tr.capture_compile_events():
        jax.jit(lambda x: x * 2.0 + 1.0)(jnp.arange(3.0))
    names = {e["name"] for e in tr.compile_events()}
    assert any(n.startswith(trace_lib.COMPILE_EVENT_PREFIXES)
               for n in names), f"no compile events captured: {names}"
    trace_lib.validate_chrome_trace(tr.sorted_events())
    # the listener is unregistered on exit: a fresh compile adds nothing
    before = len(tr.compile_events())
    jax.jit(lambda x: x - 3.0)(jnp.arange(4.0))
    assert len(tr.compile_events()) == before


@pytest.mark.parametrize("bad,msg", [
    ({"ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 1.0}, "name"),
    ({"ph": "Q", "name": "x", "pid": 1, "tid": 0, "ts": 0.0}, "phase"),
    ({"ph": "i", "name": "x", "pid": 1, "tid": 0, "ts": -5.0}, "ts"),
    ({"ph": "X", "name": "x", "pid": 1, "tid": 0, "ts": 0.0, "dur": -1.0},
     "dur"),
], ids=["missing-name", "unknown-phase", "negative-ts", "negative-dur"])
def test_validate_chrome_trace_rejects(bad, msg):
    with pytest.raises(ValueError, match=msg):
        trace_lib.validate_chrome_trace([bad])


def test_validate_chrome_trace_rejects_backwards_track():
    events = [{"ph": "i", "name": "a", "pid": 1, "tid": 0, "ts": 10.0},
              {"ph": "i", "name": "b", "pid": 1, "tid": 0, "ts": 5.0}]
    with pytest.raises(ValueError, match="backwards"):
        trace_lib.validate_chrome_trace(events)
    # same timestamps on DIFFERENT tracks are fine
    events[1]["pid"] = 2
    trace_lib.validate_chrome_trace(events)


# ---------------------------------------------------------------------------
# serving metrics: NaN semantics, rid guards, goodput, drift
# ---------------------------------------------------------------------------


def test_empty_summary_reports_nan_not_zero():
    s = ServingMetrics(n_slots=2, modules_per_slot=4).summary()
    for key in ("latency_p50_s", "latency_p95_s", "ttft_p50_s",
                "ttft_p95_s", "mean_queue_depth", "mean_active_slots",
                "drift_rel_l2_mean", "drift_cos_mean"):
        assert math.isnan(s[key]), f"{key} fabricated {s[key]} for no data"
    assert s["n_requests"] == 0.0 and s["requests_per_s"] == 0.0


def test_record_guards_reject_unadmitted_rids():
    met = ServingMetrics(n_slots=2, modules_per_slot=4)
    with pytest.raises(KeyError, match="never admitted"):
        met.record_first_token(99, 1.0)
    with pytest.raises(KeyError, match="never admitted"):
        met.record_completion(99, 1.0, 3)
    met.record_admit(99, arrival=0.0, now=0.5, prompt_len=4)
    met.record_first_token(99, 1.0)              # now fine
    met.record_completion(99, 2.0, 3)


def test_goodput_counts_only_within_slo():
    met = ServingMetrics(n_slots=2, modules_per_slot=4)
    for rid, (arrival, done) in enumerate([(0.0, 2.0), (0.0, 9.0)]):
        met.record_admit(rid, arrival=arrival, now=arrival, prompt_len=4)
        met.record_first_token(rid, arrival + 1.0)
        met.record_completion(rid, done, 2)
    s = met.summary(slo_latency_s=5.0)
    span = s["virtual_time_s"]
    assert s["requests_per_s"] == pytest.approx(2 / span)
    assert s["goodput_per_s"] == pytest.approx(1 / span)   # rid 1 misses SLO
    assert s["slo_latency_s"] == 5.0
    # within a loose SLO both complete in time: goodput == throughput
    loose = met.summary(slo_latency_s=100.0)
    assert loose["goodput_per_s"] == loose["requests_per_s"]


def test_step_drift_recording_feeds_summary_means():
    met = ServingMetrics(n_slots=2, modules_per_slot=4)
    met.record_step(1.0, 2, 0, 8.0, 0.0, 2)                # no drift data
    met.record_step(2.0, 2, 0, 8.0, 0.0, 2, drift_rel=0.4, drift_cos=0.9)
    met.record_step(3.0, 2, 0, 8.0, 0.0, 2, drift_rel=0.2, drift_cos=0.7)
    s = met.summary()
    assert s["drift_rel_l2_mean"] == pytest.approx(0.3)
    assert s["drift_cos_mean"] == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# serving engine: telemetry parity + drift + service-clock trace
# ---------------------------------------------------------------------------


def test_engine_telemetry_preserves_tokens_and_measures_drift():
    cfg = _lm_cfg()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    trace = request_trace(6, cfg.vocab_size, seed=0, mean_interarrival=0.3,
                          short_prompt=(4, 4), long_prompt=(10, 10),
                          short_output=(3, 6), long_output=(8, 14))
    max_len = max(len(r.prompt) + r.max_new for r in trace) + 4
    plan = lazy_lib.uniform_plan(16, cfg.n_layers, 2, 0.4, seed=1)

    def run(telemetry, tracer=None):
        eng = ContinuousBatchingEngine(
            cfg, params, n_slots=2, max_len=max_len, lazy_mode="plan",
            plan=plan, telemetry=telemetry, tracer=tracer)
        return eng.run(trace)

    off = run(False)
    tracer = trace_lib.Tracer()
    on = run(True, tracer)
    assert set(off.outputs) == set(on.outputs)
    for rid in off.outputs:
        np.testing.assert_array_equal(
            off.outputs[rid], on.outputs[rid],
            err_msg=f"telemetry changed served tokens for rid={rid}")
    s_on, s_off = on.metrics.summary(), off.metrics.summary()
    assert math.isnan(s_off["drift_rel_l2_mean"])
    assert math.isfinite(s_on["drift_rel_l2_mean"])
    assert s_on["drift_rel_l2_mean"] > 0.0
    assert math.isfinite(s_on["drift_cos_mean"])
    # the engine narrated the run on the service clock
    names = {e["name"] for e in tracer.events}
    assert {"prefill", "decode_step", "first_token", "completed"} <= names
    trace_lib.validate_chrome_trace(tracer.sorted_events())


# ---------------------------------------------------------------------------
# the assembled report (launch/obs.py in-process)
# ---------------------------------------------------------------------------


def test_run_report_covers_required_policies(setup, tmp_path):
    """The acceptance run: one report covering none / smoothcache /
    static_router / learned with heatmaps, drift curves and a compile
    timeline, artifacts written and schema-valid."""
    cfg, params, sched = setup
    policies = ("none", "smoothcache", "static_router", "learned")
    report, tracer, paths = obs_cli.run_report(
        policies=policies, n_steps=T, batch=2, seed=0, lazy_ratio=0.4,
        serve=True, serve_requests=4, n_slots=2,
        cfg=cfg, params=params,
        serve_cfg=_lm_cfg(),
        serve_params=tf.init_lm(jax.random.PRNGKey(1), _lm_cfg()),
        out_dir=str(tmp_path))

    assert report["schema"] == report_lib.SCHEMA
    metrics = report["metrics"]
    for name in policies:
        heat = metrics["skip_heatmap"][name]
        assert np.asarray(heat["heatmap"]).shape == (T, L)
        drift = metrics["drift_by_step"][name]
        assert len(drift["rel_l2"]) == T
        assert all(math.isfinite(v) for v in drift["rel_l2"])
        assert all(math.isfinite(v) for v in drift["cosine"])
    # the lazy policies actually skipped; the baseline did not
    assert metrics["skip_heatmap"]["none"]["realized_skip_ratio"] == 0.0
    assert metrics["skip_heatmap"]["static_router"]["realized_skip_ratio"] \
        > 0.1
    assert metrics["compile_timeline"], "no compile events in the timeline"
    assert metrics["service_percentiles"]["n_steps"] > 0
    assert math.isfinite(
        metrics["service_percentiles"]["drift_rel_l2_mean"])
    assert set(metrics["policies"]) == set(policies)

    # the written artifacts parse and the trace validates standalone
    with open(paths["report"]) as f:
        on_disk = json.load(f)
    assert on_disk["schema"] == report_lib.SCHEMA
    with open(paths["trace"]) as f:
        trace_lib.validate_chrome_trace(json.load(f)["traceEvents"])
    with open(paths["events"]) as f:
        assert len(f.readlines()) == len(tracer.sorted_events())


def test_report_registry_is_complete():
    assert {"skip_heatmap", "drift_by_step", "gate_scores", "policies",
            "compile_timeline", "service_percentiles"} \
        <= set(report_lib.available_metrics())


def test_verify_report_rejects_nonfinite_drift():
    bad = {"metrics": {"skip_heatmap": {}, "drift_by_step": {
        "p": {"rel_l2": [0.1, float("nan")], "cosine": [1.0, 1.0]}}}}
    with pytest.raises(ValueError, match="non-finite drift"):
        obs_cli.verify_report(bad)
    with pytest.raises(ValueError, match="missing metric"):
        obs_cli.verify_report({"metrics": {}})
