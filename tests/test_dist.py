"""Distribution-layer units that run on ONE device: sharding rules, HLO
analyzer, plan-mode unrolled decode, checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LazyConfig, ModelConfig
from repro.dist import hlo as hlo_lib
from repro.models import transformer as tf


def tiny(**kw):
    base = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=97, dtype="float32",
                lazy=LazyConfig(enabled=True))
    base.update(kw)
    return ModelConfig(**base)


def test_unrolled_plan_decode_matches_scan_when_no_skip():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = tf.init_decode_cache(cfg, B, max_len=8)
    lazy = tf.init_lazy_decode_cache(cfg, B)
    tok = jnp.ones((B, 1), jnp.int32)
    # prime caches with one normal step
    lg0, cache, lazy, _ = tf.decode_step(params, cfg, tok, jnp.int32(0), cache,
                                         lazy_cache=lazy, lazy_mode="masked",
                                         lazy_first_step=True)
    plan = np.zeros((cfg.n_layers, 2), bool)
    lg_a, cache_a, _ = tf.decode_step_unrolled(params, cfg, tok, jnp.int32(1),
                                               cache, lazy, plan_step=plan)
    lg_b, cache_b, _, _ = tf.decode_step(params, cfg, tok, jnp.int32(1), cache)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), rtol=1e-4,
                               atol=1e-4)


def test_unrolled_plan_skip_uses_cache_and_writes_kv():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = tf.init_decode_cache(cfg, B, max_len=8)
    lazy = tf.init_lazy_decode_cache(cfg, B)
    tok = jnp.ones((B, 1), jnp.int32)
    _, cache, lazy, _ = tf.decode_step(params, cfg, tok, jnp.int32(0), cache,
                                       lazy_cache=lazy, lazy_mode="masked",
                                       lazy_first_step=True)
    plan = np.ones((cfg.n_layers, 2), bool)      # skip EVERYTHING
    lg, cache2, lazy2 = tf.decode_step_unrolled(params, cfg, tok, jnp.int32(1),
                                                cache, lazy, plan_step=plan)
    assert not bool(jnp.any(jnp.isnan(lg)))
    # lazy cache unchanged (all modules reused)
    for a, b in zip(jax.tree.leaves(lazy), jax.tree.leaves(lazy2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # but attention KV at position 1 WAS written (kv-write on skip)
    k_before = jax.tree.leaves(cache)[0]
    k_after = jax.tree.leaves(cache2)[0]
    assert not np.array_equal(np.asarray(k_before), np.asarray(k_after))


def test_hlo_analyzer_counts_scan_trips():
    """The loop-aware analyzer must multiply by scan trip counts: a scanned
    matmul repeated N times reports ~N× the FLOPs of a single one."""
    w = jnp.ones((64, 64))

    def one(x):
        return x @ w

    def scanned(x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jnp.ones((32, 64))
    f1 = hlo_lib.analyze_module(jax.jit(one).lower(x).compile().as_text())
    f10 = hlo_lib.analyze_module(jax.jit(scanned).lower(x).compile().as_text())
    assert f1["flops"] > 0
    ratio = f10["flops"] / f1["flops"]
    assert 8 <= ratio <= 12, ratio


def test_hlo_collective_parse():
    txt = """
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[16,128]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  ROOT %ar = f32[16,128]{1,0} all-reduce(%ag), to_apply=%add
}
"""
    coll = hlo_lib.collective_bytes(txt)
    assert coll["all-gather"]["bytes"] == 16 * 128 * 4
    assert coll["all-reduce"]["count"] == 1


def test_param_spec_rules_shapes_only():
    """Rule sanity without building a mesh: path-based dims selection."""
    from repro.dist.sharding import param_spec
    import jax.sharding as js
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # with axis sizes 1 everything divides; check the AXES chosen
    spec = param_spec("prefix/0/attn/wq", (64, 64), mesh)
    assert spec == js.PartitionSpec(("data",), "model")
    spec = param_spec("prefix/0/attn/wo", (64, 64), mesh)
    assert spec == js.PartitionSpec("model", ("data",))
    spec = param_spec("embed", (128, 64), mesh)
    assert spec == js.PartitionSpec("model", ("data",))
    spec = param_spec("period/0/moe/experts/w_gate", (4, 64, 128), mesh)
    assert spec[0] is None


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.io import restore_checkpoint, save_checkpoint
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    back = restore_checkpoint(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
