"""Asyncio streaming front door: NDJSON e2e over localhost, shed path,
server stats (serving/server.py)."""
import asyncio
import functools

import jax
import numpy as np

from repro.configs.base import LazyConfig, ModelConfig
from repro.models import transformer as tf
from repro.serving.admission import AdmissionController, default_policy_bank
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.server import (StreamingServer, fetch_stats,
                                  request_once)


def tiny(**kw):
    base = dict(n_layers=3, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                d_ff=64, vocab_size=61, dtype="float32",
                lazy=LazyConfig(enabled=True, mode="masked"))
    base.update(kw)
    return ModelConfig(**base)


@functools.lru_cache(maxsize=2)
def fixture():
    cfg = tiny()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine():
    cfg, params = fixture()
    return ContinuousBatchingEngine(
        cfg, params, n_slots=2, max_len=32,
        policy_bank=default_policy_bank(lazy_ratio=0.5, seed=0),
        admission=AdmissionController())


def with_server(client_fn):
    """Start a StreamingServer on an ephemeral port, run the blocking
    client in an executor, return (client result, final server stats)."""
    async def main():
        srv = StreamingServer(make_engine(), port=0)
        await srv.start()
        loop = asyncio.get_running_loop()
        try:
            out = await asyncio.wait_for(
                loop.run_in_executor(None, client_fn, srv.port), timeout=120)
        finally:
            await srv.stop()
        return out, srv.stats()
    return asyncio.run(main())


def test_stream_one_request_end_to_end():
    """One generate request over a real localhost socket: the stream runs
    accepted -> policy_assigned -> admitted -> token... -> done, the done
    event carries all tokens, and the server records wall-clock
    first-chunk latency."""
    n_new = 5

    def client(port):
        return request_once("127.0.0.1", port, [3, 1, 4, 1], max_new=n_new,
                            slo_latency_s=1e4, max_skip_ratio=0.9,
                            priority=1)

    events, stats = with_server(client)
    kinds = [e["event"] for e in events]
    assert kinds[0] == "accepted"
    assert "policy_assigned" in kinds and "admitted" in kinds
    assert "first_token" in kinds
    assert kinds[-1] == "done"
    assert kinds.index("policy_assigned") < kinds.index("admitted")
    done = events[-1]
    assert done["n_out"] == n_new and len(done["tokens"]) == n_new
    # streamed tokens arrive in order and match the done event's list
    streamed = [e["token"] for e in events if e["event"] == "token"]
    assert streamed == list(done["tokens"])
    assigned = next(e for e in events if e["event"] == "policy_assigned")
    assert assigned["policy_class"] in ("quality", "balanced", "latency")
    assert stats["n_requests"] == 1 and stats["n_shed"] == 0
    fc = stats["first_chunk_latency_s"]
    assert fc["n"] == 1 and fc["p50"] > 0.0


def test_unsatisfiable_request_streams_shed():
    def client(port):
        return request_once("127.0.0.1", port, [1, 2, 3], max_new=8,
                            slo_latency_s=0.01, max_skip_ratio=0.9)

    events, stats = with_server(client)
    assert events[-1]["event"] == "shed"
    assert events[-1]["reason"] == "unsatisfiable"
    assert all(e["event"] != "token" for e in events)
    assert stats["n_shed"] == 1


def test_sequential_requests_and_stats_op():
    """Two requests over separate connections share one engine session;
    the stats op reports both on the service clock."""
    def client(port):
        out = []
        for i in range(2):
            out.append(request_once("127.0.0.1", port,
                                    [5 + i, 7, 11], max_new=3,
                                    slo_latency_s=1e4, max_skip_ratio=0.9))
        return out, fetch_stats("127.0.0.1", port)

    (streams, mid_stats), final_stats = with_server(client)
    for events in streams:
        assert events[-1]["event"] == "done"
        assert len(events[-1]["tokens"]) == 3
    # rids are distinct and both landed in the session metrics
    rids = {ev[-1]["rid"] for ev in streams}
    assert len(rids) == 2
    assert mid_stats["n_requests"] == 2
    assert mid_stats["service_clock"]["n_requests"] == 2
    assert final_stats["first_chunk_latency_s"]["n"] == 2


def test_outputs_match_trace_driven_session():
    """The socket path changes transport, not tokens: the same prompt
    through the NDJSON server equals the trace-driven engine run."""
    from repro.data.synthetic import SLORequestSpec
    prompt = [3, 1, 4, 1]
    n_new = 4

    def client(port):
        return request_once("127.0.0.1", port, prompt, max_new=n_new,
                            slo_latency_s=1e4, max_skip_ratio=0.9)

    events, _ = with_server(client)
    served = events[-1]["tokens"]

    eng = make_engine()
    res = eng.run([SLORequestSpec(
        rid=0, arrival=0.0, prompt=np.asarray(prompt, np.int32),
        max_new=n_new, slo_latency_s=1e4, max_skip_ratio=0.9)])
    ref = res.outputs[0][len(prompt):].tolist()
    assert served == ref
