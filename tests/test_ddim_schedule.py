"""Regression tests: DDIM timestep subsets when n_train % n_sample != 0,
and gate_score numerics under bf16 inputs (f32 accumulation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lazy import gate_score, init_lazy_gate
from repro.sampling.ddim import linear_schedule, sampling_timesteps


# ---------------------------------------------------------------------------
# sampling_timesteps with ragged divisors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_train,n_sample", [
    (1000, 50),        # the even paper case
    (1000, 7),         # ragged
    (1000, 13),
    (200, 30),
    (100, 9),
    (10, 7),           # step == 1 tail
    (10, 9),
])
def test_sampling_timesteps_unique_descending_in_range(n_train, n_sample):
    ts = sampling_timesteps(n_train, n_sample)
    assert ts.shape == (n_sample,)
    assert len(np.unique(ts)) == n_sample, "duplicate timesteps"
    assert np.all(np.diff(ts) < 0), "must be strictly descending"
    assert ts.min() >= 0 and ts.max() <= n_train - 1


def test_sampling_timesteps_index_schedule_safely():
    """Every emitted timestep must index the training schedule arrays."""
    sched = linear_schedule(100)
    ts = sampling_timesteps(100, 7)
    a = sched.alphas_cumprod[jnp.asarray(ts)]
    assert a.shape == (7,)
    assert bool(jnp.all((a > 0) & (a <= 1)))


# ---------------------------------------------------------------------------
# gate_score under bf16
# ---------------------------------------------------------------------------


def test_gate_score_bf16_f32_accumulation():
    """bf16 probes must accumulate in f32: finite scores in (0, 1) that
    agree with the f32 reference to bf16 resolution, even for long
    sequences where a bf16 mean would lose mass."""
    B, N, D = 2, 2048, 64
    key = jax.random.PRNGKey(0)
    gate32 = init_lazy_gate(key, D, dtype="float32")
    gate16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), gate32)
    z32 = jax.random.normal(jax.random.PRNGKey(1), (B, N, D), jnp.float32)
    z16 = z32.astype(jnp.bfloat16)

    s_ref = gate_score(gate32, z32)
    s_b16 = gate_score(gate16, z16)
    assert s_b16.dtype == jnp.float32
    s_b16 = np.asarray(s_b16)
    assert np.all(np.isfinite(s_b16))
    assert np.all((s_b16 > 0) & (s_b16 < 1))
    np.testing.assert_allclose(s_b16, np.asarray(s_ref), atol=2e-2)


def test_gate_score_bf16_extreme_inputs_finite():
    """Large-magnitude bf16 activations: sigmoid saturates instead of
    producing inf/nan."""
    D = 32
    gate = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                        init_lazy_gate(jax.random.PRNGKey(0), D))
    z = (jnp.ones((1, 4, D), jnp.float32) * 3e4).astype(jnp.bfloat16)
    s = np.asarray(gate_score(gate, z))
    assert np.all(np.isfinite(s))
    assert np.all((s >= 0) & (s <= 1))


def test_untrained_gate_is_diligent_on_unit_rms_inputs():
    """Regression for the serving divergence: with the small probe init an
    untrained gate stays below threshold on unit-RMS inputs — single-token
    decode included (no pooling to average the noise)."""
    D = 64
    gate = init_lazy_gate(jax.random.PRNGKey(0), D)
    z = jax.random.normal(jax.random.PRNGKey(2), (4096, 1, D))
    s = np.asarray(gate_score(gate, z))
    assert float(s.max()) < 0.5
