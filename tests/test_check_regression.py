"""Benchmark regression gate (benchmarks/check_regression.py): metric
collection from the BENCH_* schemas, the >5% one-sided tolerance, baseline
update/self-test flows, and the CLI exit codes CI keys off."""

import json

from benchmarks import check_regression as cr


def traj_payload(ratios):
    return {
        "schema": "repro.bench.trajectory/v1",
        "policies": {
            name: {"realized_skip_ratio": r} for name, r in ratios.items()
        },
    }


def cache_payload(saving):
    return {
        "schema": "repro.bench.cache_policies/v1",
        "workloads": {
            "dit": {
                "policies": {
                    "router": {
                        "realized_skip_ratio": 0.5,
                        "plan_flop_saving": saving,
                    }
                }
            }
        },
    }


def perf_payload(policies):
    """policies: {name: (wall_ms, wall_mad, speedup, speedup_mad)}"""
    return {
        "schema": "repro.bench.perf/v1",
        "policies": {
            name: {"wall_ms_median": w, "wall_ms_median_mad": wm,
                   "speedup_vs_host": s, "speedup_vs_host_mad": sm}
            for name, (w, wm, s, sm) in policies.items()
        },
    }


def kernels_payload(skip_speedup=30.0, skip_mad=1.0, blended=1.8,
                    blended_mad=0.05, saving=0.96, bitexact=True):
    return {
        "schema": "repro.bench.kernels/v1",
        "lazy_attention": {
            "skip_speedup_vs_select": skip_speedup,
            "skip_speedup_vs_select_mad": skip_mad,
            "blended_speedup_at_plan": blended,
            "blended_speedup_at_plan_mad": blended_mad,
            "bytes_saving_frac": saving,
            "plan_skip_ratio": 0.44,
            "cached_serve_bitexact": bitexact,
        },
        "gate_select": {"parity_ok": True},
        "ddim_update": {"parity_ok": True},
    }


def write(directory, name, payload):
    directory.mkdir(parents=True, exist_ok=True)
    (directory / name).write_text(json.dumps(payload))


def test_collect_metrics_flattens_both_schemas():
    m = cr.collect_metrics(traj_payload({"stride": 0.4, "none": 0.0}))
    assert m == {
        "trajectory/stride/realized_skip_ratio": 0.4,
        "trajectory/none/realized_skip_ratio": 0.0,
    }
    m = cr.collect_metrics(cache_payload(0.38))
    assert m["cache_policies/dit/router/plan_flop_saving"] == 0.38
    assert m["cache_policies/dit/router/realized_skip_ratio"] == 0.5
    assert cr.collect_metrics({"schema": "other/v1"}) == {}


def test_compare_tolerance_is_one_sided():
    base = {"m": 0.40}
    assert cr.compare(base, {"m": 0.40}) == []
    assert cr.compare(base, {"m": 0.39}) == []          # within 5%
    assert cr.compare(base, {"m": 0.60}) == []          # improvement: fine
    assert len(cr.compare(base, {"m": 0.37})) == 1      # 7.5% drop: fail
    assert len(cr.compare(base, {})) == 1               # vanished: fail
    # zero baselines (the `none` policy) gate nothing
    assert cr.compare({"z": 0.0}, {"z": 0.0}) == []
    assert cr.compare({"z": 0.0}, {}) == []


def test_gate_fails_on_injected_flop_saving_regression(tmp_path):
    """The acceptance demonstration: a >5% compiled-FLOP-saving drop vs
    the committed baseline makes the gate exit nonzero."""
    baseline, current = tmp_path / "base", tmp_path / "cur"
    write(baseline, "BENCH_cache_policies.json", cache_payload(0.40))
    write(current, "BENCH_cache_policies.json", cache_payload(0.40 * 0.90))
    rc = cr.main(["--baseline-dir", str(baseline),
                  "--current-dir", str(current)])
    assert rc == 1
    # within tolerance -> clean exit
    write(current, "BENCH_cache_policies.json", cache_payload(0.40 * 0.97))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 0


def test_gate_fails_on_skip_ratio_regression(tmp_path):
    baseline, current = tmp_path / "base", tmp_path / "cur"
    write(baseline, "BENCH_trajectory.json", traj_payload({"stride": 0.44}))
    write(current, "BENCH_trajectory.json", traj_payload({"stride": 0.30}))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 1


def test_missing_baselines_fail_loudly(tmp_path):
    assert cr.main(["--baseline-dir", str(tmp_path / "nope"),
                    "--current-dir", str(tmp_path / "alsono")]) == 1


def test_update_writes_baselines(tmp_path):
    baseline, current = tmp_path / "base", tmp_path / "cur"
    write(current, "BENCH_trajectory.json", traj_payload({"stride": 0.44}))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current), "--update"]) == 0
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 0


def test_self_test_bites(tmp_path):
    current = tmp_path / "cur"
    write(current, "BENCH_trajectory.json",
          traj_payload({"stride": 0.44, "none": 0.0}))
    assert cr.main(["--current-dir", str(current), "--self-test"]) == 0
    # no artifacts at all: the self-test must refuse to vacuously pass
    assert cr.main(["--current-dir", str(tmp_path / "empty"),
                    "--self-test"]) == 1


def test_collect_perf_metrics_and_noise():
    p = perf_payload({"none": (100.0, 2.0, 1.0, 0.05)})
    assert cr.collect_metrics(p) == {
        "perf/none/wall_ms_median": 100.0,
        "perf/none/speedup_vs_host": 1.0,
    }
    assert cr.collect_noise(p) == {
        "perf/none/wall_ms_median": 2.0,
        "perf/none/speedup_vs_host": 0.05,
    }
    # non-perf schemas carry no noise channel
    assert cr.collect_noise(traj_payload({"stride": 0.4})) == {}


def test_wall_gate_bites_catastrophic_and_tolerates_noise():
    wall = "perf/x/wall_ms_median"
    base = {wall: 100.0}
    # wall is lower-is-better with a catastrophic (100%) floor: a runner
    # that is merely slower passes, a fused executor falling back to
    # per-step dispatch (~10x) does not
    assert cr.compare(base, {wall: 180.0}) == []
    assert len(cr.compare(base, {wall: 1000.0})) == 1
    # MAD widening: the same overrun under huge measurement noise passes
    assert cr.compare(
        base, {wall: 250.0},
        baseline_noise={wall: 10.0}, current_noise={wall: 10.0}) == []
    assert len(cr.compare(base, {wall: 250.0})) == 1


def test_speedup_gate_is_noise_aware():
    sp = "perf/x/speedup_vs_host"
    base = {sp: 10.0}
    # 40% drop > the 35% perf floor on a quiet measurement: flagged
    assert len(cr.compare(base, {sp: 6.0})) == 1
    # the same drop with MAD-scale dispersion on both sides: tolerated
    assert cr.compare(
        base, {sp: 6.0},
        baseline_noise={sp: 1.0}, current_noise={sp: 1.0}) == []


def test_self_test_covers_perf_artifacts(tmp_path):
    current = tmp_path / "cur"
    write(current, "BENCH_trajectory.json",
          traj_payload({"stride": 0.44, "none": 0.0}))
    write(current, "PERF_trajectory.json",
          perf_payload({"none": (100.0, 2.0, 1.0, 0.02),
                        "static_router": (60.0, 1.5, 1.6, 0.06)}))
    assert cr.main(["--current-dir", str(current), "--self-test"]) == 0


def test_perf_gate_end_to_end(tmp_path):
    baseline, current = tmp_path / "base", tmp_path / "cur"
    write(baseline, "PERF_trajectory.json",
          perf_payload({"none": (100.0, 1.0, 1.0, 0.01)}))
    # same-machine wobble: passes
    write(current, "PERF_trajectory.json",
          perf_payload({"none": (110.0, 1.0, 0.95, 0.01)}))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 0
    # structural collapse: wall 10x, speedup halved -> gate fails
    write(current, "PERF_trajectory.json",
          perf_payload({"none": (1000.0, 1.0, 0.45, 0.01)}))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 1


def test_collect_kernel_metrics_and_noise():
    p = kernels_payload()
    m = cr.collect_metrics(p)
    # wall ratios opt into the perf floors via the perf/ prefix; bytes,
    # ratio, and the exactness/parity flags gate machine-independently
    assert m["perf/kernels_lazy_attention/skip_speedup_vs_select"] == 30.0
    assert m["perf/kernels_lazy_attention/blended_speedup_at_plan"] == 1.8
    assert m["kernels/lazy_attention/bytes_saving_frac"] == 0.96
    assert m["kernels/lazy_attention/plan_skip_ratio"] == 0.44
    assert m["kernels/lazy_attention/cached_serve_bitexact"] == 1.0
    assert m["kernels/gate_select/parity_ok"] == 1.0
    assert m["kernels/ddim_update/parity_ok"] == 1.0
    assert cr.collect_noise(p) == {
        "perf/kernels_lazy_attention/skip_speedup_vs_select": 1.0,
        "perf/kernels_lazy_attention/blended_speedup_at_plan": 0.05,
    }


def test_kernels_gate_end_to_end(tmp_path):
    baseline, current = tmp_path / "base", tmp_path / "cur"
    write(baseline, "BENCH_kernels.json", kernels_payload())
    # same-machine wobble on the wall ratio: within the perf floor
    write(current, "BENCH_kernels.json", kernels_payload(skip_speedup=25.0))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 0
    # losing cache bit-exactness is a hard regression (1.0 -> 0.0)
    write(current, "BENCH_kernels.json", kernels_payload(bitexact=False))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 1
    # a collapsed bytes saving (memory-level laziness lost) is flagged
    write(current, "BENCH_kernels.json", kernels_payload(saving=0.5))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 1
    # a structural skip-speedup collapse is flagged past the perf floor
    write(current, "BENCH_kernels.json",
          kernels_payload(skip_speedup=3.0, skip_mad=0.1))
    assert cr.main(["--baseline-dir", str(baseline),
                    "--current-dir", str(current)]) == 1


def test_self_test_covers_kernel_artifacts(tmp_path):
    current = tmp_path / "cur"
    write(current, "BENCH_kernels.json", kernels_payload())
    assert cr.main(["--current-dir", str(current), "--self-test"]) == 0


def test_committed_baselines_cover_the_gated_files():
    """The baselines this PR commits must exist and contain gated
    metrics — otherwise the CI gate would be a no-op."""
    metrics = cr.load_metrics(cr.DEFAULT_BASELINE_DIR)
    gated = {k: v for k, v in metrics.items() if v > cr.ZERO_FLOOR}
    assert len(gated) >= 5, (
        f"expected committed baselines under {cr.DEFAULT_BASELINE_DIR}, "
        f"found gated metrics: {sorted(gated)}"
    )
    # the kernel bench baseline (this PR) must be among them
    assert "kernels/lazy_attention/bytes_saving_frac" in gated
    assert "perf/kernels_lazy_attention/skip_speedup_vs_select" in gated
